//! R0 fixture: allow markers must carry a written reason.

pub fn empty_reason(v: Option<u32>) -> u32 {
    // a2q-lint: allow(panic-path)
    v.unwrap()
}
