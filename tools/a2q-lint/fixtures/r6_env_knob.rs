//! R6 fixture: `A2Q_*` env reads must appear in the knob registry —
//! `README_knobs.md` next to this file documents only `A2Q_DOCUMENTED`.

pub fn knobs() -> (Option<String>, Option<String>) {
    let documented = std::env::var("A2Q_DOCUMENTED").ok();
    let rogue = std::env::var("A2Q_NOT_A_KNOB").ok();
    (documented, rogue)
}
