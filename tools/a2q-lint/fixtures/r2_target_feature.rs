//! R2 fixture: a `#[target_feature]` definition outside the
//! `tensor::simd` dispatch module trips, even when documented and unsafe.

/// SAFETY: caller must ensure AVX2 is available.
#[target_feature(enable = "avx2")]
pub unsafe fn rogue_kernel(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v += 1.0;
    }
}
