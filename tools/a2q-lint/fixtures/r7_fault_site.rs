//! R7 fixture: `fault::point` site names must appear in the README
//! fault-site table and be unique — expected findings: one unregistered
//! site, one duplicate use of a registered site.

mod fault {
    pub fn point(_site: &str) -> Result<(), String> {
        Ok(())
    }
}

/// Registered in `README_knobs.md` and used once here: clean.
pub fn registered_site() -> Result<(), String> {
    fault::point("fixture.registered")
}

/// Missing from the fixture fault-site table: R7.
pub fn unregistered_site() -> Result<(), String> {
    fault::point("fixture.unregistered")
}

/// Second use of `fixture.registered`: R7 (an `A2Q_FAULTS` schedule
/// could no longer target one site unambiguously).
pub fn duplicate_site() -> Result<(), String> {
    fault::point("fixture.registered")
}

/// The escape hatch suppresses the finding when it carries a reason.
pub fn allowed_site() -> Result<(), String> {
    // a2q-lint: allow(fault-registry) fixture demonstrating the escape hatch
    fault::point("fixture.not_in_table")
}

#[cfg(test)]
mod tests {
    /// Test-only sites are exempt: tests arm throwaway names.
    #[test]
    fn test_lines_are_exempt() {
        super::fault::point("selftest.throwaway").unwrap();
    }
}
