//! R4 fixture: runner-path `.unwrap()`/`.expect()` outside tests trip;
//! the annotated lock unwrap and the `#[cfg(test)]` module do not.

use std::sync::Mutex;

pub fn response_path(v: Option<u32>, m: &Mutex<u32>) -> u32 {
    let a = v.unwrap();
    let b = v.expect("reachable by malformed input");
    // a2q-lint: allow(panic-path) fixture: lock poisoning propagates a prior panic on purpose
    let c = *m.lock().unwrap();
    a + b + c
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
