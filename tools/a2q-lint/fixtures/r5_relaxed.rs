//! R5 fixture: epoch/admission atomics must not use `Ordering::Relaxed`.

use std::sync::atomic::AtomicU64;

pub fn bump_epoch(e: &AtomicU64) -> u64 {
    e.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}
