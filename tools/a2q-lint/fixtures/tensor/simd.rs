//! R2 fixture: inside a `tensor/simd.rs` path the location is fine, but a
//! safe (non-`unsafe`) `#[target_feature]` fn still trips the rule.

#[target_feature(enable = "avx2")]
pub fn not_marked_unsafe(x: &mut [i32]) {
    for v in x.iter_mut() {
        *v += 1;
    }
}
