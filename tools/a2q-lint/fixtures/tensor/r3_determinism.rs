//! R3 fixture: three determinism violations in a kernel-path file — FMA
//! contraction, hash-order iteration feeding a sum, and a partial_cmp
//! float sort.

pub fn fma(acc: f32, a: f32, b: f32) -> f32 {
    a.mul_add(b, acc)
}

pub fn hash_order_sum(m: &std::collections::HashMap<u32, f32>) -> f32 {
    let mut s = 0.0;
    for v in m.values() {
        s += v;
    }
    s
}

pub fn sort_scores(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
