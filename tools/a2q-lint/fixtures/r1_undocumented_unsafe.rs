//! R1 fixture: the undocumented `unsafe fn` and the first block must
//! trip; the SAFETY-commented block and the allowed block must not.

pub unsafe fn undocumented(p: *const u8) -> u8 {
    *p
}

pub fn blocks(p: *const u8) -> u8 {
    let a = unsafe { *p };
    // SAFETY: caller guarantees `p` is valid for reads (documented block).
    let b = unsafe { *p };
    // a2q-lint: allow(undocumented-unsafe) fixture exercising the allow path
    let c = unsafe { *p };
    a.wrapping_add(b).wrapping_add(c)
}
