//! Lint-clean fixture: documented unsafe, no banned constructs.

/// Reads the first byte of a non-empty slice.
pub fn first(p: &[u8]) -> u8 {
    assert!(!p.is_empty());
    // SAFETY: `p` is non-empty per the assert above, so index 0 is in
    // bounds and `as_ptr()` is valid for a one-byte read.
    unsafe { *p.as_ptr() }
}
