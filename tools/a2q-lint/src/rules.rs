//! The seven repo-specific rules (plus R0, marker hygiene).  Each rule is a
//! pass over the scrubbed token stream from [`crate::lexer`]:
//!
//! * **R1 `undocumented-unsafe`** — every `unsafe` block/fn/impl carries a
//!   `// SAFETY:` comment (same line, or directly above through any run of
//!   comments and attributes).
//! * **R2 `target-feature`** — `#[target_feature]` fns may only be
//!   *defined* in `tensor/simd.rs` (the dispatch module keeps them in
//!   private `avx2`/`neon` submodules, so the compiler already confines
//!   invocation) and must be `unsafe`.
//! * **R3 `nondeterminism`** — kernel modules (`tensor/`, `quant/`,
//!   `gnn/`) must stay bitwise-deterministic: no `mul_add`/FMA
//!   intrinsics, no `HashMap`/`HashSet` (iteration order feeding
//!   accumulation), no `partial_cmp` float ordering (use `total_cmp`).
//! * **R4 `panic-path`** — runner-path modules (`coordinator/`,
//!   `runtime/`) must not `.unwrap()`/`.expect()` outside `#[cfg(test)]`
//!   unless annotated with an audited allow marker.
//! * **R5 `relaxed-ordering`** — no `Ordering::Relaxed` on the
//!   epoch/admission atomics (they publish state across runner threads;
//!   Acquire/Release is the floor).
//! * **R6 `env-registry`** — every `A2Q_*` env var read via `env::var`
//!   must appear in the README knob table.
//! * **R7 `fault-registry`** — every `fault::point("<site>")` name must
//!   appear in the README fault-site table, and site names must be
//!   unique across the tree (a duplicated name makes `A2Q_FAULTS`
//!   schedules ambiguous).
//!
//! Escape hatch: `// a2q-lint: allow(<rule>[, <rule>…]) <reason>` on the
//! offending line (or alone on the line above) suppresses a finding; a
//! marker without a written reason is itself a finding (R0).

use std::collections::{BTreeMap, BTreeSet};

use crate::lexer::{scrub, tokenize, Scrub, Tok};

/// `(rule id, allow()/report slug)` for every enforced rule.
pub const RULES: &[(&str, &str)] = &[
    ("R1", "undocumented-unsafe"),
    ("R2", "target-feature"),
    ("R3", "nondeterminism"),
    ("R4", "panic-path"),
    ("R5", "relaxed-ordering"),
    ("R6", "env-registry"),
    ("R7", "fault-registry"),
];

#[derive(Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub slug: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!(
            "{}:{}: [{}/{}] {}",
            self.path, self.line, self.rule, self.slug, self.message
        )
    }
}

/// Path components with any `.rs` suffix stripped, so directory names and
/// file stems compare uniformly.
fn comps(path: &str) -> Vec<String> {
    path.split(['/', '\\'])
        .map(|c| c.trim_end_matches(".rs").to_string())
        .collect()
}

fn has_comp(path: &str, names: &[&str]) -> bool {
    comps(path).iter().any(|c| names.contains(&c.as_str()))
}

/// Kernel modules under the bitwise-determinism contract (R3).
fn is_kernel(path: &str) -> bool {
    has_comp(path, &["tensor", "quant", "gnn"])
}

/// Runner-path modules under the panic-safety contract (R4).
fn is_runner(path: &str) -> bool {
    has_comp(path, &["coordinator", "runtime"])
}

/// The one module allowed to define `#[target_feature]` fns.
fn is_dispatch(path: &str) -> bool {
    let c = comps(path);
    c.len() >= 2 && c[c.len() - 2] == "tensor" && c[c.len() - 1] == "simd"
}

/// Per-line allow sets parsed from `a2q-lint: allow(...)` markers.
/// Marker-hygiene problems (no reason, unknown rule) become R0 findings.
struct Allows {
    by_line: BTreeMap<usize, BTreeSet<String>>,
}

impl Allows {
    fn permits(&self, line: usize, slug: &str) -> bool {
        self.by_line.get(&line).is_some_and(|s| s.contains(slug))
    }
}

fn parse_allows(s: &Scrub, path: &str, findings: &mut Vec<Finding>) -> Allows {
    let lines: Vec<&str> = s.code.lines().collect();
    let known: BTreeSet<&str> = RULES.iter().map(|r| r.1).collect();
    let mut by_line: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    for (line, text) in &s.comments {
        let Some(pos) = text.find("a2q-lint:") else {
            continue;
        };
        let rest = text[pos + "a2q-lint:".len()..].trim_start();
        let mut hygiene = |message: String| {
            findings.push(Finding {
                rule: "R0",
                slug: "allow-hygiene",
                path: path.to_string(),
                line: *line,
                message,
            });
        };
        let Some(args) = rest.strip_prefix("allow(") else {
            hygiene("marker must read `a2q-lint: allow(<rule>) <reason>`".to_string());
            continue;
        };
        let Some(close) = args.find(')') else {
            hygiene("unterminated allow( list".to_string());
            continue;
        };
        let reason = args[close + 1..].trim();
        if reason.is_empty() {
            hygiene("allow marker must carry a written reason after the rule list".to_string());
            continue;
        }
        let mut slugs: BTreeSet<String> = BTreeSet::new();
        let mut ok = true;
        for r in args[..close].split(',') {
            let r = r.trim();
            if known.contains(r) {
                slugs.insert(r.to_string());
            } else {
                ok = false;
                hygiene(format!(
                    "unknown rule `{r}` in allow() (expected one of: {})",
                    known.iter().copied().collect::<Vec<_>>().join(", ")
                ));
            }
        }
        if !ok || slugs.is_empty() {
            continue;
        }
        // a trailing marker covers its own line; a marker alone on a line
        // covers the next line that carries code
        let mut target = *line;
        let marker_alone = lines.get(*line - 1).map_or("", |l| *l).trim().is_empty();
        if marker_alone {
            let mut t = *line + 1;
            while t <= lines.len() && lines[t - 1].trim().is_empty() {
                t += 1;
            }
            target = t;
        }
        by_line.entry(target).or_default().extend(slugs);
    }
    Allows { by_line }
}

/// A `// SAFETY:` comment on `line` itself, or directly above it through
/// any contiguous run of comment/attribute lines (doc comments count).
fn has_safety_near(s: &Scrub, lines: &[&str], line: usize) -> bool {
    let mut l = line;
    loop {
        if s.comment_on(l, "SAFETY:") {
            return true;
        }
        if l == 1 {
            return false;
        }
        l -= 1;
        let trimmed = lines.get(l - 1).map_or("", |x| *x).trim().to_string();
        let is_attr = trimmed.starts_with("#[") || trimmed.starts_with("#!");
        if trimmed.is_empty() && !s.has_comment(l) {
            return false; // a truly blank line breaks the run
        }
        if !trimmed.is_empty() && !is_attr {
            // a code line ends the run; accept only its trailing comment
            return s.comment_on(l, "SAFETY:");
        }
    }
}

fn r1_undocumented_unsafe(
    path: &str,
    s: &Scrub,
    toks: &[Tok],
    allows: &Allows,
    findings: &mut Vec<Finding>,
) {
    let lines: Vec<&str> = s.code.lines().collect();
    for (idx, t) in toks.iter().enumerate() {
        if t.word() != Some("unsafe") {
            continue;
        }
        let kind = match toks.get(idx + 1) {
            Some(n) if n.word() == Some("fn") => "fn",
            Some(n) if n.word() == Some("impl") => "impl",
            Some(n) if n.word() == Some("trait") => "trait",
            Some(n) if n.word() == Some("extern") => "extern block",
            _ => "block",
        };
        if allows.permits(t.line, "undocumented-unsafe") {
            continue;
        }
        if !has_safety_near(s, &lines, t.line) {
            findings.push(Finding {
                rule: "R1",
                slug: "undocumented-unsafe",
                path: path.to_string(),
                line: t.line,
                message: format!("unsafe {kind} without a `// SAFETY:` comment"),
            });
        }
    }
}

fn r2_target_feature(path: &str, toks: &[Tok], allows: &Allows, findings: &mut Vec<Finding>) {
    for idx in 0..toks.len() {
        if toks[idx].word() != Some("target_feature") {
            continue;
        }
        // only the attribute form `#[target_feature(...)]` counts
        let attr = idx >= 2 && toks[idx - 1].sym() == Some('[') && toks[idx - 2].sym() == Some('#');
        if !attr {
            continue;
        }
        let line = toks[idx].line;
        if !is_dispatch(path) && !allows.permits(line, "target-feature") {
            findings.push(Finding {
                rule: "R2",
                slug: "target-feature",
                path: path.to_string(),
                line,
                message: "#[target_feature] fn defined outside the tensor::simd dispatch \
                          module (vector kernels live behind its Isa match)"
                    .to_string(),
            });
        }
        // the decorated fn must be `unsafe` (callers must prove the ISA)
        let mut saw_unsafe = false;
        let mut fn_line = None;
        for t in toks.iter().skip(idx + 1).take(64) {
            match t.word() {
                Some("unsafe") => saw_unsafe = true,
                Some("fn") => {
                    fn_line = Some(t.line);
                    break;
                }
                _ => {}
            }
        }
        if let Some(fn_line) = fn_line {
            if !saw_unsafe && !allows.permits(fn_line, "target-feature") {
                findings.push(Finding {
                    rule: "R2",
                    slug: "target-feature",
                    path: path.to_string(),
                    line: fn_line,
                    message: "#[target_feature] fn must be `unsafe` — callers prove ISA \
                              availability at the dispatch site"
                        .to_string(),
                });
            }
        }
    }
}

/// Identifiers banned in kernel modules, with the determinism argument.
const BANNED_KERNEL_WORDS: &[(&str, &str)] = &[
    (
        "mul_add",
        "fused multiply-add rounds once; kernels must round like the scalar oracle",
    ),
    (
        "HashMap",
        "hash iteration order feeding accumulation breaks bitwise determinism",
    ),
    (
        "HashSet",
        "hash iteration order feeding accumulation breaks bitwise determinism",
    ),
    (
        "partial_cmp",
        "float ordering must use total_cmp (NaN-total, reproducible)",
    ),
];

fn r3_nondeterminism(
    path: &str,
    s: &Scrub,
    toks: &[Tok],
    allows: &Allows,
    findings: &mut Vec<Finding>,
) {
    for t in toks {
        let Some(w) = t.word() else {
            continue;
        };
        let why = BANNED_KERNEL_WORDS
            .iter()
            .find(|(b, _)| *b == w)
            .map(|(_, why)| *why)
            .or_else(|| {
                (w.contains("fmadd") || w.starts_with("vfma"))
                    .then_some("FMA intrinsics contract the rounding the scalar oracle performs")
            });
        let Some(why) = why else {
            continue;
        };
        if s.is_test_line(t.line) || allows.permits(t.line, "nondeterminism") {
            continue;
        }
        findings.push(Finding {
            rule: "R3",
            slug: "nondeterminism",
            path: path.to_string(),
            line: t.line,
            message: format!("`{w}` in a kernel module: {why}"),
        });
    }
}

fn r4_panic_path(
    path: &str,
    s: &Scrub,
    toks: &[Tok],
    allows: &Allows,
    findings: &mut Vec<Finding>,
) {
    for idx in 1..toks.len() {
        let Some(w) = toks[idx].word() else {
            continue;
        };
        if w != "unwrap" && w != "expect" {
            continue;
        }
        if toks[idx - 1].sym() != Some('.') {
            continue;
        }
        if toks.get(idx + 1).and_then(|t| t.sym()) != Some('(') {
            continue;
        }
        let line = toks[idx].line;
        if s.is_test_line(line) || allows.permits(line, "panic-path") {
            continue;
        }
        findings.push(Finding {
            rule: "R4",
            slug: "panic-path",
            path: path.to_string(),
            line,
            message: format!(
                "`.{w}()` on a runner path can panic a serving thread; return a \
                 coordinator error, or annotate `// a2q-lint: allow(panic-path) <reason>`"
            ),
        });
    }
}

fn r5_relaxed_ordering(path: &str, toks: &[Tok], allows: &Allows, findings: &mut Vec<Finding>) {
    for t in toks {
        if t.word() != Some("Relaxed") {
            continue;
        }
        if allows.permits(t.line, "relaxed-ordering") {
            continue;
        }
        findings.push(Finding {
            rule: "R5",
            slug: "relaxed-ordering",
            path: path.to_string(),
            line: t.line,
            message: "Ordering::Relaxed forbidden: epoch/admission atomics publish state \
                      across runner threads (Acquire/Release is the floor)"
                .to_string(),
        });
    }
}

fn knob_name(v: &str) -> bool {
    v.starts_with("A2Q_")
        && v.len() > 4
        && v.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
}

fn r6_env_registry(
    path: &str,
    s: &Scrub,
    toks: &[Tok],
    knobs: &BTreeSet<String>,
    allows: &Allows,
    findings: &mut Vec<Finding>,
) {
    for idx in 0..toks.len() {
        if toks[idx].word() != Some("env") {
            continue;
        }
        let colons = toks.get(idx + 1).and_then(|t| t.sym()) == Some(':')
            && toks.get(idx + 2).and_then(|t| t.sym()) == Some(':');
        if !colons {
            continue;
        }
        let Some(w) = toks.get(idx + 3).and_then(|t| t.word()) else {
            continue;
        };
        if w != "var" && w != "var_os" {
            continue;
        }
        let line = toks[idx + 3].line;
        // the knob literal: first A2Q_* string on this line or the next two
        // (rustfmt may wrap the call)
        let Some(name) = s
            .strings
            .iter()
            .filter(|(l, _)| *l >= line && *l <= line + 2)
            .map(|(_, v)| v)
            .find(|v| knob_name(v))
        else {
            continue;
        };
        if knobs.contains(name) || allows.permits(line, "env-registry") {
            continue;
        }
        findings.push(Finding {
            rule: "R6",
            slug: "env-registry",
            path: path.to_string(),
            line,
            message: format!(
                "`{name}` is read here but missing from the README environment-knob table"
            ),
        });
    }
}

/// Whether a string is a valid fault-site name: two or more
/// dot-separated `[a-z][a-z0-9_]*` segments (the same grammar
/// `util::fault::validate_site` enforces at runtime).
fn site_name(v: &str) -> bool {
    let segs: Vec<&str> = v.split('.').collect();
    segs.len() >= 2
        && segs.iter().all(|seg| {
            let mut ch = seg.chars();
            matches!(ch.next(), Some(c) if c.is_ascii_lowercase())
                && ch.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        })
}

/// `fault::point("<site>")` call sites in a file, as `(line, site)`,
/// excluding test-only lines (tests use throwaway `selftest.*` names).
pub fn fault_points(src: &str) -> Vec<(usize, String)> {
    let s = scrub(src);
    let toks = tokenize(&s.code);
    let mut out = Vec::new();
    for idx in 0..toks.len() {
        if toks[idx].word() != Some("fault") {
            continue;
        }
        let call = toks.get(idx + 1).and_then(|t| t.sym()) == Some(':')
            && toks.get(idx + 2).and_then(|t| t.sym()) == Some(':')
            && toks.get(idx + 3).and_then(|t| t.word()) == Some("point")
            && toks.get(idx + 4).and_then(|t| t.sym()) == Some('(');
        if !call {
            continue;
        }
        let line = toks[idx + 3].line;
        if s.is_test_line(line) {
            continue;
        }
        // the site literal: first string on this line or the next two
        // (rustfmt may wrap the call)
        if let Some((l, v)) = s
            .strings
            .iter()
            .find(|(l, _)| *l >= line && *l <= line + 2)
        {
            out.push((*l, v.clone()));
        }
    }
    out
}

fn r7_fault_registry(
    path: &str,
    src: &str,
    sites: &BTreeSet<String>,
    allows: &Allows,
    findings: &mut Vec<Finding>,
) {
    let mut seen: BTreeMap<String, usize> = BTreeMap::new();
    for (line, name) in fault_points(src) {
        if allows.permits(line, "fault-registry") {
            continue;
        }
        if !sites.contains(&name) {
            findings.push(Finding {
                rule: "R7",
                slug: "fault-registry",
                path: path.to_string(),
                line,
                message: format!(
                    "fault site `{name}` is not registered in the README fault-site table"
                ),
            });
        }
        if let Some(first) = seen.get(&name) {
            findings.push(Finding {
                rule: "R7",
                slug: "fault-registry",
                path: path.to_string(),
                line,
                message: format!(
                    "fault site `{name}` already used at line {first}; site names must be \
                     unique so `A2Q_FAULTS` schedules are unambiguous"
                ),
            });
        } else {
            seen.insert(name, line);
        }
    }
}

/// Cross-file uniqueness (within-file duplicates are caught by
/// [`check_file`]): a site name used in two different files is a finding
/// against every file after the first, in scan order.
pub fn cross_file_fault_duplicates(per_file: &[(String, Vec<(usize, String)>)]) -> Vec<Finding> {
    let mut first_use: BTreeMap<String, String> = BTreeMap::new();
    let mut findings = Vec::new();
    for (path, points) in per_file {
        for (line, name) in points {
            match first_use.get(name) {
                None => {
                    first_use.insert(name.clone(), path.clone());
                }
                Some(origin) if origin != path => findings.push(Finding {
                    rule: "R7",
                    slug: "fault-registry",
                    path: path.clone(),
                    line: *line,
                    message: format!(
                        "fault site `{name}` already used in {origin}; site names must be \
                         unique so `A2Q_FAULTS` schedules are unambiguous"
                    ),
                }),
                Some(_) => {} // same-file duplicate: check_file reported it
            }
        }
    }
    findings
}

/// Parse the registered fault-site names out of the README's markdown
/// table rows: backticked dotted-lowercase tokens in lines starting
/// with `|` (mirrors [`readme_knobs`]).
pub fn readme_fault_sites(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in text.lines() {
        let t = line.trim_start();
        if !t.starts_with('|') {
            continue;
        }
        let mut rest = t;
        while let Some(p) = rest.find('`') {
            let tail = &rest[p + 1..];
            let Some(q) = tail.find('`') else { break };
            let tok = &tail[..q];
            if site_name(tok) {
                out.insert(tok.to_string());
            }
            rest = &tail[q + 1..];
        }
    }
    out
}

/// Parse the registered knob names out of the README's markdown table rows
/// (lines starting with `|` that mention an `A2Q_*` name).
pub fn readme_knobs(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for line in text.lines() {
        let t = line.trim_start();
        if !t.starts_with('|') {
            continue;
        }
        let mut rest = t;
        while let Some(p) = rest.find("A2Q_") {
            let tail = &rest[p..];
            let end = tail
                .find(|c: char| !(c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_'))
                .unwrap_or(tail.len());
            out.insert(tail[..end].to_string());
            rest = &tail[end..];
        }
    }
    out
}

/// Run every rule over one file.  `knobs` is the README knob registry
/// (R6); `sites` the README fault-site registry (R7).
pub fn check_file(
    path: &str,
    src: &str,
    knobs: &BTreeSet<String>,
    sites: &BTreeSet<String>,
) -> Vec<Finding> {
    let s = scrub(src);
    let toks = tokenize(&s.code);
    let mut findings = Vec::new();
    let allows = parse_allows(&s, path, &mut findings);
    r1_undocumented_unsafe(path, &s, &toks, &allows, &mut findings);
    r2_target_feature(path, &toks, &allows, &mut findings);
    if is_kernel(path) {
        r3_nondeterminism(path, &s, &toks, &allows, &mut findings);
    }
    if is_runner(path) {
        r4_panic_path(path, &s, &toks, &allows, &mut findings);
    }
    r5_relaxed_ordering(path, &toks, &allows, &mut findings);
    r6_env_registry(path, &s, &toks, knobs, &allows, &mut findings);
    r7_fault_registry(path, src, sites, &allows, &mut findings);
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}
