//! Fixture-based self-tests: each fixture file trips exactly its own
//! rule, the clean fixture passes, and — the acceptance criterion — the
//! real `rust/` tree is lint-clean.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use crate::rules::{
    check_file, cross_file_fault_duplicates, fault_points, readme_fault_sites, readme_knobs,
    Finding,
};

fn fixture_path(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(rel)
}

fn registries_from(readme: &Path) -> (BTreeSet<String>, BTreeSet<String>) {
    let text = std::fs::read_to_string(readme)
        .unwrap_or_else(|e| panic!("read {}: {e}", readme.display()));
    (readme_knobs(&text), readme_fault_sites(&text))
}

/// Lint one fixture against the fixture knob/fault-site registries.
fn run_fixture(rel: &str) -> Vec<Finding> {
    let path = fixture_path(rel);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let (knobs, sites) = registries_from(&fixture_path("README_knobs.md"));
    let display = path.to_string_lossy().replace('\\', "/");
    check_file(&display, &src, &knobs, &sites)
}

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

#[test]
fn r1_fixture_trips_twice_and_honors_safety_and_allow() {
    let f = run_fixture("r1_undocumented_unsafe.rs");
    assert_eq!(rules_of(&f), ["R1", "R1"], "findings: {f:?}");
    assert!(f.iter().all(|x| x.slug == "undocumented-unsafe"));
}

#[test]
fn r2_fixture_trips_outside_dispatch_module() {
    let f = run_fixture("r2_target_feature.rs");
    assert_eq!(rules_of(&f), ["R2"], "findings: {f:?}");
    assert!(f[0].message.contains("outside"), "message: {}", f[0].message);
}

#[test]
fn r2_fixture_trips_safe_target_feature_even_in_dispatch_path() {
    let f = run_fixture("tensor/simd.rs");
    assert_eq!(rules_of(&f), ["R2"], "findings: {f:?}");
    assert!(f[0].message.contains("unsafe"), "message: {}", f[0].message);
}

#[test]
fn r3_fixture_trips_fma_hashmap_and_partial_cmp() {
    let f = run_fixture("tensor/r3_determinism.rs");
    assert_eq!(rules_of(&f), ["R3", "R3", "R3"], "findings: {f:?}");
    let msgs: Vec<&str> = f.iter().map(|x| x.message.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("mul_add")));
    assert!(msgs.iter().any(|m| m.contains("HashMap")));
    assert!(msgs.iter().any(|m| m.contains("partial_cmp")));
}

#[test]
fn r4_fixture_trips_production_unwraps_only() {
    let f = run_fixture("coordinator/r4_unwrap.rs");
    assert_eq!(rules_of(&f), ["R4", "R4"], "findings: {f:?}");
    assert!(f[0].message.contains("unwrap"));
    assert!(f[1].message.contains("expect"));
}

#[test]
fn r5_fixture_trips_relaxed_ordering() {
    let f = run_fixture("r5_relaxed.rs");
    assert_eq!(rules_of(&f), ["R5"], "findings: {f:?}");
}

#[test]
fn r6_fixture_trips_unregistered_knob_only() {
    let f = run_fixture("r6_env_knob.rs");
    assert_eq!(rules_of(&f), ["R6"], "findings: {f:?}");
    assert!(
        f[0].message.contains("A2Q_NOT_A_KNOB"),
        "message: {}",
        f[0].message
    );
}

#[test]
fn r7_fixture_trips_unregistered_and_duplicate_sites() {
    let f = run_fixture("r7_fault_site.rs");
    assert_eq!(rules_of(&f), ["R7", "R7"], "findings: {f:?}");
    assert!(
        f[0].message.contains("not registered"),
        "message: {}",
        f[0].message
    );
    assert!(
        f[1].message.contains("already used"),
        "message: {}",
        f[1].message
    );
}

#[test]
fn r7_cross_file_duplicates_flag_second_file_only() {
    let src_a = "pub fn a() { fault::point(\"fixture.registered\").unwrap(); }\n";
    let src_b = "pub fn b() { fault::point(\"fixture.registered\").unwrap(); }\n";
    let per_file = vec![
        ("a.rs".to_string(), fault_points(src_a)),
        ("b.rs".to_string(), fault_points(src_b)),
    ];
    let f = cross_file_fault_duplicates(&per_file);
    assert_eq!(rules_of(&f), ["R7"], "findings: {f:?}");
    assert_eq!(f[0].path, "b.rs");
    assert!(f[0].message.contains("a.rs"), "message: {}", f[0].message);
}

#[test]
fn r0_fixture_trips_allow_marker_without_reason() {
    let f = run_fixture("r0_bad_allow.rs");
    assert_eq!(rules_of(&f), ["R0"], "findings: {f:?}");
    assert!(f[0].message.contains("reason"), "message: {}", f[0].message);
}

#[test]
fn clean_fixture_passes() {
    let f = run_fixture("clean.rs");
    assert!(f.is_empty(), "clean fixture tripped: {f:?}");
}

/// Acceptance criterion: the real tree is lint-clean against the real
/// README knob and fault-site tables (all allows carrying written
/// reasons, every fault site registered and globally unique).
#[test]
fn real_tree_is_lint_clean() {
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let (knobs, sites) = registries_from(&repo.join("README.md"));
    let mut files = Vec::new();
    for root in ["rust/src", "rust/tests"] {
        collect(&repo.join(root), &mut files);
    }
    assert!(!files.is_empty(), "no sources found under {}", repo.display());
    let mut findings = Vec::new();
    let mut per_file_points = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f).unwrap_or_else(|e| panic!("read {f:?}: {e}"));
        let display = f.to_string_lossy().replace('\\', "/");
        findings.extend(check_file(&display, &src, &knobs, &sites));
        per_file_points.push((display, fault_points(&src)));
    }
    findings.extend(cross_file_fault_duplicates(&per_file_points));
    let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
    assert!(
        findings.is_empty(),
        "real tree has findings:\n{}",
        rendered.join("\n")
    );
}

fn collect(root: &Path, out: &mut Vec<PathBuf>) {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)
        .unwrap_or_else(|e| panic!("read_dir {}: {e}", root.display()))
        .map(|e| e.expect("dir entry").path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}
