//! `a2q-lint` — the repo's zero-dependency invariant checker.
//!
//! Walks Rust sources (default: `rust/src` and `rust/tests`, run from the
//! repo root) and enforces the unsafe-code and bitwise-determinism
//! contracts described in `src/rules.rs`.  Findings go to stdout as
//! `path:line: [R#/slug] message`; `--json <path>` additionally writes a
//! machine-readable array (uploaded as a CI artifact on failure).
//!
//! Exit codes: 0 clean · 1 findings · 2 usage or I/O error.
//!
//! ```text
//! a2q-lint [--readme <README.md>] [--json <out.json>] [ROOT|FILE ...]
//! ```

mod lexer;
mod rules;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use rules::{
    check_file, cross_file_fault_duplicates, fault_points, readme_fault_sites, readme_knobs,
    Finding,
};

struct Opts {
    readme: PathBuf,
    json: Option<PathBuf>,
    roots: Vec<PathBuf>,
}

fn parse_args(args: &[String]) -> Result<Opts, String> {
    let mut readme = PathBuf::from("README.md");
    let mut json = None;
    let mut roots = Vec::new();
    let mut i = 0usize;
    while i < args.len() {
        match args[i].as_str() {
            "--readme" => {
                i += 1;
                readme = PathBuf::from(args.get(i).ok_or("--readme needs a path")?);
            }
            "--json" => {
                i += 1;
                json = Some(PathBuf::from(args.get(i).ok_or("--json needs a path")?));
            }
            "--help" | "-h" => {
                return Err("usage: a2q-lint [--readme <path>] [--json <path>] [ROOT ...]"
                    .to_string())
            }
            flag if flag.starts_with("--") => return Err(format!("unknown flag {flag}")),
            root => roots.push(PathBuf::from(root)),
        }
        i += 1;
    }
    if roots.is_empty() {
        roots = vec![PathBuf::from("rust/src"), PathBuf::from("rust/tests")];
    }
    Ok(Opts {
        readme,
        json,
        roots,
    })
}

/// Collect `.rs` files under `root` (or `root` itself), sorted so runs are
/// deterministic across filesystems.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)?
        .map(|e| e.map(|e| e.path()))
        .collect::<std::io::Result<_>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `roots` against the `readme` knob and
/// fault-site registries.  Returns `(findings, files_scanned)`.
fn lint(roots: &[PathBuf], readme: &Path) -> Result<(Vec<Finding>, usize), String> {
    let readme_text = std::fs::read_to_string(readme)
        .map_err(|e| format!("cannot read knob registry {}: {e}", readme.display()))?;
    let knobs: BTreeSet<String> = readme_knobs(&readme_text);
    let sites: BTreeSet<String> = readme_fault_sites(&readme_text);
    let mut files = Vec::new();
    for root in roots {
        collect_rs(root, &mut files).map_err(|e| format!("walking {}: {e}", root.display()))?;
    }
    let mut findings = Vec::new();
    let mut per_file_points = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .map_err(|e| format!("cannot read {}: {e}", f.display()))?;
        let display = f.to_string_lossy().replace('\\', "/");
        findings.extend(check_file(&display, &src, &knobs, &sites));
        per_file_points.push((display, fault_points(&src)));
    }
    // R7 cross-file pass: a site name reused in a different file
    findings.extend(cross_file_fault_duplicates(&per_file_points));
    Ok((findings, files.len()))
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn write_json(findings: &[Finding], path: &Path) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, f) in findings.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"rule\": \"{}\", \"slug\": \"{}\", \"path\": \"{}\", \
             \"line\": {}, \"message\": \"{}\"}}{}\n",
            f.rule,
            f.slug,
            json_escape(&f.path),
            f.line,
            json_escape(&f.message),
            if i + 1 < findings.len() { "," } else { "" }
        ));
    }
    out.push_str("]\n");
    std::fs::write(path, out)
}

fn run(args: &[String]) -> Result<usize, String> {
    let opts = parse_args(args)?;
    let (findings, scanned) = lint(&opts.roots, &opts.readme)?;
    for f in &findings {
        println!("{}", f.render());
    }
    if let Some(json) = &opts.json {
        write_json(&findings, json).map_err(|e| format!("writing {}: {e}", json.display()))?;
    }
    eprintln!(
        "a2q-lint: {} finding(s) across {scanned} file(s) scanned",
        findings.len()
    );
    Ok(findings.len())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(0) => 0,
        Ok(_) => 1,
        Err(e) => {
            eprintln!("a2q-lint: {e}");
            2
        }
    };
    std::process::exit(code);
}

#[cfg(test)]
mod fixture_tests;
