//! Hand-rolled, line-aware Rust scrubber — no syn, no proc-macro, just
//! enough lexing to blank out comments and string/char literals while
//! preserving the line structure byte-for-byte, so the rule passes can
//! treat the remaining text as structural code and report real line
//! numbers.
//!
//! Captured side channels:
//! * comment text per line (`// SAFETY:` comments, `a2q-lint: allow(...)`
//!   markers),
//! * string-literal contents per line (the `A2Q_*` env-var registry
//!   cross-check),
//! * a per-line mask of `#[cfg(test)]` / `#[test]` regions (rules that
//!   only guard production paths skip those lines).

/// Scrubbed view of one source file.
pub struct Scrub {
    /// Source with comments and literal bodies replaced by spaces.  Same
    /// line structure as the input, so positions map 1:1.
    pub code: String,
    /// Comment text per 1-indexed line; block comments contribute one
    /// entry per line they span.
    pub comments: Vec<(usize, String)>,
    /// String-literal contents, keyed by the line of the opening quote.
    pub strings: Vec<(usize, String)>,
    /// 1-indexed: `true` for lines inside a `#[cfg(test)]`/`#[test]` item.
    test_lines: Vec<bool>,
}

impl Scrub {
    /// Whether a 1-indexed line sits inside a test-only region.
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test_lines.get(line).copied().unwrap_or(false)
    }

    /// Whether any comment on `line` contains `needle`.
    pub fn comment_on(&self, line: usize, needle: &str) -> bool {
        self.comments
            .iter()
            .any(|(l, t)| *l == line && t.to_ascii_uppercase().contains(needle))
    }

    /// Whether `line` carries any comment at all.
    pub fn has_comment(&self, line: usize) -> bool {
        self.comments.iter().any(|(l, _)| *l == line)
    }
}

fn blank(code: &mut String, k: usize) {
    for _ in 0..k {
        code.push(' ');
    }
}

/// Scrub `src` into code/comments/strings views (see [`Scrub`]).
pub fn scrub(src: &str) -> Scrub {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(src.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut strings: Vec<(usize, String)> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        match c {
            '\n' => {
                code.push('\n');
                line += 1;
                i += 1;
            }
            '/' if i + 1 < n && chars[i + 1] == '/' => {
                let start = i;
                while i < n && chars[i] != '\n' {
                    i += 1;
                }
                comments.push((line, chars[start..i].iter().collect()));
                blank(&mut code, i - start);
            }
            '/' if i + 1 < n && chars[i + 1] == '*' => {
                i = take_block_comment(&chars, i, &mut line, &mut code, &mut comments);
            }
            '"' => {
                i = take_string(&chars, i, &mut line, &mut code, &mut strings);
            }
            'r' if i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '#') => {
                i = take_raw_string(&chars, i, &mut line, &mut code, &mut strings);
            }
            'b' if i + 1 < n && chars[i + 1] == '"' => {
                code.push(' ');
                i = take_string(&chars, i + 1, &mut line, &mut code, &mut strings);
            }
            'b' if i + 1 < n && chars[i + 1] == '\'' => {
                code.push(' ');
                i = take_char_or_lifetime(&chars, i + 1, &mut code);
            }
            'b' if i + 2 < n
                && chars[i + 1] == 'r'
                && (chars[i + 2] == '"' || chars[i + 2] == '#') =>
            {
                code.push(' ');
                i = take_raw_string(&chars, i + 1, &mut line, &mut code, &mut strings);
            }
            '\'' => {
                i = take_char_or_lifetime(&chars, i, &mut code);
            }
            c if c == '_' || c.is_alphanumeric() => {
                // consume a whole identifier/number so prefix letters like
                // `r`/`b` inside words can't be mistaken for literal starts
                while i < n && (chars[i] == '_' || chars[i].is_alphanumeric()) {
                    code.push(chars[i]);
                    i += 1;
                }
            }
            _ => {
                code.push(c);
                i += 1;
            }
        }
    }
    let test_lines = test_line_mask(&code);
    Scrub {
        code,
        comments,
        strings,
        test_lines,
    }
}

/// `i` at the `/` of `/*`.  Handles nesting; captures text per line.
fn take_block_comment(
    chars: &[char],
    mut i: usize,
    line: &mut usize,
    code: &mut String,
    comments: &mut Vec<(usize, String)>,
) -> usize {
    let n = chars.len();
    let mut depth = 1usize;
    let mut buf = String::new();
    blank(code, 2);
    i += 2;
    while i < n && depth > 0 {
        if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
            depth += 1;
            buf.push_str("/*");
            blank(code, 2);
            i += 2;
        } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
            depth -= 1;
            if depth > 0 {
                buf.push_str("*/");
            }
            blank(code, 2);
            i += 2;
        } else if chars[i] == '\n' {
            comments.push((*line, std::mem::take(&mut buf)));
            code.push('\n');
            *line += 1;
            i += 1;
        } else {
            buf.push(chars[i]);
            code.push(' ');
            i += 1;
        }
    }
    comments.push((*line, buf));
    i
}

/// `i` at the opening `"`.
fn take_string(
    chars: &[char],
    mut i: usize,
    line: &mut usize,
    code: &mut String,
    strings: &mut Vec<(usize, String)>,
) -> usize {
    let n = chars.len();
    let open_line = *line;
    code.push('"');
    i += 1;
    let mut buf = String::new();
    while i < n {
        match chars[i] {
            '\\' if i + 1 < n => {
                if chars[i + 1] == '\n' {
                    // line-continuation escape
                    code.push(' ');
                    code.push('\n');
                    *line += 1;
                } else {
                    buf.push(chars[i + 1]);
                    blank(code, 2);
                }
                i += 2;
            }
            '"' => {
                code.push('"');
                i += 1;
                break;
            }
            '\n' => {
                buf.push('\n');
                code.push('\n');
                *line += 1;
                i += 1;
            }
            c => {
                buf.push(c);
                code.push(' ');
                i += 1;
            }
        }
    }
    strings.push((open_line, buf));
    i
}

/// `i` at the `r` of `r"…"` / `r#"…"#`.  `r#ident` (raw identifier) is
/// passed through as code.
fn take_raw_string(
    chars: &[char],
    i: usize,
    line: &mut usize,
    code: &mut String,
    strings: &mut Vec<(usize, String)>,
) -> usize {
    let n = chars.len();
    let open_line = *line;
    let mut j = i + 1;
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || chars[j] != '"' {
        // raw identifier (`r#name`) or a bare `r` — not a string literal
        for &c in &chars[i..j] {
            code.push(c);
        }
        return j;
    }
    blank(code, j + 1 - i);
    j += 1;
    let mut buf = String::new();
    while j < n {
        if chars[j] == '"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < n && chars[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                blank(code, 1 + hashes);
                j += 1 + hashes;
                break;
            }
        }
        if chars[j] == '\n' {
            buf.push('\n');
            code.push('\n');
            *line += 1;
        } else {
            buf.push(chars[j]);
            code.push(' ');
        }
        j += 1;
    }
    strings.push((open_line, buf));
    j
}

/// `i` at a `'`: a char literal (blanked) or a lifetime tick (kept).
fn take_char_or_lifetime(chars: &[char], i: usize, code: &mut String) -> usize {
    let n = chars.len();
    if i + 1 < n && chars[i + 1] == '\\' {
        // escaped char literal: scan (bounded) for the closing quote
        let mut j = i + 2;
        let mut steps = 0usize;
        while j < n && chars[j] != '\'' && steps < 12 {
            j += 1;
            steps += 1;
        }
        if j < n && chars[j] == '\'' {
            blank(code, j + 1 - i);
            return j + 1;
        }
        code.push('\'');
        return i + 1;
    }
    if i + 2 < n && chars[i + 2] == '\'' && chars[i + 1] != '\'' && chars[i + 1] != '\n' {
        // simple one-char literal like 'a' (multibyte chars are one slot)
        blank(code, 3);
        return i + 3;
    }
    // a lifetime tick (`'a`, `'_`, `'static`)
    code.push('\'');
    i + 1
}

/// Mark every line covered by a `#[cfg(test)]` or `#[test]` item.  The
/// attribute governs the next item: if a `;` ends it before any `{`
/// opens, only those lines are marked; otherwise the marked region runs
/// through the matching close brace.  Operates on scrubbed code, so
/// braces inside strings/comments can't unbalance the match.
fn test_line_mask(code: &str) -> Vec<bool> {
    let line_count = code.lines().count();
    let mut mask = vec![false; line_count + 2];
    let bytes = code.as_bytes();
    let line_of = |pos: usize| {
        let end = pos.min(bytes.len());
        1 + bytes[..end].iter().filter(|&&b| b == b'\n').count()
    };
    let mut spots: Vec<usize> = Vec::new();
    spots.extend(code.match_indices("#[cfg(test)]").map(|(p, _)| p));
    spots.extend(code.match_indices("#[test]").map(|(p, _)| p));
    for &p in &spots {
        let mut j = p;
        let mut open = None;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => break,
                _ => j += 1,
            }
        }
        let end = match open {
            None => j,
            Some(o) => {
                let mut depth = 0usize;
                let mut k = o;
                let mut end = bytes.len().saturating_sub(1);
                while k < bytes.len() {
                    match bytes[k] {
                        b'{' => depth += 1,
                        b'}' => {
                            depth -= 1;
                            if depth == 0 {
                                end = k;
                                break;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
                end
            }
        };
        for l in line_of(p)..=line_of(end) {
            if l < mask.len() {
                mask[l] = true;
            }
        }
    }
    mask
}

/// A structural token of the scrubbed code: identifier-ish words plus
/// single punctuation chars (whitespace dropped, line numbers retained).
pub struct Tok {
    pub line: usize,
    pub kind: TokKind,
}

pub enum TokKind {
    Word(String),
    Sym(char),
}

impl Tok {
    pub fn word(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Word(w) => Some(w.as_str()),
            TokKind::Sym(_) => None,
        }
    }

    pub fn sym(&self) -> Option<char> {
        match &self.kind {
            TokKind::Word(_) => None,
            TokKind::Sym(c) => Some(*c),
        }
    }
}

/// Tokenize scrubbed code (see [`Tok`]).
pub fn tokenize(code: &str) -> Vec<Tok> {
    let mut toks = Vec::new();
    let mut line = 1usize;
    let mut word = String::new();
    let mut word_line = 0usize;
    for c in code.chars() {
        if c == '_' || c.is_alphanumeric() {
            if word.is_empty() {
                word_line = line;
            }
            word.push(c);
            continue;
        }
        if !word.is_empty() {
            toks.push(Tok {
                line: word_line,
                kind: TokKind::Word(std::mem::take(&mut word)),
            });
        }
        if c == '\n' {
            line += 1;
        } else if !c.is_whitespace() {
            toks.push(Tok {
                line,
                kind: TokKind::Sym(c),
            });
        }
    }
    if !word.is_empty() {
        toks.push(Tok {
            line: word_line,
            kind: TokKind::Word(word),
        });
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked_but_captured() {
        let src = "let a = \"A2Q_X\"; // trailing note\nlet b = 'x';\n";
        let s = scrub(src);
        assert!(!s.code.contains("A2Q_X"));
        assert!(!s.code.contains("trailing"));
        assert_eq!(s.strings, vec![(1, "A2Q_X".to_string())]);
        assert!(s.comment_on(1, "TRAILING NOTE"));
        assert_eq!(s.code.lines().count(), src.lines().count());
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let s = scrub("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(s.code.contains("'a"), "lifetime ticks must survive");
        assert!(s.strings.is_empty());
    }

    #[test]
    fn raw_strings_and_escapes() {
        let s = scrub("let a = r#\"quote \" inside\"#; let b = \"esc\\\"aped\";\n");
        assert_eq!(s.strings.len(), 2);
        assert!(s.strings[0].1.contains("quote"));
        assert!(!s.code.contains("inside"));
    }

    #[test]
    fn nested_block_comments_end_where_they_should() {
        let s = scrub("/* outer /* inner */ still comment */ fn f() {}\n");
        assert!(s.code.contains("fn f"));
        assert!(!s.code.contains("inner"));
    }

    #[test]
    fn cfg_test_regions_are_masked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn prod2() {}\n";
        let s = scrub(src);
        assert!(!s.is_test_line(1));
        assert!(s.is_test_line(2));
        assert!(s.is_test_line(4));
        assert!(s.is_test_line(5));
        assert!(!s.is_test_line(6));
    }

    #[test]
    fn cfg_test_on_statement_marks_only_the_statement() {
        let src = "#[cfg(test)]\nuse foo::bar;\nfn prod() { let x = 1; }\n";
        let s = scrub(src);
        assert!(s.is_test_line(2));
        assert!(!s.is_test_line(3));
    }

    #[test]
    fn tokenizer_splits_words_and_symbols() {
        let toks = tokenize("a.unwrap()");
        let words: Vec<_> = toks.iter().filter_map(|t| t.word()).collect();
        assert_eq!(words, vec!["a", "unwrap"]);
        assert_eq!(toks[1].sym(), Some('.'));
    }
}
