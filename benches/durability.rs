//! Bench: the cost of durability on the dynamic-graph serving path.
//!
//! Three questions, answered in `BENCH_durability.json`:
//!
//! 1. What does WAL logging add to `apply_delta`?  The same toggling
//!    delta is applied with no persistence, with a WAL left to the OS
//!    (`fsync = never`), and with per-append fsync (`fsync = always`) —
//!    the gap between the first two is the logging overhead, the gap to
//!    the third is the price of surviving power loss.
//! 2. How fast is recovery, and how does it scale with the WAL tail?
//!    Restart time is measured against 16- and 64-record logs.
//! 3. What does a snapshot rotation cost on a resident session?
//!
//! `--quick` (CI) shrinks sample budgets to a smoke test.

use std::path::{Path, PathBuf};
use std::time::Instant;

use a2q::coordinator::{synthetic_node_session, NativeExecutor};
use a2q::graph::delta::GraphDelta;
use a2q::runtime::{FsyncPolicy, PersistConfig};
use a2q::util::bench::{BenchConfig, BenchRunner};
use a2q::util::threadpool::ParallelConfig;

const NODES: usize = 128;
const SEED: u64 = 11;

fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("a2q_bench_dur_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn executor() -> NativeExecutor {
    let (model, ds) = synthetic_node_session(NODES, SEED).expect("synthetic session");
    NativeExecutor::new(model, Some(&ds))
        .expect("executor")
        .with_parallelism(ParallelConfig::serial())
}

/// Alternating add/remove of one edge: every apply is a real CSR + plan
/// repair, and the resident graph never drifts from its starting size.
fn toggle(i: u64) -> GraphDelta {
    let edge = vec![(2u32, 100u32), (100, 2)];
    if i % 2 == 0 {
        GraphDelta {
            add_edges: edge,
            ..Default::default()
        }
    } else {
        GraphDelta {
            remove_edges: edge,
            ..Default::default()
        }
    }
}

/// Time `apply_delta` with the given persistence setup (`None` = volatile).
fn bench_apply(
    runner: &mut BenchRunner,
    name: &str,
    persist: Option<(PathBuf, FsyncPolicy)>,
) -> f64 {
    let exec = executor();
    let (exec, dir) = match persist {
        None => (exec, None),
        Some((dir, fsync)) => {
            let mut cfg = PersistConfig::new(&dir);
            cfg.snapshot_every = 0; // isolate append cost from rotation
            cfg.fsync = fsync;
            let (exec, _) = exec.with_persistence(cfg).expect("attach persistence");
            (exec, Some(dir))
        }
    };
    let mut i = 0u64;
    let median = runner
        .bench(name, || {
            exec.apply_delta(&toggle(i)).expect("apply delta");
            i += 1;
        })
        .median_ns();
    if let Some(dir) = dir {
        let _ = std::fs::remove_dir_all(&dir);
    }
    median
}

/// Build a state dir whose WAL holds exactly `records` toggling deltas.
fn seed_wal(dir: &Path, records: u64) {
    let mut cfg = PersistConfig::new(dir);
    cfg.snapshot_every = 0;
    cfg.fsync = FsyncPolicy::Never;
    let (exec, _) = executor().with_persistence(cfg).expect("attach persistence");
    for i in 0..records {
        exec.apply_delta(&toggle(i)).expect("seed delta");
    }
}

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut runner = BenchRunner::new(BenchConfig::from_args());

    // 1. WAL append overhead on the apply path
    let base = bench_apply(&mut runner, "durability/apply_delta/no_wal", None);
    let wal = bench_apply(
        &mut runner,
        "durability/apply_delta/wal_fsync_never",
        Some((state_dir("never"), FsyncPolicy::Never)),
    );
    bench_apply(
        &mut runner,
        "durability/apply_delta/wal_fsync_always",
        Some((state_dir("always"), FsyncPolicy::Always)),
    );
    runner.report_metric(
        "durability/wal_overhead_frac",
        (wal - base) / base.max(1.0),
        "apply_delta slowdown from WAL logging (fsync=never vs none)",
    );

    // 2. recovery time vs WAL length: replay is the dominant term, so the
    //    restart cost should scale roughly linearly in the tail
    let reps = if quick { 3 } else { 10 };
    for records in [16u64, 64] {
        let dir = state_dir(&format!("recov_{records}"));
        seed_wal(&dir, records);
        let mut times_ms = Vec::with_capacity(reps);
        for _ in 0..reps {
            let exec = executor();
            let cfg = PersistConfig::new(&dir);
            let start = Instant::now();
            let (_exec, report) = exec.with_persistence(cfg).expect("recover");
            times_ms.push(start.elapsed().as_secs_f64() * 1e3);
            assert_eq!(report.replayed_deltas, records as usize, "full replay");
        }
        times_ms.sort_by(|a, b| a.total_cmp(b));
        runner.report_metric(
            &format!("durability/recovery_ms/wal_{records}"),
            times_ms[times_ms.len() / 2],
            "ms to restore + replay (median)",
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    // 3. snapshot rotation cost: cadence 1 makes every apply pay a full
    //    capture + install, so the delta vs the no-wal baseline is the
    //    per-snapshot price
    {
        let dir = state_dir("rotate");
        let mut cfg = PersistConfig::new(&dir);
        cfg.snapshot_every = 1;
        cfg.fsync = FsyncPolicy::Never;
        let (exec, _) = executor().with_persistence(cfg).expect("attach persistence");
        let mut i = 0u64;
        runner.bench("durability/apply_delta/snapshot_every_1", || {
            exec.apply_delta(&toggle(i)).expect("apply delta");
            i += 1;
        });
        let _ = std::fs::remove_dir_all(&dir);
    }

    runner
        .write_json(Path::new("BENCH_durability.json"))
        .expect("write BENCH_durability.json");
}
