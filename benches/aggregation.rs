//! Bench: the aggregation phase (sparse Â·X) — the memory-bound half of
//! GNN inference (§Perf L3 target).
//!
//! Measures the serial edge-scatter reference against the row-parallel
//! destination-grouped gather (`AggregationPlan`) at 2 and 4 threads, and
//! records the headline serial-vs-4-threads speedup on a ≥100k-node
//! synthetic graph.  Results land in `BENCH_aggregation.json` so the perf
//! trajectory is machine-readable across PRs.
//!
//! `--quick` (used by CI) shrinks the graphs and the measurement budget to
//! a smoke test: kernel regressions break the build, not just the numbers.

use a2q::graph::generate::preferential_attachment;
use a2q::graph::norm::EdgeForm;
use a2q::util::bench::{black_box, BenchConfig, BenchRunner};
use a2q::util::rng::Rng;
use a2q::util::threadpool::ParallelConfig;

fn median_of(runner: &BenchRunner, name: &str) -> f64 {
    runner
        .results
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.median_ns())
        .unwrap_or(0.0)
}

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut rng = Rng::new(5);
    let mut runner = BenchRunner::new(BenchConfig::from_args());

    let shapes: &[(usize, usize)] = if quick {
        &[(512, 16)]
    } else {
        &[(2708, 64), (12000, 64), (12000, 128)]
    };
    for &(n, f) in shapes {
        let csr = preferential_attachment(&mut rng, n, 3);
        let ef = EdgeForm::from_csr(&csr);
        let plan = ef.plan();
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();
        runner.bench(&format!("aggregate/serial/n={n}/f={f}"), || {
            black_box(ef.aggregate_serial(&x, f, &ef.gcn_w));
        });
        for threads in [2usize, 4] {
            let cfg = ParallelConfig {
                threads,
                min_rows_per_task: 64,
                ..ParallelConfig::serial()
            };
            runner.bench(&format!("aggregate/parallel/n={n}/f={f}/t={threads}"), || {
                black_box(plan.aggregate_with(&x, f, &ef.src, &ef.gcn_w, &cfg));
            });
        }
        let edge_floats = (ef.num_edges() * f) as f64;
        runner.report_metric(
            &format!("aggregate/workload/n={n}/f={f}"),
            edge_floats / 1e6,
            "M edge-floats per pass",
        );
    }

    // Headline: serial edge-scatter vs the 4-thread gather on a large
    // power-law graph (the acceptance bar is >= 2x at 4 threads).
    let (n, f) = if quick { (2_000, 16) } else { (100_000, 64) };
    let csr = preferential_attachment(&mut rng, n, 3);
    let ef = EdgeForm::from_csr(&csr);
    let plan = ef.plan();
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();
    let serial_name = format!("aggregate/headline_serial/n={n}/f={f}");
    runner.bench(&serial_name, || {
        black_box(ef.aggregate_serial(&x, f, &ef.gcn_w));
    });
    let par_name = format!("aggregate/headline_parallel/n={n}/f={f}/t=4");
    let cfg4 = ParallelConfig {
        threads: 4,
        min_rows_per_task: 64,
        ..ParallelConfig::serial()
    };
    runner.bench(&par_name, || {
        black_box(plan.aggregate_with(&x, f, &ef.src, &ef.gcn_w, &cfg4));
    });
    let serial_ns = median_of(&runner, &serial_name);
    let par_ns = median_of(&runner, &par_name);
    runner.report_metric(
        &format!("aggregate/parallel_speedup/n={n}/f={f}/threads=4"),
        if par_ns > 0.0 { serial_ns / par_ns } else { 0.0 },
        "x vs serial scatter",
    );

    // serving-path batch prep: edge-form + plan construction
    let prep_n = if quick { 512 } else { 12_000 };
    let csr = preferential_attachment(&mut rng, prep_n, 3);
    runner.bench(&format!("aggregate/edge_form_build/n={prep_n}"), || {
        black_box(EdgeForm::from_csr(&csr));
    });
    let ef = EdgeForm::from_csr(&csr);
    runner.bench(&format!("aggregate/plan_build/n={prep_n}"), || {
        black_box(ef.plan());
    });

    // prepared sessions: a resident graph's plan is request-invariant, so
    // serving reuses one prepared plan instead of rebuilding it per
    // forward (the pre-prepared-session behavior).  Record the speedup so
    // the perf trajectory captures what plan caching banks.
    let f = 16usize;
    let x: Vec<f32> = (0..prep_n * f).map(|_| rng.normal() as f32).collect();
    let cfg4 = ParallelConfig {
        threads: 4,
        min_rows_per_task: 64,
        ..ParallelConfig::serial()
    };
    let plan = ef.plan();
    let reuse_name = format!("aggregate/prepared_plan_reuse/n={prep_n}/f={f}/t=4");
    runner.bench(&reuse_name, || {
        black_box(plan.aggregate_with(&x, f, &ef.src, &ef.gcn_w, &cfg4));
    });
    let rebuild_name = format!("aggregate/unprepared_plan_rebuild/n={prep_n}/f={f}/t=4");
    runner.bench(&rebuild_name, || {
        let p = ef.plan();
        black_box(p.aggregate_with(&x, f, &ef.src, &ef.gcn_w, &cfg4));
    });
    let reuse_ns = median_of(&runner, &reuse_name);
    let rebuild_ns = median_of(&runner, &rebuild_name);
    runner.report_metric(
        &format!("aggregate/prepared_vs_rebuild_speedup/n={prep_n}/f={f}"),
        if reuse_ns > 0.0 {
            rebuild_ns / reuse_ns
        } else {
            0.0
        },
        "x prepared plan reuse vs per-request rebuild",
    );

    runner
        .write_json(std::path::Path::new("BENCH_aggregation.json"))
        .expect("write BENCH_aggregation.json");
}
