//! Bench: the aggregation phase (sparse Â·X) — the memory-bound half of
//! GNN inference (§Perf L3 target).

use a2q::graph::generate::preferential_attachment;
use a2q::graph::norm::EdgeForm;
use a2q::util::bench::{black_box, BenchRunner};
use a2q::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(5);
    let mut runner = BenchRunner::default();

    for (n, f) in [(2708usize, 64usize), (12000, 64), (12000, 128)] {
        let csr = preferential_attachment(&mut rng, n, 3);
        let ef = EdgeForm::from_csr(&csr);
        let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();
        runner.bench(&format!("aggregate/gcn_norm/n={n}/f={f}"), || {
            black_box(ef.aggregate(&x, f, &ef.gcn_w));
        });
        let edges_per_sec = (ef.num_edges() * f) as f64;
        runner.report_metric(
            &format!("aggregate/workload/n={n}/f={f}"),
            edges_per_sec / 1e6,
            "M edge-floats per pass",
        );
    }

    // edge-form construction (serving-path batch prep)
    let csr = preferential_attachment(&mut rng, 12000, 3);
    runner.bench("aggregate/edge_form_build/n=12000", || {
        black_box(EdgeForm::from_csr(&csr));
    });
}
