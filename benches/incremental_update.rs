//! Bench: dynamic-graph serving — incremental [`GraphDelta`] application
//! vs full rebuild.
//!
//! Two levels, both recorded into `BENCH_incremental_update.json`:
//!
//! 1. **Structural** (headline, 100k-node power-law graph): incremental
//!    CSR row repair + GCN-weight splice + sort-free plan reconstruction
//!    vs `Csr::from_edges` + `EdgeForm::from_csr` + counting-sort plan
//!    from the full post-delta edge set.  The incremental path skips the
//!    O(E log E) edge sort and the per-edge `(d̃_s·d̃_d)^{-1/2}` work, so
//!    small deltas should win by a wide margin
//!    (`delta/incremental_vs_rebuild_speedup/...`).
//! 2. **Serving** (native executor): `apply_delta` (L-hop frontier logits
//!    patch against the resident activation cache) vs the epoch-bump full
//!    recompute a frozen-graph server would pay for the same mutation.
//!
//! `--quick` (CI) shrinks graphs and the measurement budget to a smoke
//! test: regressions in the delta path break the build, not just numbers.

use a2q::coordinator::{BatchExecutor, NativeExecutor};
use a2q::gnn::{GnnModel, LayerParams, QuantMethod};
use a2q::graph::delta::GraphDelta;
use a2q::graph::generate::preferential_attachment;
use a2q::graph::io::{Dataset, NodeData};
use a2q::graph::norm::{AggregationPlan, EdgeForm};
use a2q::graph::Csr;
use a2q::quant::mixed::NodeQuantParams;
use a2q::tensor::Matrix;
use a2q::util::bench::{black_box, BenchConfig, BenchRunner};
use a2q::util::json::Json;
use a2q::util::prop::Gen;
use a2q::util::rng::Rng;

fn median_of(runner: &BenchRunner, name: &str) -> f64 {
    runner
        .results
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.median_ns())
        .unwrap_or(0.0)
}

/// Random node-level A²Q GCN + its resident dataset (mirrors the
/// generator in rust/tests/forward_parity.rs).
fn synth_gcn(n: usize, in_dim: usize, hidden: usize, out_dim: usize) -> (GnnModel, Dataset) {
    let mut g = Gen::new(42);
    let mut rng = Rng::new(7);
    let csr = preferential_attachment(&mut rng, n, 3);
    let features = g.vec_normal(n * in_dim, 0.5);
    let layer = |g: &mut Gen, d_in: usize, d_out: usize, signed: bool| LayerParams {
        w: Some(Matrix::from_vec(d_in, d_out, g.vec_normal(d_in * d_out, 0.5)).unwrap()),
        b: g.vec_uniform(d_out, -0.1, 0.1),
        w_steps: g.vec_uniform(d_out, 0.02, 0.08),
        feat: Some(
            NodeQuantParams::new(
                g.vec_uniform(n, 0.02, 0.1),
                (0..n).map(|_| g.usize_range(2, 9) as u8).collect(),
                signed,
            )
            .unwrap(),
        ),
        ..Default::default()
    };
    let layers = vec![
        layer(&mut g, in_dim, hidden, true),
        layer(&mut g, hidden, out_dim, false),
    ];
    let model = GnnModel {
        name: "bench-delta-gcn".into(),
        arch: "gcn".into(),
        dataset: "synthetic".into(),
        method: QuantMethod::A2q,
        layers,
        head: None,
        dq_steps: Vec::new(),
        skip_input_quant: false,
        node_level: true,
        num_nodes: n,
        in_dim,
        out_dim,
        heads: 1,
        graph_capacity: 0,
        accuracy: 0.0,
        avg_bits: 4.0,
        expected_head: Vec::new(),
        manifest: Json::Null,
    };
    let ds = Dataset::Node(NodeData {
        name: "synthetic".into(),
        csr,
        num_features: in_dim,
        num_classes: out_dim,
        features,
        labels: vec![0; n],
        train_mask: vec![false; n],
        val_mask: vec![false; n],
        test_mask: vec![false; n],
    });
    (model, ds)
}

/// A small delta against an `n`-node graph: a few appended nodes, a batch
/// of new edges, a batch of removals of existing edges.
fn small_delta(csr: &Csr, add_nodes: usize, k: usize) -> GraphDelta {
    let n = csr.num_nodes();
    let n_new = n + add_nodes;
    let existing = csr.edge_list();
    let mut add_edges = Vec::with_capacity(k + add_nodes);
    for i in 0..k {
        add_edges.push((
            ((i * 2654435761) % n_new) as u32,
            ((i * 40503 + 17) % n_new) as u32,
        ));
    }
    for v in 0..add_nodes {
        // anchor each appended node to the resident graph
        add_edges.push(((n + v) as u32, ((v * 7919) % n) as u32));
    }
    let remove_edges: Vec<(u32, u32)> = (0..k)
        .map(|i| existing[(i * 104729) % existing.len()])
        .collect();
    GraphDelta {
        add_nodes,
        new_features: vec![],
        add_edges,
        remove_edges,
    }
}

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut runner = BenchRunner::new(BenchConfig::from_args());
    let mut rng = Rng::new(11);

    // -----------------------------------------------------------------
    // 1. structural: 100k-node graph, ~16-edge delta
    // -----------------------------------------------------------------
    let n = if quick { 2_000 } else { 100_000 };
    let csr = preferential_attachment(&mut rng, n, 3);
    let ef = EdgeForm::from_csr(&csr);
    let delta = small_delta(&csr, 4, 16);

    let inc_name = format!("delta/incremental_structural/n={n}");
    runner.bench(&inc_name, || {
        let applied = delta.apply_to_csr(&csr).expect("apply");
        let edges2 = ef.apply_delta(&csr, &applied);
        let plan2 = AggregationPlan::for_csr_edge_form(&applied.csr);
        black_box((edges2, plan2));
    });

    // the full-rebuild baseline gets the post-delta edge set for free
    // (assembled once, outside the timed region)
    let applied = delta.apply_to_csr(&csr).expect("apply");
    let full_edges = applied.csr.edge_list();
    let n_new = applied.csr.num_nodes();
    let reb_name = format!("delta/full_rebuild_structural/n={n}");
    runner.bench(&reb_name, || {
        let csr2 = Csr::from_edges(n_new, &full_edges).expect("rebuild");
        let ef2 = EdgeForm::from_csr(&csr2);
        let plan2 = ef2.plan();
        black_box((ef2, plan2));
    });
    let inc_ns = median_of(&runner, &inc_name);
    let reb_ns = median_of(&runner, &reb_name);
    runner.report_metric(
        &format!("delta/incremental_vs_rebuild_speedup/n={n}"),
        if inc_ns > 0.0 { reb_ns / inc_ns } else { 0.0 },
        "x incremental delta apply vs full structural rebuild",
    );
    runner.report_metric(
        &format!("delta/touched_rows/n={n}"),
        applied.num_changed_rows() as f64,
        "rows repaired by the delta",
    );

    // -----------------------------------------------------------------
    // 2. serving path: frontier patch vs epoch-bump full recompute
    // -----------------------------------------------------------------
    let (n2, in_dim, hidden, out_dim) = if quick {
        (512, 8, 16, 4)
    } else {
        (16_384, 32, 64, 8)
    };
    let (model, dataset) = synth_gcn(n2, in_dim, hidden, out_dim);
    let exec = NativeExecutor::new(model.clone(), Some(&dataset)).expect("prepare session");
    exec.run_node_batch(&[0]).expect("warm the activation cache");
    let Dataset::Node(nd) = &dataset else { unreachable!() };
    // toggle one edge batch on and off so each timed call applies exactly
    // one delta and the resident graph returns to base every two calls
    let toggle = small_delta(&nd.csr, 0, 8);
    let delta_add = GraphDelta {
        add_edges: toggle.add_edges.clone(),
        ..Default::default()
    };
    let delta_remove = GraphDelta {
        remove_edges: toggle.add_edges.clone(),
        ..Default::default()
    };
    // one untimed delta pair first: the session's first apply pays a
    // one-time full recording forward (activation-cache warm-up) that
    // would otherwise skew the --quick medians
    exec.apply_delta(&delta_add).expect("warm-up apply");
    exec.apply_delta(&delta_remove).expect("warm-up apply");
    let apply_name = format!("delta/executor_apply/n={n2}");
    let mut flip = false;
    runner.bench(&apply_name, || {
        let d = if flip { &delta_remove } else { &delta_add };
        flip = !flip;
        black_box(exec.apply_delta(d).expect("delta applies"));
    });

    let exec_full = NativeExecutor::new(model, Some(&dataset)).expect("prepare session");
    let full_name = format!("delta/executor_full_recompute/n={n2}");
    runner.bench(&full_name, || {
        // what a frozen-graph server pays per mutation: invalidate, then
        // recompute the whole graph on the next batch
        exec_full.bump_epoch();
        black_box(exec_full.run_node_batch(&[0]).expect("full recompute"));
    });
    let apply_ns = median_of(&runner, &apply_name);
    let full_ns = median_of(&runner, &full_name);
    runner.report_metric(
        &format!("delta/executor_patch_speedup/n={n2}"),
        if apply_ns > 0.0 { full_ns / apply_ns } else { 0.0 },
        "x frontier patch vs whole-graph recompute per delta",
    );

    runner
        .write_json(std::path::Path::new("BENCH_incremental_update.json"))
        .expect("write BENCH_incremental_update.json");
}
