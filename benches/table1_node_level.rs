//! Bench: regenerates the Table 1 speedup column (cycle-accurate simulation
//! of A²Q vs DQ-INT4 on the node-level datasets) and times the simulator.

use a2q::accel::{compare::speedup_vs_dq, AccelConfig, ModelWorkload, Simulator};
use a2q::harness::tables::representative_csr;
use a2q::harness::ResultsStore;
use a2q::quant::mixed::BitsFile;
use a2q::util::bench::{black_box, BenchRunner};

fn main() {
    let artifacts = a2q::artifacts_dir();
    let store = ResultsStore::load(&artifacts).unwrap_or_default();
    let mut runner = BenchRunner::default();
    let sim = Simulator::new(AccelConfig::default());

    let rows = [
        ("gcn", "synth-cora", 7usize),
        ("gat", "synth-cora", 7),
        ("gcn", "synth-citeseer", 6),
        ("gin", "synth-citeseer", 6),
        ("gat", "synth-pubmed", 3),
        ("gcn", "synth-arxiv", 23),
    ];
    for (arch, dataset, out_dim) in rows {
        let entries = store.find(dataset, arch, "a2q");
        let Some(entry) = entries.iter().find(|e| e.bits_path().exists()) else {
            eprintln!("{arch}-{dataset}: no bits.bin yet (run `make experiments`)");
            continue;
        };
        let Ok(bf) = BitsFile::load(&entry.bits_path()) else {
            continue;
        };
        let Ok(csr) = representative_csr(&artifacts, dataset) else {
            continue;
        };
        let n_maps = bf.maps.len();
        let matmuls: Vec<(usize, usize)> = bf
            .maps
            .iter()
            .enumerate()
            .map(|(i, (_b, dim))| (*dim, if i + 1 == n_maps { out_dim } else { 64 }))
            .collect();
        let workload = ModelWorkload::from_bits_file(&bf, matmuls, 0);
        let speedup = speedup_vs_dq(&sim, &csr, &workload);
        runner.report_metric(
            &format!("table1/{arch}-{dataset}/speedup_vs_dq"),
            speedup,
            "x (paper: 1.28x-2.00x)",
        );
        runner.bench(&format!("table1/{arch}-{dataset}/simulate"), || {
            black_box(speedup_vs_dq(&sim, &csr, &workload));
        });
    }
}
