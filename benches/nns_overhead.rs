//! Bench: Nearest-Neighbor-Strategy overhead (§5.4 / Table 6).
//!
//! The paper claims NNS adds ~0.95% latency.  Measures the rust runtime
//! lookup (binary search over sorted q_max) against the full quantize cost,
//! the binary-vs-linear-scan crossover over m, and the simulated cycle
//! overhead.

use a2q::accel::{simulate_model_cycles, AccelConfig, ModelWorkload, Simulator};
use a2q::graph::generate::preferential_attachment;
use a2q::quant::nns::NnsTable;
use a2q::quant::uniform::fake_quantize_row;
use a2q::util::bench::{black_box, BenchRunner};
use a2q::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let mut runner = BenchRunner::default();

    let f = 64usize;
    let n = 1024usize;
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();

    for m in [100usize, 400, 1000, 1500] {
        let steps: Vec<f32> = (0..m).map(|_| rng.uniform(0.005, 0.4) as f32).collect();
        let bits: Vec<u8> = (0..m).map(|_| rng.range(2, 9) as u8).collect();
        let table = NnsTable::new(&steps, &bits, true);
        runner.bench(&format!("nns/select_rows/m={m}"), || {
            black_box(table.select_rows(&x, f));
        });
        runner.bench(&format!("nns/linear_scan/m={m}"), || {
            for row in x.chunks_exact(f).take(64) {
                let fmax = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                black_box(table.select_linear(fmax));
            }
        });
    }

    // NNS select+quantize vs plain quantize — the end-to-end overhead
    let steps: Vec<f32> = (0..1000).map(|_| rng.uniform(0.005, 0.4) as f32).collect();
    let bits: Vec<u8> = (0..1000).map(|_| rng.range(2, 9) as u8).collect();
    let table = NnsTable::new(&steps, &bits, true);
    let mut buf = x.clone();
    runner.bench("nns/quantize_with_select", || {
        buf.copy_from_slice(&x);
        for row in buf.chunks_exact_mut(f) {
            let fmax = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let (_, s, b) = table.select(fmax);
            fake_quantize_row(row, s, b, true);
        }
        black_box(&buf);
    });
    runner.bench("nns/quantize_fixed_params", || {
        buf.copy_from_slice(&x);
        for row in buf.chunks_exact_mut(f) {
            fake_quantize_row(row, 0.05, 4, true);
        }
        black_box(&buf);
    });

    // simulated cycle overhead (the paper's 0.95% claim)
    let csr = preferential_attachment(&mut rng, 3000, 2);
    let dims = vec![(64usize, 64usize); 4];
    let wl_base = ModelWorkload {
        matmuls: dims.clone(),
        bits: vec![vec![4u8; 3000]; 4],
        agg_dims: vec![64; 4],
        nns_m: 0,
    };
    let mut wl_nns = wl_base.clone();
    wl_nns.nns_m = 1000;
    let sim = Simulator::new(AccelConfig::default());
    let base = simulate_model_cycles(&sim, &csr, &wl_base).total_cycles();
    let with = simulate_model_cycles(&sim, &csr, &wl_nns).total_cycles();
    runner.report_metric(
        "nns/simulated_cycle_overhead",
        100.0 * (with as f64 / base as f64 - 1.0),
        "% (paper: 0.95%)",
    );
}
