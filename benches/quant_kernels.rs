//! Bench: L3 quantization hot paths — per-node fake-quant, code extraction,
//! bit packing, packed-payload matmul, and the integer vs f32 matmul
//! kernels (serial vs parallel, §Perf).
//!
//! `--quick` (used by CI) shrinks shapes and measurement budget to a smoke
//! test so kernel regressions break the build.

use a2q::quant::mixed::NodeQuantParams;
use a2q::quant::pack::pack_rows;
use a2q::tensor::{matmul_i32_with, matmul_with, ops::rescale_outer, Matrix};
use a2q::util::bench::{black_box, BenchConfig, BenchRunner};
use a2q::util::rng::Rng;
use a2q::util::threadpool::ParallelConfig;

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut rng = Rng::new(11);
    let mut runner = BenchRunner::new(BenchConfig::from_args());

    // cora-shaped feature map: 2708 x 64 hidden (shrunk under --quick)
    let n = if quick { 256usize } else { 2708 };
    let f = if quick { 16usize } else { 64 };
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();
    let steps: Vec<f32> = (0..n).map(|_| rng.uniform(0.01, 0.2) as f32).collect();
    let bits: Vec<u8> = (0..n).map(|_| rng.range(1, 9) as u8).collect();
    let params = NodeQuantParams::new(steps.clone(), bits.clone(), true).unwrap();

    let mut buf = x.clone();
    runner.bench(&format!("quant/fake_quantize_{n}x{f}"), || {
        buf.copy_from_slice(&x);
        params.fake_quantize(&mut buf, f);
        black_box(&buf);
    });

    runner.bench(&format!("quant/codes_{n}x{f}"), || {
        black_box(params.quantize_codes(&x, f));
    });

    let (codes, _) = params.quantize_codes(&x, f);
    runner.bench(&format!("quant/pack_rows_{n}x{f}"), || {
        black_box(pack_rows(&codes, &steps, &bits, f, true));
    });

    // packed-payload integer matmul (the forward_int hot path)
    let packed = pack_rows(&codes, &steps, &bits, f, true);
    let w_cols = if quick { 8usize } else { 64 };
    let w_codes = Matrix::from_vec(
        f,
        w_cols,
        (0..f * w_cols).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    for threads in [1usize, 4] {
        let cfg = ParallelConfig {
            threads,
            min_rows_per_task: 64,
        };
        runner.bench(&format!("quant/packed_matmul_{n}x{f}x{w_cols}/t={threads}"), || {
            black_box(packed.matmul_i32(&w_codes, &cfg));
        });
    }

    // update-phase matmul shapes (cora layer 1: 2708x16 @ 16x7 is tiny;
    // use the arxiv-ish 2048x128 @ 128x64 shape for a meaningful number)
    let (m, k, nn) = if quick {
        (128usize, 32usize, 16usize)
    } else {
        (2048, 128, 64)
    };
    let a_f = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.normal() as f32).collect()).unwrap();
    let b_f = Matrix::from_vec(k, nn, (0..k * nn).map(|_| rng.normal() as f32).collect()).unwrap();
    let a_i = Matrix::from_vec(
        m,
        k,
        (0..m * k).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    let b_i = Matrix::from_vec(
        k,
        nn,
        (0..k * nn).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    let sx: Vec<f32> = (0..m).map(|_| 0.05f32).collect();
    let sw: Vec<f32> = (0..nn).map(|_| 0.05f32).collect();
    for threads in [1usize, 4] {
        let cfg = ParallelConfig {
            threads,
            min_rows_per_task: 64,
        };
        runner.bench(&format!("matmul/f32_{m}x{k}x{nn}/t={threads}"), || {
            black_box(matmul_with(&a_f, &b_f, &cfg));
        });
        runner.bench(&format!("matmul/i32_{m}x{k}x{nn}_with_rescale/t={threads}"), || {
            let acc = matmul_i32_with(&a_i, &b_i, &cfg);
            black_box(rescale_outer(&acc, &sx, &sw));
        });
    }

    runner
        .write_json(std::path::Path::new("BENCH_quant_kernels.json"))
        .expect("write BENCH_quant_kernels.json");
}
