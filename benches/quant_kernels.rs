//! Bench: L3 quantization hot paths — per-node fake-quant, code extraction,
//! bit packing, and the integer vs f32 matmul kernels (§Perf).

use a2q::quant::mixed::NodeQuantParams;
use a2q::quant::pack::pack_rows;
use a2q::tensor::{matmul, matmul_i32, ops::rescale_outer, Matrix};
use a2q::util::bench::{black_box, BenchRunner};
use a2q::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(11);
    let mut runner = BenchRunner::default();

    // cora-shaped feature map: 2708 x 64 hidden
    let n = 2708usize;
    let f = 64usize;
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();
    let steps: Vec<f32> = (0..n).map(|_| rng.uniform(0.01, 0.2) as f32).collect();
    let bits: Vec<u8> = (0..n).map(|_| rng.range(1, 9) as u8).collect();
    let params = NodeQuantParams::new(steps.clone(), bits.clone(), true).unwrap();

    let mut buf = x.clone();
    runner.bench("quant/fake_quantize_2708x64", || {
        buf.copy_from_slice(&x);
        params.fake_quantize(&mut buf, f);
        black_box(&buf);
    });

    runner.bench("quant/codes_2708x64", || {
        black_box(params.quantize_codes(&x, f));
    });

    let (codes, _) = params.quantize_codes(&x, f);
    runner.bench("quant/pack_rows_2708x64", || {
        black_box(pack_rows(&codes, &steps, &bits, f, true));
    });

    // update-phase matmul shapes (cora layer 1: 2708x16 @ 16x7 is tiny;
    // use the arxiv-ish 2048x128 @ 128x64 shape for a meaningful number)
    let (m, k, nn) = (2048usize, 128usize, 64usize);
    let a_f = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.normal() as f32).collect()).unwrap();
    let b_f = Matrix::from_vec(k, nn, (0..k * nn).map(|_| rng.normal() as f32).collect()).unwrap();
    runner.bench("matmul/f32_2048x128x64", || {
        black_box(matmul(&a_f, &b_f));
    });

    let a_i = Matrix::from_vec(
        m,
        k,
        (0..m * k).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    let b_i = Matrix::from_vec(
        k,
        nn,
        (0..k * nn).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    let sx: Vec<f32> = (0..m).map(|_| 0.05f32).collect();
    let sw: Vec<f32> = (0..nn).map(|_| 0.05f32).collect();
    runner.bench("matmul/i32_2048x128x64_with_rescale", || {
        let acc = matmul_i32(&a_i, &b_i);
        black_box(rescale_outer(&acc, &sx, &sw));
    });
}
