//! Bench: L3 quantization hot paths — per-node fake-quant, code extraction,
//! bit packing, packed-payload matmul (bucketed vs the scratch-unpack
//! reference kernel), and the integer vs f32 matmul kernels (serial vs
//! parallel, §Perf).
//!
//! The headline metrics:
//!
//! * `quant/bucketed_speedup` — bucketed per-bitwidth kernels vs the
//!   element-by-element scratch-unpack reference on a 100k-node
//!   mixed-bitwidth feature map (avg ≤ 4 bits), serial, **both pinned to
//!   the scalar ISA** so the number isolates the layout effect — the CPU
//!   analogue of the paper's §5.4 claim that learned low bitwidths should
//!   make inference *cheaper*, not just smaller.
//! * `quant/simd_speedup/<isa>` — the same bucketed kernel, scalar vs the
//!   active SIMD dispatch (`A2Q_SIMD`), correctness-asserted bitwise
//!   before timing.  Reports 1.0 under `/scalar` when no vector ISA is
//!   available (or dispatch is forced scalar).
//!
//! `--quick` (used by CI) shrinks shapes and measurement budget to a smoke
//! test so kernel regressions break the build.

use a2q::quant::mixed::NodeQuantParams;
use a2q::quant::pack::pack_rows;
use a2q::quant::uniform::quantize_value;
use a2q::tensor::simd::Isa;
use a2q::tensor::{matmul_i32_with, matmul_with, ops::rescale_outer, Matrix};
use a2q::util::bench::{black_box, BenchConfig, BenchRunner};
use a2q::util::rng::Rng;
use a2q::util::threadpool::ParallelConfig;

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut rng = Rng::new(11);
    let mut runner = BenchRunner::new(BenchConfig::from_args());

    // cora-shaped feature map: 2708 x 64 hidden (shrunk under --quick)
    let n = if quick { 256usize } else { 2708 };
    let f = if quick { 16usize } else { 64 };
    let x: Vec<f32> = (0..n * f).map(|_| rng.normal() as f32).collect();
    let steps: Vec<f32> = (0..n).map(|_| rng.uniform(0.01, 0.2) as f32).collect();
    let bits: Vec<u8> = (0..n).map(|_| rng.range(1, 9) as u8).collect();
    let params = NodeQuantParams::new(steps.clone(), bits.clone(), true).unwrap();

    let mut buf = x.clone();
    runner.bench(&format!("quant/fake_quantize_{n}x{f}"), || {
        buf.copy_from_slice(&x);
        params.fake_quantize(&mut buf, f);
        black_box(&buf);
    });

    runner.bench(&format!("quant/codes_{n}x{f}"), || {
        black_box(params.quantize_codes(&x, f));
    });

    let (codes, _) = params.quantize_codes(&x, f);
    runner.bench(&format!("quant/pack_rows_{n}x{f}"), || {
        black_box(pack_rows(&codes, &steps, &bits, f, true));
    });

    // packed-payload integer matmul (the forward_int hot path)
    let packed = pack_rows(&codes, &steps, &bits, f, true);
    let w_cols = if quick { 8usize } else { 64 };
    let w_codes = Matrix::from_vec(
        f,
        w_cols,
        (0..f * w_cols).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    for threads in [1usize, 4] {
        let cfg = ParallelConfig {
            threads,
            min_rows_per_task: 64,
            ..ParallelConfig::serial()
        };
        runner.bench(&format!("quant/packed_matmul_{n}x{f}x{w_cols}/t={threads}"), || {
            black_box(packed.matmul_i32(&w_codes, &cfg));
        });
    }

    // ISSUE 5 tentpole: bucketed vs scratch-unpack integer matmul on a
    // 100k-node mixed-bitwidth graph's feature map.  Bit distribution
    // averages ≤ 4 bits (the paper's compressed operating points); the
    // weight panel is GIN-hidden-map shaped (few output classes), where
    // decode cost is a real fraction of the kernel.
    let (gn, gf, gcols) = if quick {
        (4096usize, 16usize, 8usize)
    } else {
        (100_000, 64, 16)
    };
    const BIT_CHOICES: [u8; 8] = [1, 2, 2, 3, 4, 4, 6, 8]; // avg 3.75
    let gbits: Vec<u8> = (0..gn).map(|_| BIT_CHOICES[rng.below(8)]).collect();
    let gsteps: Vec<f32> = (0..gn).map(|_| rng.uniform(0.01, 0.2) as f32).collect();
    let avg_bits = gbits.iter().map(|&b| b as f64).sum::<f64>() / gn as f64;
    let mut gcodes = vec![0i32; gn * gf];
    for v in 0..gn {
        for j in 0..gf {
            gcodes[v * gf + j] =
                quantize_value(rng.normal() as f32, gsteps[v], gbits[v], true);
        }
    }
    let gpacked = pack_rows(&gcodes, &gsteps, &gbits, gf, true);
    let gw = Matrix::from_vec(
        gf,
        gcols,
        (0..gf * gcols).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    // bucketed_speedup is pinned scalar on BOTH sides so it stays a pure
    // layout number; the SIMD win is reported separately below
    let scalar = ParallelConfig::serial().with_simd(Isa::Scalar);
    let active = ParallelConfig::serial();
    // the kernels must agree bitwise before their timings mean anything —
    // this also re-checks scalar/SIMD parity on the bench shapes
    let want = gpacked.matmul_i32_scratch(&gw, &scalar);
    assert_eq!(
        gpacked.matmul_i32(&gw, &scalar).data,
        want.data,
        "bucketed kernel diverged from the scratch reference"
    );
    assert_eq!(
        gpacked.matmul_i32(&gw, &active).data,
        want.data,
        "SIMD ({}) bucketed kernel diverged from the scalar reference",
        active.simd.name()
    );
    let t_scratch = runner
        .bench(&format!("quant/packed_matmul_scratch_{gn}x{gf}x{gcols}/t=1"), || {
            black_box(gpacked.matmul_i32_scratch(&gw, &scalar));
        })
        .median_ns();
    let t_bucketed = runner
        .bench(&format!("quant/packed_matmul_bucketed_{gn}x{gf}x{gcols}/t=1"), || {
            black_box(gpacked.matmul_i32(&gw, &scalar));
        })
        .median_ns();
    runner.report_metric("quant/bucketed_speedup", t_scratch / t_bucketed, "x");
    runner.report_metric("quant/bucketed_avg_bits", avg_bits, "bits");

    // SIMD dispatch win on the same kernel: forced-scalar vs the active
    // ISA (A2Q_SIMD).  When dispatch resolves to scalar the two configs
    // are identical and the metric pins to exactly 1.0.
    let isa_name = active.simd.name();
    let t_simd = if active.simd == Isa::Scalar {
        t_bucketed
    } else {
        runner
            .bench(
                &format!("quant/packed_matmul_bucketed_{gn}x{gf}x{gcols}/isa={isa_name}"),
                || {
                    black_box(gpacked.matmul_i32(&gw, &active));
                },
            )
            .median_ns()
    };
    runner.report_metric(&format!("quant/simd_speedup/{isa_name}"), t_bucketed / t_simd, "x");

    // update-phase matmul shapes (cora layer 1: 2708x16 @ 16x7 is tiny;
    // use the arxiv-ish 2048x128 @ 128x64 shape for a meaningful number)
    let (m, k, nn) = if quick {
        (128usize, 32usize, 16usize)
    } else {
        (2048, 128, 64)
    };
    let a_f = Matrix::from_vec(m, k, (0..m * k).map(|_| rng.normal() as f32).collect()).unwrap();
    let b_f = Matrix::from_vec(k, nn, (0..k * nn).map(|_| rng.normal() as f32).collect()).unwrap();
    let a_i = Matrix::from_vec(
        m,
        k,
        (0..m * k).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    let b_i = Matrix::from_vec(
        k,
        nn,
        (0..k * nn).map(|_| rng.range(0, 15) as i32 - 7).collect(),
    )
    .unwrap();
    let sx: Vec<f32> = (0..m).map(|_| 0.05f32).collect();
    let sw: Vec<f32> = (0..nn).map(|_| 0.05f32).collect();
    for threads in [1usize, 4] {
        let cfg = ParallelConfig {
            threads,
            min_rows_per_task: 64,
            ..ParallelConfig::serial()
        };
        runner.bench(&format!("matmul/f32_{m}x{k}x{nn}/t={threads}"), || {
            black_box(matmul_with(&a_f, &b_f, &cfg));
        });
        runner.bench(&format!("matmul/i32_{m}x{k}x{nn}_with_rescale/t={threads}"), || {
            let acc = matmul_i32_with(&a_i, &b_i, &cfg);
            black_box(rescale_outer(&acc, &sx, &sw));
        });
    }

    runner
        .write_json(std::path::Path::new("BENCH_quant_kernels.json"))
        .expect("write BENCH_quant_kernels.json");
}
