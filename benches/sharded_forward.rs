//! Bench: shard-parallel forward over a partitioned resident graph.
//!
//! Records into `BENCH_sharded_forward.json`:
//!
//! * `sharded/forward_fp/s=S` — one full fp forward at S ∈ {1, 2, 4, 8}
//!   shards (thread budget = S, so S = 1 is the single-shard serial
//!   baseline the others are bitwise-identical to);
//! * `sharded/scaling_vs_s1/s=S` — speedup over S = 1;
//! * `sharded/halo_fraction/s=S` — fraction of edges whose source is a
//!   halo mirror (the cross-shard traffic a distributed deployment pays);
//! * `sharded/halo_nodes/s=S`, `sharded/partition_imbalance/s=S` — halo
//!   mirror count and max/mean load of the degree-aware partitioner;
//! * `sharded/build/s=S` — partition + local-view build time;
//! * `sharded/forward_int/s=S_max` — the integer path (per-shard packed
//!   slabs) at the widest fan-out.
//!
//! Default profile runs a 1M-node power-law graph (the ROADMAP's
//! production-scale shape); `--quick` (CI) shrinks it to a smoke test so
//! regressions in the shard path break the build, not just numbers.

use a2q::gnn::{
    forward_fp_sharded, forward_int_sharded, GnnModel, LayerParams, PreparedModel, QuantMethod,
};
use a2q::graph::generate::preferential_attachment;
use a2q::graph::norm::EdgeForm;
use a2q::graph::shard::ShardedGraph;
use a2q::quant::mixed::NodeQuantParams;
use a2q::tensor::Matrix;
use a2q::util::bench::{black_box, BenchConfig, BenchRunner};
use a2q::util::json::Json;
use a2q::util::prop::Gen;
use a2q::util::rng::Rng;
use a2q::util::threadpool::ParallelConfig;

fn median_of(runner: &BenchRunner, name: &str) -> f64 {
    runner
        .results
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.median_ns())
        .unwrap_or(0.0)
}

/// Random node-level A²Q GCN over `n` nodes (per-node learned bitwidths,
/// the layout whose low-bit rows keep shard payloads small).
fn synth_gcn(n: usize, in_dim: usize, hidden: usize, out_dim: usize) -> GnnModel {
    let mut g = Gen::new(42);
    let layer = |g: &mut Gen, d_in: usize, d_out: usize, signed: bool| LayerParams {
        w: Some(Matrix::from_vec(d_in, d_out, g.vec_normal(d_in * d_out, 0.5)).unwrap()),
        b: g.vec_uniform(d_out, -0.1, 0.1),
        w_steps: g.vec_uniform(d_out, 0.02, 0.08),
        feat: Some(
            NodeQuantParams::new(
                g.vec_uniform(n, 0.02, 0.1),
                (0..n).map(|_| g.usize_range(2, 9) as u8).collect(),
                signed,
            )
            .unwrap(),
        ),
        ..Default::default()
    };
    let layers = vec![
        layer(&mut g, in_dim, hidden, true),
        layer(&mut g, hidden, out_dim, false),
    ];
    GnnModel {
        name: "bench-sharded-gcn".into(),
        arch: "gcn".into(),
        dataset: "synthetic".into(),
        method: QuantMethod::A2q,
        layers,
        head: None,
        dq_steps: Vec::new(),
        skip_input_quant: false,
        node_level: true,
        num_nodes: n,
        in_dim,
        out_dim,
        heads: 1,
        graph_capacity: 0,
        accuracy: 0.0,
        avg_bits: 4.0,
        expected_head: Vec::new(),
        manifest: Json::Null,
    }
}

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut runner = BenchRunner::new(BenchConfig::from_args());
    let mut rng = Rng::new(11);

    let (n, in_dim, hidden, out_dim) = if quick {
        (10_000, 8, 16, 4)
    } else {
        (1_000_000, 8, 16, 4)
    };
    let csr = preferential_attachment(&mut rng, n, 3);
    let ef = EdgeForm::from_csr(&csr);
    let mut g = Gen::new(7);
    let features = g.vec_normal(n * in_dim, 0.5);
    let model = synth_gcn(n, in_dim, hidden, out_dim);
    let prep = PreparedModel::prepare(model).expect("prepare session");

    let shard_counts = [1usize, 2, 4, 8];
    let mut fp_medians = Vec::with_capacity(shard_counts.len());
    let mut last_graph: Option<ShardedGraph> = None;
    for &s in &shard_counts {
        // partition + local-view build cost
        let build_name = format!("sharded/build/s={s}");
        runner.bench(&build_name, || {
            black_box(ShardedGraph::build(&csr, &ef, s).expect("shard build"));
        });
        let sg = ShardedGraph::build(&csr, &ef, s).expect("shard build");
        let stats = sg.halo_stats();
        runner.report_metric(
            &format!("sharded/halo_fraction/s={s}"),
            stats.halo_fraction(),
            "fraction of edges crossing shards",
        );
        runner.report_metric(
            &format!("sharded/halo_nodes/s={s}"),
            stats.halo_nodes as f64,
            "total halo mirror nodes",
        );
        let max_load = *sg.partition.load.iter().max().unwrap_or(&0) as f64;
        let mean_load = sg.partition.load.iter().sum::<u64>() as f64
            / sg.partition.load.len().max(1) as f64;
        runner.report_metric(
            &format!("sharded/partition_imbalance/s={s}"),
            if mean_load > 0.0 { max_load / mean_load } else { 0.0 },
            "max/mean shard load (degree-weighted)",
        );

        let cfg = ParallelConfig {
            threads: s,
            min_rows_per_task: 1,
            ..ParallelConfig::serial()
        };
        let fp_name = format!("sharded/forward_fp/s={s}");
        runner.bench(&fp_name, || {
            black_box(forward_fp_sharded(&prep, &features, &sg, &cfg));
        });
        fp_medians.push(median_of(&runner, &fp_name));
        last_graph = Some(sg);
    }
    let base = fp_medians[0];
    for (&s, &med) in shard_counts.iter().zip(&fp_medians) {
        runner.report_metric(
            &format!("sharded/scaling_vs_s1/s={s}"),
            if med > 0.0 { base / med } else { 0.0 },
            "x speedup of S shards over the single-shard forward",
        );
    }

    // the integer path (per-shard packed slabs) at the widest fan-out
    let s_max = *shard_counts.last().unwrap();
    let sg = last_graph.expect("built above");
    let cfg = ParallelConfig {
        threads: s_max,
        min_rows_per_task: 1,
        ..ParallelConfig::serial()
    };
    runner.bench(&format!("sharded/forward_int/s={s_max}"), || {
        black_box(forward_int_sharded(&prep, &features, &sg, &cfg));
    });

    runner
        .write_json(std::path::Path::new("BENCH_sharded_forward.json"))
        .expect("write BENCH_sharded_forward.json");
}
