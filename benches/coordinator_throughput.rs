//! Bench: coordinator pipeline throughput/latency with a mock executor —
//! isolates router + batcher + worker overhead from model compute
//! (§Perf L3: "L3 should not be the bottleneck") — plus the headline
//! prepared-session metric: node-batch serving over a [`NativeExecutor`]
//! (prepared weights/NNS tables, cached AggregationPlan, versioned
//! full-graph logits cache) vs the pre-prepared-session path that re-ran
//! model prep + a full-graph forward per batch.  Results land in
//! `BENCH_coordinator_throughput.json`; `--quick` (CI) shrinks shapes and
//! measurement budget to a smoke test.

use std::sync::Arc;
use std::time::{Duration, Instant};

use a2q::coordinator::request::Payload;
use a2q::coordinator::{BatchExecutor, BatcherConfig, Coordinator, MockExecutor, NativeExecutor};
use a2q::gnn::{forward_fp_with, GnnModel, GraphInput, LayerParams, QuantMethod};
use a2q::graph::generate::preferential_attachment;
use a2q::graph::io::{Dataset, NodeData};
use a2q::graph::norm::EdgeForm;
use a2q::quant::mixed::NodeQuantParams;
use a2q::tensor::Matrix;
use a2q::util::bench::{black_box, BenchConfig, BenchRunner};
use a2q::util::json::Json;
use a2q::util::prop::Gen;
use a2q::util::rng::Rng;

/// Random node-level A²Q GCN + its resident dataset (mirrors the
/// generator in rust/tests/forward_parity.rs).
fn synth_gcn(n: usize, in_dim: usize, hidden: usize, out_dim: usize) -> (GnnModel, Dataset) {
    let mut g = Gen::new(42);
    let mut rng = Rng::new(7);
    let csr = preferential_attachment(&mut rng, n, 3);
    let features = g.vec_normal(n * in_dim, 0.5);
    let layer = |g: &mut Gen, d_in: usize, d_out: usize, signed: bool| LayerParams {
        w: Some(Matrix::from_vec(d_in, d_out, g.vec_normal(d_in * d_out, 0.5)).unwrap()),
        b: g.vec_uniform(d_out, -0.1, 0.1),
        w_steps: g.vec_uniform(d_out, 0.02, 0.08),
        feat: Some(
            NodeQuantParams::new(
                g.vec_uniform(n, 0.02, 0.1),
                (0..n).map(|_| g.usize_range(2, 9) as u8).collect(),
                signed,
            )
            .unwrap(),
        ),
        ..Default::default()
    };
    let layers = vec![
        layer(&mut g, in_dim, hidden, true),
        layer(&mut g, hidden, out_dim, false),
    ];
    let model = GnnModel {
        name: "bench-gcn".into(),
        arch: "gcn".into(),
        dataset: "synthetic".into(),
        method: QuantMethod::A2q,
        layers,
        head: None,
        dq_steps: Vec::new(),
        skip_input_quant: false,
        node_level: true,
        num_nodes: n,
        in_dim,
        out_dim,
        heads: 1,
        graph_capacity: 0,
        accuracy: 0.0,
        avg_bits: 4.0,
        expected_head: Vec::new(),
        manifest: Json::Null,
    };
    let ds = Dataset::Node(NodeData {
        name: "synthetic".into(),
        csr,
        num_features: in_dim,
        num_classes: out_dim,
        features,
        labels: vec![0; n],
        train_mask: vec![false; n],
        val_mask: vec![false; n],
        test_mask: vec![false; n],
    });
    (model, ds)
}

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut runner = BenchRunner::new(BenchConfig::from_args());

    for (label, exec_latency) in [("zero-cost-exec", 0u64), ("200us-exec", 200)] {
        let mut coord = Coordinator::new();
        coord.add_model(
            "m",
            Arc::new(MockExecutor {
                out_dim: 8,
                latency: Duration::from_micros(exec_latency),
            }),
            BatcherConfig {
                node_budget: 4096,
                graph_slots: 64,
                max_wait: Duration::from_micros(200),
                queue_cap: 4096,
                ..BatcherConfig::default()
            },
        );
        let coord = Arc::new(coord);

        // closed-loop single client: per-request pipeline latency
        runner.bench(&format!("coordinator/{label}/closed_loop"), || {
            let _ = coord
                .submit_blocking("m", Payload::ClassifyNodes(vec![1, 2, 3]))
                .unwrap();
        });

        // open-loop burst from 4 clients: throughput under batching
        let c2 = Arc::clone(&coord);
        runner.bench(&format!("coordinator/{label}/burst_4x32"), || {
            let mut joins = Vec::new();
            for t in 0..4 {
                let c = Arc::clone(&c2);
                joins.push(std::thread::spawn(move || {
                    let mut rxs = Vec::new();
                    for i in 0..32u32 {
                        rxs.push(
                            c.submit("m", Payload::ClassifyNodes(vec![t * 32 + i]))
                                .unwrap(),
                        );
                    }
                    for rx in rxs {
                        let _ = rx.recv().unwrap();
                    }
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        let snap = coord.metrics();
        runner.report_metric(
            &format!("coordinator/{label}/mean_batch_size"),
            snap.mean_batch_size,
            "requests per execution",
        );
    }

    // -----------------------------------------------------------------
    // Headline: prepared sessions vs per-request model prep over a real
    // native model.  The prepared executor pays one full-graph forward,
    // then serves every later node batch as a slice-copy off the cached
    // logits; the unprepared baseline is today's per-call shim (session
    // prep — model clone + weight quantization — plus plan build and the
    // full-graph forward, every batch), which brackets the pre-PR cost:
    // same per-request weight re-quantization, plan rebuild, and full
    // forward, with the clone standing in for the old ad-hoc per-layer
    // copies.  The dominant term either way is the per-batch full-graph
    // forward that the logits cache eliminates.
    // -----------------------------------------------------------------
    let (n, in_dim, hidden, out_dim) = if quick {
        (512, 8, 16, 4)
    } else {
        (4096, 32, 64, 8)
    };
    let (model, dataset) = synth_gcn(n, in_dim, hidden, out_dim);
    let exec = NativeExecutor::new(model.clone(), Some(&dataset))
        .expect("prepare native serving session");
    let cfg = exec.parallelism();
    let ids: Vec<u32> = (0..32u32).collect();
    let batches = 100usize;

    let t0 = Instant::now();
    for _ in 0..batches {
        black_box(exec.run_node_batch(&ids).expect("prepared node batch"));
    }
    let prepared_s = t0.elapsed().as_secs_f64();

    let Dataset::Node(nd) = &dataset else { unreachable!() };
    let ef = EdgeForm::from_csr(&nd.csr);
    let t0 = Instant::now();
    for _ in 0..batches {
        // unprepared serving: per-call session prep + full-graph forward
        // per batch, then the same row extraction
        let input = GraphInput::node_level(&nd.features, model.in_dim, &ef);
        let logits = forward_fp_with(&model, &input, &cfg);
        let out: Vec<Vec<f32>> = ids
            .iter()
            .map(|&v| logits.row(v as usize).to_vec())
            .collect();
        black_box(out);
    }
    let unprepared_s = t0.elapsed().as_secs_f64();

    runner.report_metric(
        &format!("coordinator/prepared_node_batch_us/n={n}"),
        prepared_s * 1e6 / batches as f64,
        "us per 32-node batch (prepared session)",
    );
    runner.report_metric(
        &format!("coordinator/unprepared_node_batch_us/n={n}"),
        unprepared_s * 1e6 / batches as f64,
        "us per 32-node batch (per-request prep)",
    );
    // acceptance bar: >= 2x at 100 batches (the cache makes it far larger)
    runner.report_metric(
        &format!("coordinator/prepared_speedup/n={n}/batches={batches}"),
        if prepared_s > 0.0 {
            unprepared_s / prepared_s
        } else {
            0.0
        },
        "x vs per-request model prep",
    );

    runner
        .write_json(std::path::Path::new("BENCH_coordinator_throughput.json"))
        .expect("write BENCH_coordinator_throughput.json");
}
