//! Bench: coordinator pipeline throughput/latency with a mock executor —
//! isolates router + batcher + worker overhead from model compute
//! (§Perf L3: "L3 should not be the bottleneck").

use std::sync::Arc;
use std::time::Duration;

use a2q::coordinator::request::Payload;
use a2q::coordinator::{BatcherConfig, Coordinator, MockExecutor};
use a2q::util::bench::BenchRunner;

fn main() {
    let mut runner = BenchRunner::default();

    for (label, exec_latency) in [("zero-cost-exec", 0u64), ("200us-exec", 200)] {
        let mut coord = Coordinator::new();
        coord.add_model(
            "m",
            Arc::new(MockExecutor {
                out_dim: 8,
                latency: Duration::from_micros(exec_latency),
            }),
            BatcherConfig {
                node_budget: 4096,
                graph_slots: 64,
                max_wait: Duration::from_micros(200),
                queue_cap: 4096,
            },
        );
        let coord = Arc::new(coord);

        // closed-loop single client: per-request pipeline latency
        runner.bench(&format!("coordinator/{label}/closed_loop"), || {
            let _ = coord
                .submit_blocking("m", Payload::ClassifyNodes(vec![1, 2, 3]))
                .unwrap();
        });

        // open-loop burst from 4 clients: throughput under batching
        let c2 = Arc::clone(&coord);
        runner.bench(&format!("coordinator/{label}/burst_4x32"), || {
            let mut joins = Vec::new();
            for t in 0..4 {
                let c = Arc::clone(&c2);
                joins.push(std::thread::spawn(move || {
                    let mut rxs = Vec::new();
                    for i in 0..32u32 {
                        rxs.push(
                            c.submit("m", Payload::ClassifyNodes(vec![t * 32 + i]))
                                .unwrap(),
                        );
                    }
                    for rx in rxs {
                        let _ = rx.recv().unwrap();
                    }
                }));
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        let snap = coord.metrics();
        runner.report_metric(
            &format!("coordinator/{label}/mean_batch_size"),
            snap.mean_batch_size,
            "requests per execution",
        );
    }
}
