//! Bench: Table 2 speedup column (graph-level tasks, NNS) + batch-packing
//! throughput of the serving path.

use a2q::accel::{compare::speedup_vs_dq, AccelConfig, ModelWorkload, Simulator};
use a2q::graph::batch::GraphBatch;
use a2q::graph::io::{load_named, Dataset};
use a2q::harness::tables::representative_csr;
use a2q::harness::ResultsStore;
use a2q::quant::mixed::BitsFile;
use a2q::util::bench::{black_box, BenchRunner};

fn main() {
    let artifacts = a2q::artifacts_dir();
    let store = ResultsStore::load(&artifacts).unwrap_or_default();
    let mut runner = BenchRunner::default();
    let sim = Simulator::new(AccelConfig::default());

    let rows = [
        ("gcn", "synth-mnist", 10usize),
        ("gin", "synth-mnist", 10),
        ("gcn", "synth-cifar10", 10),
        ("gat", "synth-cifar10", 10),
        ("gcn", "synth-zinc", 1),
        ("gin", "synth-reddit-b", 2),
    ];
    for (arch, dataset, out_dim) in rows {
        let entries = store.find(dataset, arch, "a2q");
        let Some(entry) = entries.iter().find(|e| e.bits_path().exists()) else {
            eprintln!("{arch}-{dataset}: no bits.bin yet (run `make experiments`)");
            continue;
        };
        let (Ok(bf), Ok(csr)) = (
            BitsFile::load(&entry.bits_path()),
            representative_csr(&artifacts, dataset),
        ) else {
            continue;
        };
        let n_maps = bf.maps.len();
        let matmuls: Vec<(usize, usize)> = bf
            .maps
            .iter()
            .enumerate()
            .map(|(i, (_b, dim))| (*dim, if i + 1 == n_maps { out_dim } else { 64 }))
            .collect();
        let workload = ModelWorkload::from_bits_file(&bf, matmuls, 1000);
        let speedup = speedup_vs_dq(&sim, &csr, &workload);
        runner.report_metric(
            &format!("table2/{arch}-{dataset}/speedup_vs_dq"),
            speedup,
            "x (paper: 1.07x-1.25x)",
        );
    }

    // serving-path cost: block-diagonal packing of a 16-graph batch
    if let Ok(Dataset::Graphs(gs)) = load_named(&artifacts, "synth-zinc") {
        let refs: Vec<&a2q::graph::io::SmallGraph> = gs.graphs.iter().take(16).collect();
        let total_n: usize = refs.iter().map(|g| g.num_nodes()).sum();
        runner.bench("table2/zinc/pack_batch_16", || {
            black_box(
                GraphBatch::pack(&refs, gs.num_features, total_n + 64, 8192, 16).unwrap(),
            );
        });
    }
}
