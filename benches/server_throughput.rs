//! Bench: the TCP serving front end under increasing overload.
//!
//! Measures sustained wire-protocol throughput and tail latency, then
//! pushes the offered load to ~2x and ~10x the server's capacity and
//! verifies the overload contract quantitatively: every request is
//! answered on-protocol (`on_protocol_reply_frac == 1.0`, `io_errors ==
//! 0`), excess load surfaces as explicit `rejected` frames, and a graceful
//! drain loses zero admitted replies.  Results land in
//! `BENCH_server_throughput.json`; `--quick` (CI) shrinks connection
//! counts and request budgets to a smoke test.

use std::sync::Arc;
use std::time::Duration;

use a2q::coordinator::net::{run_load, LoadConfig, NetConfig, NetServer, RetryPolicy, WireResponse};
use a2q::coordinator::{AdaptiveWait, BatcherConfig, Coordinator, MockExecutor, SuperviseConfig};
use a2q::util::bench::{BenchConfig, BenchRunner};
use a2q::util::fault;

fn start_server() -> (NetServer, AdaptiveWait) {
    let wait = AdaptiveWait::new(
        Duration::from_micros(500),
        Duration::from_micros(100),
        Duration::from_millis(2),
    );
    let mut coord = Coordinator::new();
    coord.add_model(
        "mock",
        Arc::new(MockExecutor {
            out_dim: 8,
            // per-batch model cost: makes capacity finite so the overload
            // scenarios actually overload
            latency: Duration::from_micros(500),
        }),
        BatcherConfig {
            node_budget: 4096,
            graph_slots: 64,
            max_wait: Duration::from_micros(500),
            // small admission queue: at 10x offered load the router must
            // shed, and every shed request must become a rejection frame
            queue_cap: 16,
            adaptive_wait: Some(wait.clone()),
        },
    );
    let cfg = NetConfig {
        target_p99_us: 5_000,
        tuner_interval: Duration::from_millis(50),
        ..NetConfig::default()
    };
    let server = NetServer::start(coord, cfg).expect("start net server");
    (server, wait)
}

fn main() {
    let quick = BenchConfig::quick_requested();
    let mut runner = BenchRunner::new(BenchConfig::from_args());

    let (server, wait) = start_server();
    let addr = format!("{}", server.local_addr());

    // single-connection wire roundtrip: protocol + batching + mock exec
    let mut client =
        a2q::coordinator::net::NetClient::connect(&addr).expect("connect bench client");
    runner.bench("server/wire_roundtrip", || {
        match client.classify("mock", vec![1, 2, 3]).expect("classify") {
            WireResponse::Ok { .. } => {}
            other => panic!("roundtrip got {other:?}"),
        }
    });

    // offered-load ladder: ~capacity, ~2x, ~10x (closed-loop connections)
    let (reqs, ladder) = if quick {
        (20, [("sustained", 2usize), ("overload_2x", 4), ("overload_10x", 10)])
    } else {
        (200, [("sustained", 4usize), ("overload_2x", 8), ("overload_10x", 40)])
    };
    for (scenario, conns) in ladder {
        let report = run_load(
            &addr,
            &LoadConfig {
                conns,
                requests_per_conn: reqs,
                model: "mock".to_string(),
                nodes_per_req: 2,
                node_space: 64,
                pace: Duration::ZERO,
                retry: RetryPolicy::none(),
            },
        )
        .expect("load run");
        let sent = report.sent.max(1) as f64;
        let answered = (report.ok + report.rejected + report.errors) as f64;
        runner.report_metric(
            &format!("server/{scenario}/ok_rps"),
            report.achieved_ok_rps,
            "successful replies per second",
        );
        runner.report_metric(
            &format!("server/{scenario}/p99_ms"),
            report.p99_ms,
            "ms (p99 over ok replies)",
        );
        runner.report_metric(
            &format!("server/{scenario}/rejected_frac"),
            report.rejected as f64 / sent,
            "fraction rejected on-protocol",
        );
        // the contract metric: 1.0 means every request got an explicit
        // reply frame; anything less means a hang or dropped connection
        runner.report_metric(
            &format!("server/{scenario}/on_protocol_reply_frac"),
            answered / sent,
            "fraction answered on-protocol (must be 1.0)",
        );
        runner.report_metric(
            &format!("server/{scenario}/io_errors"),
            report.io_errors as f64,
            "transport failures (must be 0)",
        );
    }

    runner.report_metric(
        "server/adaptive/final_wait_us",
        wait.current().as_micros() as f64,
        "flush deadline after the tuner reacted to load",
    );

    // graceful drain under load: no admitted request may lose its reply
    let drain_load = std::thread::spawn({
        let addr = addr.clone();
        let conns = if quick { 2 } else { 4 };
        move || {
            run_load(
                &addr,
                &LoadConfig {
                    conns,
                    requests_per_conn: 1000,
                    model: "mock".to_string(),
                    nodes_per_req: 2,
                    node_space: 64,
                    pace: Duration::ZERO,
                    retry: RetryPolicy::none(),
                },
            )
        }
    });
    std::thread::sleep(Duration::from_millis(30));
    let report = server.drain();
    runner.report_metric(
        "server/drain/lost_replies",
        report.unreplied_in_flight as f64,
        "admitted requests never answered (must be 0)",
    );
    runner.report_metric(
        "server/drain/took_ms",
        report.took.as_secs_f64() * 1e3,
        "ms to quiesce",
    );
    // the load thread sees EOFs once the server is gone; that's expected —
    // the contract only covers requests the server admitted
    let _ = drain_load.join();

    // faulted rung: a fresh supervised server with seeded executor faults.
    // Retrying clients ride through breaker-open windows; `recovery_p99`
    // is the retry-inclusive tail, `breaker_open_frac` the share of
    // requests the breaker shed fast instead of burning a failing batch.
    let faulted = {
        let mut coord = Coordinator::new();
        coord.set_supervision(SuperviseConfig {
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_millis(50),
            ..SuperviseConfig::default()
        });
        coord.add_model(
            "mock",
            Arc::new(MockExecutor {
                out_dim: 8,
                latency: Duration::from_micros(500),
            }),
            BatcherConfig {
                node_budget: 4096,
                graph_slots: 64,
                max_wait: Duration::from_micros(500),
                queue_cap: 16,
                adaptive_wait: None,
            },
        );
        NetServer::start(coord, NetConfig::default()).expect("start faulted server")
    };
    let faulted_addr = format!("{}", faulted.local_addr());
    fault::arm(0x5eed_cafe, "executor.classify=err@0.3").expect("arm fault schedule");
    let report = run_load(
        &faulted_addr,
        &LoadConfig {
            conns: if quick { 2 } else { 4 },
            requests_per_conn: if quick { 20 } else { 200 },
            model: "mock".to_string(),
            nodes_per_req: 2,
            node_space: 64,
            pace: Duration::ZERO,
            retry: RetryPolicy {
                max_retries: 5,
                deadline: Some(Duration::from_secs(2)),
                ..RetryPolicy::default()
            },
        },
    )
    .expect("faulted load run");
    let breaker_rejected = faulted
        .metrics_json()
        .req_f64("breaker_rejected")
        .expect("breaker_rejected metric");
    fault::disarm();
    runner.report_metric(
        "server/faulted/recovery_p99",
        report.p99_ms,
        "ms (p99 over ok replies, retries included, under seeded faults)",
    );
    runner.report_metric(
        "server/faulted/breaker_open_frac",
        breaker_rejected / report.sent.max(1) as f64,
        "breaker fast-rejections per offered request",
    );
    runner.report_metric(
        "server/faulted/retries",
        report.retries as f64,
        "extra attempts clients needed under faults",
    );
    runner.report_metric(
        "server/faulted/io_errors",
        report.io_errors as f64,
        "transport failures (must be 0: faults surface on-protocol)",
    );
    faulted.drain();

    runner
        .write_json(std::path::Path::new("BENCH_server_throughput.json"))
        .expect("write BENCH_server_throughput.json");
}
