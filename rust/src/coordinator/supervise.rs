//! Self-healing supervision: runner restart policy + per-model circuit
//! breakers.
//!
//! The coordinator wraps every runner loop in a panic boundary
//! (`server.rs::supervised_runner`): a panic that escapes the batch
//! boundary — a poisoned executor, an injected `runner.poll` fault — no
//! longer leaves the model dead behind a queue that keeps admitting.
//! The supervisor respawns the loop with exponential backoff, bounded
//! by a restart budget ([`SuperviseConfig::restart_budget`],
//! `A2Q_RESTART_BUDGET`); the queue receiver survives the respawn, so
//! requests admitted before the crash are still served by the next
//! incarnation (mpsc receivers do not poison).
//!
//! Orthogonally, each model gets a [`CircuitBreaker`] fed one
//! observation per executed batch.  After
//! [`SuperviseConfig::breaker_threshold`] *consecutive* batch failures
//! the breaker opens: submissions are rejected fast and on-protocol
//! with a `retry_after_ms` covering the cooldown, instead of queueing
//! behind an executor that is currently failing everything.  After the
//! cooldown ([`SuperviseConfig::breaker_cooldown`]) it admits exactly
//! one probe (half-open); the probe's batch result closes the breaker
//! or re-opens it for another cooldown.  State transitions and fast
//! rejections are surfaced in [`Metrics`] (`breaker_opens`,
//! `breaker_rejected`, per-model `breaker_states`) and therefore in the
//! wire `metrics` reply.

use std::sync::Arc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::metrics::Metrics;

/// Restart + circuit-breaker policy (per coordinator, applied to every
/// model registered after it is set).
#[derive(Debug, Clone)]
pub struct SuperviseConfig {
    /// Respawns allowed per runner over its lifetime; on exhaustion the
    /// model stops (later submits are rejected as `stopped`).  0 means
    /// "never respawn" — a runner panic then behaves like pre-PR-10.
    pub restart_budget: u32,
    /// First respawn backoff; doubles per consecutive respawn.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Consecutive failed batches that open the breaker; 0 disables the
    /// breaker entirely.
    pub breaker_threshold: u32,
    /// How long an open breaker rejects before admitting a half-open
    /// probe; also the `retry_after_ms` hint ceiling clients see.
    pub breaker_cooldown: Duration,
}

impl Default for SuperviseConfig {
    fn default() -> Self {
        SuperviseConfig {
            restart_budget: 5,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_secs(1),
            breaker_threshold: 5,
            breaker_cooldown: Duration::from_millis(250),
        }
    }
}

impl SuperviseConfig {
    /// Read overrides from `A2Q_RESTART_BUDGET`, `A2Q_BREAKER_THRESHOLD`
    /// and `A2Q_BREAKER_COOLDOWN_MS`; unset knobs keep the defaults, bad
    /// values are startup errors (same discipline as `NetConfig`).
    pub fn from_env() -> Result<SuperviseConfig> {
        let mut cfg = SuperviseConfig::default();
        if let Some(v) = env_u64("A2Q_RESTART_BUDGET")? {
            cfg.restart_budget = v as u32;
        }
        if let Some(v) = env_u64("A2Q_BREAKER_THRESHOLD")? {
            cfg.breaker_threshold = v as u32;
        }
        if let Some(v) = env_u64("A2Q_BREAKER_COOLDOWN_MS")? {
            if v == 0 {
                return Err(Error::config("A2Q_BREAKER_COOLDOWN_MS must be >= 1"));
            }
            cfg.breaker_cooldown = Duration::from_millis(v);
        }
        Ok(cfg)
    }

    /// Backoff before respawn number `restart` (1-based): exponential
    /// from `backoff_base`, clamped to `backoff_cap`.
    pub fn backoff_for(&self, restart: u32) -> Duration {
        let exp = restart.saturating_sub(1).min(20);
        let d = self.backoff_base.saturating_mul(1u32 << exp);
        d.min(self.backoff_cap)
    }
}

fn env_u64(key: &str) -> Result<Option<u64>> {
    match std::env::var(key) {
        Ok(v) if !v.trim().is_empty() => v
            .trim()
            .parse::<u64>()
            .map(Some)
            .map_err(|_| Error::config(format!("{key}='{v}' is not a non-negative integer"))),
        _ => Ok(None),
    }
}

#[derive(Debug)]
enum BreakerState {
    /// Normal service; counts the current run of failed batches.
    Closed { consecutive_failures: u32 },
    /// Fast-rejecting until `until`.
    Open { until: Instant },
    /// Cooldown elapsed; exactly one probe submission is admitted.
    HalfOpen { probe_inflight: bool },
}

/// Per-model circuit breaker.  `try_submit` consults [`Self::check_reject`]
/// before routing; the runner feeds [`Self::on_batch_result`] once per
/// executed batch.
#[derive(Debug)]
pub struct CircuitBreaker {
    model: String,
    threshold: u32,
    cooldown: Duration,
    metrics: Arc<Metrics>,
    inner: Mutex<BreakerState>,
}

impl CircuitBreaker {
    pub fn new(cfg: &SuperviseConfig, model: &str, metrics: Arc<Metrics>) -> CircuitBreaker {
        if cfg.breaker_threshold > 0 {
            metrics.set_breaker_state(model, "closed");
        }
        CircuitBreaker {
            model: model.to_string(),
            threshold: cfg.breaker_threshold,
            cooldown: cfg.breaker_cooldown,
            metrics,
            inner: Mutex::new(BreakerState::Closed {
                consecutive_failures: 0,
            }),
        }
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, BreakerState> {
        // a small enum behind a short-lived lock: salvage on poison
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// `None` admits the submission; `Some(retry_after_ms)` means the
    /// breaker is open (or half-open with its probe already in flight)
    /// and the caller should reject fast with that hint.
    pub fn check_reject(&self) -> Option<u64> {
        if self.threshold == 0 {
            return None;
        }
        let mut st = self.locked();
        loop {
            match &mut *st {
                BreakerState::Closed { .. } => return None,
                BreakerState::Open { until } => {
                    let now = Instant::now();
                    if now < *until {
                        let ms = until.saturating_duration_since(now).as_millis() as u64;
                        self.metrics.record_breaker_rejected();
                        return Some(ms.max(1));
                    }
                    // cooldown elapsed: half-open, re-evaluate as such
                    *st = BreakerState::HalfOpen {
                        probe_inflight: false,
                    };
                    self.metrics.set_breaker_state(&self.model, "half_open");
                }
                BreakerState::HalfOpen { probe_inflight } => {
                    if *probe_inflight {
                        // one probe at a time; suggest waiting about a
                        // probe-round-trip, not a full cooldown
                        let ms = (self.cooldown.as_millis() as u64 / 4).max(1);
                        self.metrics.record_breaker_rejected();
                        return Some(ms);
                    }
                    *probe_inflight = true;
                    return None;
                }
            }
        }
    }

    /// Feed one executed batch's outcome (`ok` = every sub-batch
    /// succeeded).  Drives closed→open after `threshold` consecutive
    /// failures and half-open→closed/open on the probe result; results
    /// arriving while open (batches admitted before it opened) are
    /// ignored.
    pub fn on_batch_result(&self, ok: bool) {
        if self.threshold == 0 {
            return;
        }
        let mut st = self.locked();
        match &mut *st {
            BreakerState::Closed {
                consecutive_failures,
            } => {
                if ok {
                    *consecutive_failures = 0;
                } else {
                    *consecutive_failures += 1;
                    if *consecutive_failures >= self.threshold {
                        *st = BreakerState::Open {
                            until: Instant::now() + self.cooldown,
                        };
                        self.metrics.record_breaker_open();
                        self.metrics.set_breaker_state(&self.model, "open");
                    }
                }
            }
            BreakerState::HalfOpen { .. } => {
                if ok {
                    *st = BreakerState::Closed {
                        consecutive_failures: 0,
                    };
                    self.metrics.set_breaker_state(&self.model, "closed");
                } else {
                    *st = BreakerState::Open {
                        until: Instant::now() + self.cooldown,
                    };
                    self.metrics.record_breaker_open();
                    self.metrics.set_breaker_state(&self.model, "open");
                }
            }
            BreakerState::Open { .. } => {}
        }
    }

    /// Current state tag ("closed" / "open" / "half_open").  Passive:
    /// reports the stored state without advancing open→half-open (only
    /// an admission attempt does that).
    pub fn state_str(&self) -> &'static str {
        match &*self.locked() {
            BreakerState::Closed { .. } => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfOpen { .. } => "half_open",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(threshold: u32, cooldown_ms: u64) -> SuperviseConfig {
        SuperviseConfig {
            breaker_threshold: threshold,
            breaker_cooldown: Duration::from_millis(cooldown_ms),
            ..SuperviseConfig::default()
        }
    }

    #[test]
    fn opens_after_threshold_consecutive_failures_only() {
        let m = Arc::new(Metrics::default());
        let b = CircuitBreaker::new(&cfg(3, 50), "m", Arc::clone(&m));
        b.on_batch_result(false);
        b.on_batch_result(false);
        b.on_batch_result(true); // success resets the run
        b.on_batch_result(false);
        b.on_batch_result(false);
        assert_eq!(b.state_str(), "closed");
        assert!(b.check_reject().is_none());
        b.on_batch_result(false); // third consecutive failure
        assert_eq!(b.state_str(), "open");
        let hint = b.check_reject().expect("open breaker rejects");
        assert!(hint >= 1 && hint <= 50, "hint {hint} within cooldown");
        let s = m.snapshot();
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.breaker_rejected, 1);
        assert_eq!(
            s.breaker_states,
            vec![("m".to_string(), "open".to_string())]
        );
    }

    #[test]
    fn half_open_admits_one_probe_then_closes_on_success() {
        let m = Arc::new(Metrics::default());
        let b = CircuitBreaker::new(&cfg(1, 20), "m", Arc::clone(&m));
        b.on_batch_result(false);
        assert_eq!(b.state_str(), "open");
        std::thread::sleep(Duration::from_millis(25));
        // cooldown elapsed: first admission is the probe...
        assert!(b.check_reject().is_none());
        assert_eq!(b.state_str(), "half_open");
        // ...and the second is rejected while the probe is in flight
        assert!(b.check_reject().is_some());
        b.on_batch_result(true);
        assert_eq!(b.state_str(), "closed");
        assert!(b.check_reject().is_none());
        assert_eq!(m.snapshot().breaker_opens, 1);
    }

    #[test]
    fn failed_probe_reopens_for_another_cooldown() {
        let m = Arc::new(Metrics::default());
        let b = CircuitBreaker::new(&cfg(1, 20), "m", Arc::clone(&m));
        b.on_batch_result(false);
        std::thread::sleep(Duration::from_millis(25));
        assert!(b.check_reject().is_none(), "probe admitted");
        b.on_batch_result(false);
        assert_eq!(b.state_str(), "open");
        assert!(b.check_reject().is_some(), "re-opened after failed probe");
        assert_eq!(m.snapshot().breaker_opens, 2);
    }

    #[test]
    fn results_while_open_are_ignored() {
        let m = Arc::new(Metrics::default());
        let b = CircuitBreaker::new(&cfg(2, 10_000), "m", Arc::clone(&m));
        b.on_batch_result(false);
        b.on_batch_result(false);
        assert_eq!(b.state_str(), "open");
        // a straggler batch admitted before the open completes fine —
        // the breaker stays open for its cooldown regardless
        b.on_batch_result(true);
        assert_eq!(b.state_str(), "open");
    }

    #[test]
    fn threshold_zero_disables_the_breaker() {
        let m = Arc::new(Metrics::default());
        let b = CircuitBreaker::new(&cfg(0, 10), "m", Arc::clone(&m));
        for _ in 0..100 {
            b.on_batch_result(false);
            assert!(b.check_reject().is_none());
        }
        assert_eq!(b.state_str(), "closed");
        assert!(m.snapshot().breaker_states.is_empty(), "disabled: no gauge");
    }

    #[test]
    fn backoff_doubles_and_clamps() {
        let c = SuperviseConfig {
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(100),
            ..SuperviseConfig::default()
        };
        assert_eq!(c.backoff_for(1), Duration::from_millis(10));
        assert_eq!(c.backoff_for(2), Duration::from_millis(20));
        assert_eq!(c.backoff_for(3), Duration::from_millis(40));
        assert_eq!(c.backoff_for(5), Duration::from_millis(100), "clamped");
        assert_eq!(c.backoff_for(40), Duration::from_millis(100), "exp clamped");
    }
}
