//! Dynamic batching policy.
//!
//! Graph-level: requests accumulate until the **node budget** of the
//! static-shape executable fills, the **graph-slot capacity** is reached,
//! or the oldest request's **deadline** expires — the same trade-off as
//! vLLM-style continuous batching, specialised to padded graph batches.
//! Node-level: all queued classify requests coalesce onto one full-graph
//! forward (the forward cost is independent of the query count).
//!
//! Admission control lives in the **router** (its bounded per-model queue
//! is the single backpressure point); the batcher accepts every request
//! handed to it.  Re-applying a cap here double-counted admission: after a
//! flush left leftovers pending, burst-drained requests the router had
//! already admitted were bounced with spurious "overloaded" replies and
//! recorded both admitted *and* rejected.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::request::{Payload, Request};

/// Shared, live-tunable flush deadline.
///
/// The net front end's latency tuner holds one end; the runner's batcher
/// reads the other.  When observed p99 latency exceeds the target the wait
/// shrinks (smaller batches, lower tail); when p99 is comfortably under
/// target it grows back (bigger batches, higher throughput).  Both sides
/// are lock-free: the deadline is a single `AtomicU64` of microseconds.
#[derive(Debug, Clone)]
pub struct AdaptiveWait {
    us: Arc<AtomicU64>,
    min_us: u64,
    max_us: u64,
}

impl AdaptiveWait {
    /// `initial` is clamped into `[min, max]`.
    pub fn new(initial: Duration, min: Duration, max: Duration) -> AdaptiveWait {
        let min_us = (min.as_micros() as u64).max(1);
        let max_us = (max.as_micros() as u64).max(min_us);
        let init = (initial.as_micros() as u64).clamp(min_us, max_us);
        AdaptiveWait {
            us: Arc::new(AtomicU64::new(init)),
            min_us,
            max_us,
        }
    }

    /// The flush deadline currently in force.
    pub fn current(&self) -> Duration {
        Duration::from_micros(self.us.load(Ordering::SeqCst))
    }

    /// Feed one p99-latency observation (µs) against the target (µs).
    /// Over target → halve the wait (multiplicative decrease reacts fast
    /// to tail blowups); under half the target → grow 25% (additive-ish
    /// increase recovers throughput cautiously).  In the comfort band
    /// between, hold.  `p99_us == 0` (no traffic yet) is a no-op.
    pub fn observe_p99_us(&self, p99_us: f64, target_us: f64) {
        if p99_us <= 0.0 || target_us <= 0.0 {
            return;
        }
        let cur = self.us.load(Ordering::SeqCst);
        let next = if p99_us > target_us {
            (cur / 2).max(self.min_us)
        } else if p99_us < target_us / 2.0 {
            (cur + cur / 4 + 1).min(self.max_us)
        } else {
            cur
        };
        if next != cur {
            self.us.store(next, Ordering::SeqCst);
        }
    }
}

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// max nodes across a graph-level batch (executable capacity)
    pub node_budget: usize,
    /// max graphs per batch (executable graph slots)
    pub graph_slots: usize,
    /// flush even if underfull once the oldest request waited this long
    pub max_wait: Duration,
    /// depth of the router's bounded per-model queue — the single
    /// admission-control point (`Router::register`); the batcher itself
    /// never rejects, so its transient backlog is bounded by this depth
    /// plus what a flush leaves pending
    pub queue_cap: usize,
    /// when set, overrides `max_wait` with a live-tunable deadline (the
    /// net front end's p99 tuner holds the other handle)
    pub adaptive_wait: Option<AdaptiveWait>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            node_budget: 1024,
            graph_slots: 16,
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
            adaptive_wait: None,
        }
    }
}

impl BatcherConfig {
    /// The flush deadline in force right now: the adaptive handle's
    /// current value when one is wired, else the static `max_wait`.
    pub fn effective_max_wait(&self) -> Duration {
        match &self.adaptive_wait {
            Some(w) => w.current(),
            None => self.max_wait,
        }
    }
}

/// Accumulates requests into flushable batches.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    pending: Vec<Request>,
    pending_nodes: usize,
    pending_updates: usize,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        DynamicBatcher {
            cfg,
            pending: Vec::new(),
            pending_nodes: 0,
            pending_updates: 0,
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Queue a request for the next batch.  Never rejects: everything
    /// reaching the batcher was already admitted by the router's bounded
    /// queue, the single backpressure point.
    pub fn offer(&mut self, req: Request) {
        self.pending_nodes += req.num_nodes();
        if req.is_update() {
            self.pending_updates += 1;
        }
        self.pending.push(req);
    }

    /// Would adding `n` more nodes overflow the budget?
    fn over_budget(&self) -> bool {
        self.pending_nodes >= self.cfg.node_budget
            || self.pending.len() >= self.cfg.graph_slots
    }

    fn deadline_expired(&self, now: Instant) -> bool {
        self.pending
            .first()
            .map(|r| now.duration_since(r.enqueued) >= self.cfg.effective_max_wait())
            .unwrap_or(false)
    }

    /// Pull the next batch if a flush condition holds (or `force`).
    /// Greedy packing in arrival order; a graph that would overflow the
    /// node budget closes the batch (it stays queued for the next one).
    ///
    /// Resident-graph **updates are ordering barriers**: an update never
    /// shares a batch with anything else.  A pending update both forces a
    /// flush (mutations should not sit out the deadline) and closes the
    /// batch being packed right before itself; when it reaches the front
    /// it ships as a singleton.  Since the runner executes batches in
    /// formation order, every request admitted after an update's reply
    /// observes the post-update state.
    pub fn flush(&mut self, now: Instant, force: bool) -> Option<Vec<Request>> {
        if self.pending.is_empty() {
            return None;
        }
        if !(force
            || self.over_budget()
            || self.deadline_expired(now)
            || self.pending_updates > 0)
        {
            return None;
        }
        let mut batch = Vec::new();
        let mut nodes = 0usize;
        let mut rest = Vec::new();
        let mut closed = false;
        for req in self.pending.drain(..) {
            if closed {
                rest.push(req);
                continue;
            }
            if req.is_update() {
                if batch.is_empty() && rest.is_empty() {
                    batch.push(req); // ships alone
                } else {
                    rest.push(req); // close the batch just before it
                }
                closed = true;
                continue;
            }
            let n = req.num_nodes();
            let fits = batch.len() < self.cfg.graph_slots
                && (nodes + n <= self.cfg.node_budget || batch.is_empty());
            if fits && rest.is_empty() {
                nodes += n;
                batch.push(req);
            } else {
                rest.push(req);
            }
        }
        self.pending = rest;
        self.pending_nodes = self.pending.iter().map(|r| r.num_nodes()).sum();
        self.pending_updates = self.pending.iter().filter(|r| r.is_update()).count();
        Some(batch)
    }

    /// Split a batch into (classify, predict) sub-batches — mixed payloads
    /// execute separately but are accounted as one admission batch.
    /// Updates never reach here (they flush as singletons; `server`
    /// partitions them out first).
    pub fn split_payloads(batch: Vec<Request>) -> (Vec<Request>, Vec<Request>) {
        batch
            .into_iter()
            .partition(|r| matches!(r.payload, Payload::ClassifyNodes(_)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::io::SmallGraph;
    use std::sync::mpsc;

    fn graph_req(n: usize) -> Request {
        let csr = Csr::from_edges(n, &[]).unwrap();
        let (tx, _rx) = mpsc::channel();
        Request {
            model: "m".into(),
            payload: Payload::PredictGraph(SmallGraph {
                csr,
                features: vec![0.0; n * 2],
                target_class: 0,
                target_value: 0.0,
            }),
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    fn cfg(budget: usize, slots: usize) -> BatcherConfig {
        BatcherConfig {
            node_budget: budget,
            graph_slots: slots,
            max_wait: Duration::from_millis(1),
            queue_cap: 8,
            adaptive_wait: None,
        }
    }

    #[test]
    fn accumulates_until_budget() {
        let mut b = DynamicBatcher::new(cfg(100, 16));
        for _ in 0..3 {
            b.offer(graph_req(20));
        }
        assert!(b.flush(Instant::now(), false).is_none()); // 60 < 100, fresh
        b.offer(graph_req(50)); // 110 >= 100
        let batch = b.flush(Instant::now(), false).unwrap();
        // greedy packing: 20+20+20 fits, 50 overflows 100? 60+50=110 > 100
        assert_eq!(batch.len(), 4 - 1);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn deadline_flushes_underfull_batch() {
        let mut b = DynamicBatcher::new(cfg(1000, 16));
        b.offer(graph_req(5));
        assert!(b.flush(Instant::now(), false).is_none());
        let later = Instant::now() + Duration::from_millis(5);
        let batch = b.flush(later, false).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn graph_slot_cap() {
        let mut b = DynamicBatcher::new(cfg(10_000, 2));
        for _ in 0..3 {
            b.offer(graph_req(5));
        }
        let batch = b.flush(Instant::now(), true).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending_len(), 1);
    }

    #[test]
    fn no_double_admission_beyond_router_cap() {
        // the router admitted these (its queue is the backpressure point);
        // a flush leaving leftovers + a burst drain must not re-reject
        let mut b = DynamicBatcher::new(cfg(1000, 16));
        for _ in 0..3 * b.cfg.queue_cap {
            b.offer(graph_req(1));
        }
        assert_eq!(b.pending_len(), 3 * b.cfg.queue_cap);
        let mut flushed = 0;
        let far = Instant::now() + Duration::from_secs(1);
        while let Some(batch) = b.flush(far, true) {
            flushed += batch.len();
        }
        assert_eq!(flushed, 3 * b.cfg.queue_cap);
    }

    #[test]
    fn conservation_property() {
        use crate::util::prop::{property, Gen};
        property("batcher conserves requests", 30, |g: &mut Gen| {
            let mut b = DynamicBatcher::new(cfg(g.usize_range(10, 200), g.usize_range(1, 8)));
            let total = g.usize_range(1, 30);
            for _ in 0..total {
                b.offer(graph_req(g.usize_range(1, 40)));
            }
            let mut flushed = 0;
            let far = Instant::now() + Duration::from_secs(1);
            while let Some(batch) = b.flush(far, true) {
                assert!(!batch.is_empty());
                flushed += batch.len();
            }
            assert_eq!(flushed, total);
            assert_eq!(b.pending_len(), 0);
        });
    }

    #[test]
    fn oversized_single_request_still_ships_alone() {
        let mut b = DynamicBatcher::new(cfg(10, 4));
        b.offer(graph_req(50)); // bigger than the whole budget
        let batch = b.flush(Instant::now(), true).unwrap();
        assert_eq!(batch.len(), 1);
    }

    fn update_req() -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            model: "m".into(),
            payload: Payload::UpdateGraph(crate::graph::delta::GraphDelta {
                add_edges: vec![(0, 1)],
                ..Default::default()
            }),
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn update_is_a_batch_barrier_in_arrival_order() {
        let mut b = DynamicBatcher::new(cfg(10_000, 16));
        b.offer(graph_req(1));
        b.offer(graph_req(1));
        b.offer(update_req());
        b.offer(graph_req(1));
        // a pending update forces flushing even before budget/deadline
        let first = b.flush(Instant::now(), false).unwrap();
        assert_eq!(first.len(), 2);
        assert!(first.iter().all(|r| !r.is_update()));
        // the update ships strictly alone…
        let second = b.flush(Instant::now(), false).unwrap();
        assert_eq!(second.len(), 1);
        assert!(second[0].is_update());
        // …and whatever arrived after it stays behind it
        let third = b.flush(Instant::now(), true).unwrap();
        assert_eq!(third.len(), 1);
        assert!(!third[0].is_update());
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn adaptive_wait_shrinks_under_tail_pressure_and_recovers() {
        let w = AdaptiveWait::new(
            Duration::from_micros(1000),
            Duration::from_micros(100),
            Duration::from_micros(4000),
        );
        assert_eq!(w.current(), Duration::from_micros(1000));
        // p99 over target → multiplicative decrease
        w.observe_p99_us(9000.0, 5000.0);
        assert_eq!(w.current(), Duration::from_micros(500));
        // repeated pressure clamps at min, never zero
        for _ in 0..10 {
            w.observe_p99_us(9000.0, 5000.0);
        }
        assert_eq!(w.current(), Duration::from_micros(100));
        // comfortably under target/2 → cautious growth, clamped at max
        for _ in 0..40 {
            w.observe_p99_us(1000.0, 5000.0);
        }
        assert_eq!(w.current(), Duration::from_micros(4000));
        // comfort band [target/2, target]: hold steady
        w.observe_p99_us(4000.0, 5000.0);
        assert_eq!(w.current(), Duration::from_micros(4000));
        // no traffic yet: no-op
        w.observe_p99_us(0.0, 5000.0);
        assert_eq!(w.current(), Duration::from_micros(4000));
    }

    #[test]
    fn adaptive_wait_drives_the_flush_deadline() {
        let w = AdaptiveWait::new(
            Duration::from_millis(50),
            Duration::from_micros(100),
            Duration::from_millis(50),
        );
        let mut c = cfg(1000, 16);
        c.adaptive_wait = Some(w.clone());
        assert_eq!(c.effective_max_wait(), Duration::from_millis(50));
        let mut b = DynamicBatcher::new(c);
        b.offer(graph_req(5));
        // 5 ms old: under the 50 ms adaptive deadline → no flush
        let later = Instant::now() + Duration::from_millis(5);
        assert!(b.flush(later, false).is_none());
        // the tuner (other handle of the same Arc) slams the wait down
        for _ in 0..12 {
            w.observe_p99_us(1_000_000.0, 1000.0);
        }
        assert_eq!(w.current(), Duration::from_micros(100));
        // same age, new deadline → flushes
        let batch = b.flush(later, false).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn leading_update_flushes_immediately_and_alone() {
        let mut b = DynamicBatcher::new(cfg(10_000, 16));
        b.offer(update_req());
        b.offer(update_req());
        b.offer(graph_req(1));
        let first = b.flush(Instant::now(), false).unwrap();
        assert_eq!(first.len(), 1);
        assert!(first[0].is_update());
        let second = b.flush(Instant::now(), false).unwrap();
        assert_eq!(second.len(), 1);
        assert!(second[0].is_update());
        let third = b.flush(Instant::now(), true).unwrap();
        assert_eq!(third.len(), 1);
        assert!(!third[0].is_update());
    }
}
