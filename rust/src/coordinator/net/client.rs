//! Blocking wire-protocol client + closed-loop load generator.
//!
//! The client is deliberately simple — one request in flight per
//! connection, matching the server's sequential per-connection loop.  The
//! load generator drives `conns` such clients in parallel and tallies
//! every outcome class separately (`ok` / `rejected` / `errors` /
//! `io_errors`), so a bench can assert the overload contract: every
//! request gets an on-protocol reply, never a hang or a dropped
//! connection.

use std::io::Write as _;
use std::net::{TcpStream, ToSocketAddrs};
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::stats::percentile;

use super::protocol::{read_frame, write_frame, WireRequest, WireResponse};

/// Blocking client for one connection.
pub struct NetClient {
    stream: TcpStream,
    max_frame: usize,
}

impl NetClient {
    /// Connect with a generous reply deadline (the server always answers
    /// or closes; the deadline only guards against a dead peer).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        Ok(NetClient {
            stream,
            max_frame: 64 << 20,
        })
    }

    /// Send one request and wait for its reply frame.
    pub fn request(&mut self, req: &WireRequest) -> Result<WireResponse> {
        let (kind, payload) = req.encode();
        write_frame(&mut self.stream, kind, &payload)?;
        match read_frame(&mut self.stream, self.max_frame)? {
            Some(frame) => WireResponse::decode(&frame),
            None => Err(Error::coordinator("server closed the connection")),
        }
    }

    pub fn classify(&mut self, model: &str, nodes: Vec<u32>) -> Result<WireResponse> {
        self.request(&WireRequest::Classify {
            model: model.to_string(),
            nodes,
        })
    }

    pub fn ping(&mut self) -> Result<WireResponse> {
        self.request(&WireRequest::Ping)
    }

    /// Fetch the server's metrics snapshot (JSON body).
    pub fn metrics(&mut self) -> Result<Json> {
        match self.request(&WireRequest::Metrics)? {
            WireResponse::Metrics { body } => Ok(body),
            other => Err(Error::coordinator(format!(
                "expected metrics reply, got {other:?}"
            ))),
        }
    }

    /// Send raw bytes (test helper for malformed-input cases).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read one raw reply frame (test helper).
    pub fn read_reply(&mut self) -> Result<Option<WireResponse>> {
        match read_frame(&mut self.stream, self.max_frame)? {
            Some(frame) => Ok(Some(WireResponse::decode(&frame)?)),
            None => Ok(None),
        }
    }
}

/// Load-generator shape: `conns` closed-loop clients, each sending
/// `requests_per_conn` classify requests.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub conns: usize,
    pub requests_per_conn: usize,
    pub model: String,
    /// node ids per classify request
    pub nodes_per_req: usize,
    /// ids are drawn modulo this (match the resident graph size)
    pub node_space: u32,
    /// sleep between requests; `ZERO` = closed loop (max pressure)
    pub pace: Duration,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            conns: 4,
            requests_per_conn: 100,
            model: "mock".to_string(),
            nodes_per_req: 2,
            node_space: 64,
            pace: Duration::ZERO,
        }
    }
}

/// Outcome tally of one load run.  `sent` always equals
/// `ok + rejected + errors + io_errors`: every request is accounted for.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub sent: u64,
    /// `Ok` replies
    pub ok: u64,
    /// on-protocol `Rejected` replies (overload / rate limit / drain)
    pub rejected: u64,
    /// on-protocol `Error` replies
    pub errors: u64,
    /// transport failures: connect refused, reset, timeout — the failure
    /// class a graceful server must keep at zero
    pub io_errors: u64,
    pub elapsed: Duration,
    /// latency percentiles over `Ok` replies only (ms)
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// successful replies per second of wall time
    pub achieved_ok_rps: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("io_errors", Json::Num(self.io_errors as f64)),
            ("elapsed_ms", Json::Num(self.elapsed.as_secs_f64() * 1e3)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("achieved_ok_rps", Json::Num(self.achieved_ok_rps)),
        ])
    }
}

struct ThreadTally {
    sent: u64,
    ok: u64,
    rejected: u64,
    errors: u64,
    io_errors: u64,
    latencies_ms: Vec<f64>,
}

fn run_client(addr: &str, cfg: &LoadConfig, thread_idx: usize) -> ThreadTally {
    let mut t = ThreadTally {
        sent: 0,
        ok: 0,
        rejected: 0,
        errors: 0,
        io_errors: 0,
        latencies_ms: Vec::with_capacity(cfg.requests_per_conn),
    };
    let mut client = match NetClient::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            // a refused connection fails every request this client owed
            t.sent = cfg.requests_per_conn as u64;
            t.io_errors = t.sent;
            return t;
        }
    };
    for i in 0..cfg.requests_per_conn {
        let base = (thread_idx * cfg.requests_per_conn + i) as u32;
        let nodes: Vec<u32> = (0..cfg.nodes_per_req)
            .map(|k| (base + k as u32) % cfg.node_space.max(1))
            .collect();
        t.sent += 1;
        let start = Instant::now();
        match client.classify(&cfg.model, nodes) {
            Ok(WireResponse::Ok { .. }) => {
                t.ok += 1;
                t.latencies_ms.push(start.elapsed().as_secs_f64() * 1e3);
            }
            Ok(WireResponse::Rejected { .. }) => t.rejected += 1,
            Ok(WireResponse::Error { .. }) => t.errors += 1,
            Ok(_) => t.errors += 1,
            Err(_) => {
                // transport is gone; the remaining requests can't be sent
                t.io_errors += 1;
                let unsent = (cfg.requests_per_conn - i - 1) as u64;
                t.sent += unsent;
                t.io_errors += unsent;
                break;
            }
        }
        if cfg.pace > Duration::ZERO {
            thread::sleep(cfg.pace);
        }
    }
    t
}

/// Drive `cfg.conns` parallel closed-loop clients against `addr`.
pub fn run_load(addr: &str, cfg: &LoadConfig) -> Result<LoadReport> {
    let started = Instant::now();
    let mut joins = Vec::with_capacity(cfg.conns);
    for idx in 0..cfg.conns {
        let addr = addr.to_string();
        let cfg = cfg.clone();
        joins.push(
            thread::Builder::new()
                .name(format!("a2q-loadgen-{idx}"))
                .spawn(move || run_client(&addr, &cfg, idx))
                .map_err(|e| Error::coordinator(format!("spawn load client: {e}")))?,
        );
    }
    let mut total = ThreadTally {
        sent: 0,
        ok: 0,
        rejected: 0,
        errors: 0,
        io_errors: 0,
        latencies_ms: Vec::new(),
    };
    for j in joins {
        let t = j
            .join()
            .map_err(|_| Error::coordinator("load client panicked"))?;
        total.sent += t.sent;
        total.ok += t.ok;
        total.rejected += t.rejected;
        total.errors += t.errors;
        total.io_errors += t.io_errors;
        total.latencies_ms.extend(t.latencies_ms);
    }
    let elapsed = started.elapsed();
    Ok(LoadReport {
        sent: total.sent,
        ok: total.ok,
        rejected: total.rejected,
        errors: total.errors,
        io_errors: total.io_errors,
        elapsed,
        p50_ms: percentile(&total.latencies_ms, 50.0),
        p99_ms: percentile(&total.latencies_ms, 99.0),
        achieved_ok_rps: total.ok as f64 / elapsed.as_secs_f64().max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let r = LoadReport {
            sent: 10,
            ok: 7,
            rejected: 2,
            errors: 1,
            io_errors: 0,
            elapsed: Duration::from_millis(500),
            p50_ms: 1.5,
            p99_ms: 9.0,
            achieved_ok_rps: 14.0,
        };
        let j = r.to_json();
        assert_eq!(j.req_f64("sent").unwrap(), 10.0);
        assert_eq!(j.req_f64("io_errors").unwrap(), 0.0);
        assert!(j.req_f64("p99_ms").unwrap() >= j.req_f64("p50_ms").unwrap());
    }
}
