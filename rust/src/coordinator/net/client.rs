//! Blocking wire-protocol client + closed-loop load generator.
//!
//! The client is deliberately simple — one request in flight per
//! connection, matching the server's sequential per-connection loop.
//! [`NetClient::request_with_retry`] layers deadline-aware retries on
//! top: on-protocol rejections are retried after the server's
//! `retry_after_ms` hint (plus jittered exponential backoff), transport
//! errors trigger a reconnect, and the whole attempt chain respects one
//! overall deadline.  The load generator drives `conns` such clients in
//! parallel and tallies every outcome class separately (`ok` /
//! `rejected` / `errors` / `io_errors`, plus `retries`), so a bench can
//! assert the overload contract: every request gets an on-protocol
//! reply, never a hang or a dropped connection.

use std::io::Write as _;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::percentile;

use super::protocol::{read_frame, write_frame, WireRequest, WireResponse};

/// Default per-reply read deadline.  The server always answers or closes;
/// the deadline only guards against a dead peer.
const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(60);

/// How a client retries a request: how many extra attempts, how to back
/// off between them, and a wall-clock budget for the whole chain.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// extra attempts after the first (0 = never retry)
    pub max_retries: u32,
    /// first backoff; doubles each retry (jittered, capped)
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// wall-clock budget for the whole attempt chain, measured from the
    /// first send.  Also bounds the per-reply read timeout, so a request
    /// with a 2 s deadline never sits 60 s in a blocking read.
    pub deadline: Option<Duration>,
    /// seed for backoff jitter (deterministic per client)
    pub jitter_seed: u64,
}

impl RetryPolicy {
    /// Never retry — single attempt, default read deadline.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
            deadline: None,
            jitter_seed: 0,
        }
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
            deadline: None,
            jitter_seed: 0x5eed,
        }
    }
}

/// Blocking client for one connection.
pub struct NetClient {
    stream: TcpStream,
    max_frame: usize,
    /// resolved peer (kept so retries can reconnect after an io error)
    peer: SocketAddr,
    read_timeout: Duration,
    /// total extra attempts made by `request_with_retry` on this client
    retries_total: u64,
}

impl NetClient {
    /// Connect with the default reply deadline.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<NetClient> {
        NetClient::connect_with_timeout(addr, DEFAULT_READ_TIMEOUT)
    }

    /// Connect with an explicit per-reply read deadline (the old client
    /// hardcoded 60 s, which made short request deadlines meaningless).
    pub fn connect_with_timeout(
        addr: impl ToSocketAddrs,
        read_timeout: Duration,
    ) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let peer = stream.peer_addr()?;
        let read_timeout = read_timeout.max(Duration::from_millis(1));
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(read_timeout))?;
        Ok(NetClient {
            stream,
            max_frame: 64 << 20,
            peer,
            read_timeout,
            retries_total: 0,
        })
    }

    /// Drop the current stream and dial the same peer again.
    fn reconnect(&mut self) -> Result<()> {
        let stream = TcpStream::connect(self.peer)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(self.read_timeout))?;
        self.stream = stream;
        Ok(())
    }

    /// Extra attempts made by [`request_with_retry`] over this client's
    /// lifetime (load-generator bookkeeping).
    pub fn retries_total(&self) -> u64 {
        self.retries_total
    }

    /// Send one request and wait for its reply frame.
    pub fn request(&mut self, req: &WireRequest) -> Result<WireResponse> {
        let (kind, payload) = req.encode();
        write_frame(&mut self.stream, kind, &payload)?;
        match read_frame(&mut self.stream, self.max_frame)? {
            Some(frame) => WireResponse::decode(&frame),
            None => Err(Error::coordinator("server closed the connection")),
        }
    }

    /// Send with deadline-aware retries.
    ///
    /// * `Rejected` replies are retried after `max(retry_after_ms,
    ///   exponential backoff)` plus up to 25% jitter — honouring the
    ///   server's hint instead of hammering a draining or breaker-open
    ///   server.
    /// * Transport errors reconnect before retrying.
    /// * The whole chain (sends, waits, backoffs) stops at
    ///   `policy.deadline`; the per-reply read timeout is clamped to the
    ///   remaining budget so the final attempt cannot overshoot it.
    ///
    /// Returns the last outcome when attempts run out — a terminal
    /// `Rejected` is still an on-protocol reply, not an `Err`.
    pub fn request_with_retry(
        &mut self,
        req: &WireRequest,
        policy: &RetryPolicy,
    ) -> Result<WireResponse> {
        let started = Instant::now();
        let mut jitter = Rng::new(policy.jitter_seed ^ 0x9e37_79b9_7f4a_7c15);
        let mut attempt: u32 = 0;
        loop {
            // clamp the read timeout to the remaining deadline budget
            if let Some(deadline) = policy.deadline {
                let remaining = deadline.saturating_sub(started.elapsed());
                if remaining.is_zero() {
                    return Err(Error::coordinator(format!(
                        "request deadline ({deadline:?}) exceeded after {attempt} attempt(s)"
                    )));
                }
                let t = remaining.min(self.read_timeout).max(Duration::from_millis(1));
                self.stream.set_read_timeout(Some(t))?;
            }
            let outcome = self.request(req);
            let out_of_attempts = attempt >= policy.max_retries;
            let wait = match &outcome {
                Ok(WireResponse::Rejected { retry_after_ms, .. }) if !out_of_attempts => {
                    let backoff = policy
                        .base_backoff
                        .saturating_mul(1u32 << attempt.min(20))
                        .min(policy.max_backoff);
                    Some(backoff.max(Duration::from_millis(*retry_after_ms)))
                }
                Ok(_) => return outcome,
                Err(_) if !out_of_attempts => {
                    // transport gone: reconnect, then back off and resend
                    if self.reconnect().is_err() {
                        return outcome;
                    }
                    Some(
                        policy
                            .base_backoff
                            .saturating_mul(1u32 << attempt.min(20))
                            .min(policy.max_backoff),
                    )
                }
                Err(_) => return outcome,
            };
            let Some(wait) = wait else { return outcome };
            // up to 25% jitter decorrelates clients retrying in lockstep
            let wait = wait.mul_f64(1.0 + 0.25 * jitter.f64());
            let wait = match policy.deadline {
                Some(deadline) => {
                    let remaining = deadline.saturating_sub(started.elapsed());
                    if remaining <= wait {
                        // not enough budget for another attempt: the last
                        // on-protocol outcome is the answer
                        return outcome;
                    }
                    wait
                }
                None => wait,
            };
            thread::sleep(wait);
            attempt += 1;
            self.retries_total += 1;
        }
    }

    pub fn classify(&mut self, model: &str, nodes: Vec<u32>) -> Result<WireResponse> {
        self.request(&WireRequest::Classify {
            model: model.to_string(),
            nodes,
        })
    }

    pub fn ping(&mut self) -> Result<WireResponse> {
        self.request(&WireRequest::Ping)
    }

    /// Fetch the server's metrics snapshot (JSON body).
    pub fn metrics(&mut self) -> Result<Json> {
        match self.request(&WireRequest::Metrics)? {
            WireResponse::Metrics { body } => Ok(body),
            other => Err(Error::coordinator(format!(
                "expected metrics reply, got {other:?}"
            ))),
        }
    }

    /// Send raw bytes (test helper for malformed-input cases).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read one raw reply frame (test helper).
    pub fn read_reply(&mut self) -> Result<Option<WireResponse>> {
        match read_frame(&mut self.stream, self.max_frame)? {
            Some(frame) => Ok(Some(WireResponse::decode(&frame)?)),
            None => Ok(None),
        }
    }
}

/// Load-generator shape: `conns` closed-loop clients, each sending
/// `requests_per_conn` classify requests.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub conns: usize,
    pub requests_per_conn: usize,
    pub model: String,
    /// node ids per classify request
    pub nodes_per_req: usize,
    /// ids are drawn modulo this (match the resident graph size)
    pub node_space: u32,
    /// sleep between requests; `ZERO` = closed loop (max pressure)
    pub pace: Duration,
    /// retry behaviour per request (`RetryPolicy::none()` = the old
    /// single-attempt tally, where every rejection counts as rejected)
    pub retry: RetryPolicy,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            conns: 4,
            requests_per_conn: 100,
            model: "mock".to_string(),
            nodes_per_req: 2,
            node_space: 64,
            pace: Duration::ZERO,
            retry: RetryPolicy::none(),
        }
    }
}

/// Outcome tally of one load run.  `sent` always equals
/// `ok + rejected + errors + io_errors`: every request is accounted for.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub sent: u64,
    /// `Ok` replies
    pub ok: u64,
    /// on-protocol `Rejected` replies (overload / rate limit / drain)
    pub rejected: u64,
    /// on-protocol `Error` replies
    pub errors: u64,
    /// transport failures: connect refused, reset, timeout — the failure
    /// class a graceful server must keep at zero
    pub io_errors: u64,
    /// extra attempts made by retrying clients (each request still counts
    /// once in `sent`, under its final outcome)
    pub retries: u64,
    pub elapsed: Duration,
    /// latency percentiles over `Ok` replies only (ms)
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// successful replies per second of wall time
    pub achieved_ok_rps: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sent", Json::Num(self.sent as f64)),
            ("ok", Json::Num(self.ok as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("io_errors", Json::Num(self.io_errors as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("elapsed_ms", Json::Num(self.elapsed.as_secs_f64() * 1e3)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p99_ms", Json::Num(self.p99_ms)),
            ("achieved_ok_rps", Json::Num(self.achieved_ok_rps)),
        ])
    }
}

struct ThreadTally {
    sent: u64,
    ok: u64,
    rejected: u64,
    errors: u64,
    io_errors: u64,
    retries: u64,
    latencies_ms: Vec<f64>,
}

fn run_client(addr: &str, cfg: &LoadConfig, thread_idx: usize) -> ThreadTally {
    let mut t = ThreadTally {
        sent: 0,
        ok: 0,
        rejected: 0,
        errors: 0,
        io_errors: 0,
        retries: 0,
        latencies_ms: Vec::with_capacity(cfg.requests_per_conn),
    };
    let mut client = match NetClient::connect(addr) {
        Ok(c) => c,
        Err(_) => {
            // a refused connection fails every request this client owed
            t.sent = cfg.requests_per_conn as u64;
            t.io_errors = t.sent;
            return t;
        }
    };
    // each client jitters differently, else retries re-synchronise
    let mut policy = cfg.retry.clone();
    policy.jitter_seed ^= thread_idx as u64;
    for i in 0..cfg.requests_per_conn {
        let base = (thread_idx * cfg.requests_per_conn + i) as u32;
        let nodes: Vec<u32> = (0..cfg.nodes_per_req)
            .map(|k| (base + k as u32) % cfg.node_space.max(1))
            .collect();
        t.sent += 1;
        let start = Instant::now();
        let req = WireRequest::Classify {
            model: cfg.model.clone(),
            nodes,
        };
        match client.request_with_retry(&req, &policy) {
            Ok(WireResponse::Ok { .. }) => {
                t.ok += 1;
                t.latencies_ms.push(start.elapsed().as_secs_f64() * 1e3);
            }
            Ok(WireResponse::Rejected { .. }) => t.rejected += 1,
            Ok(WireResponse::Error { .. }) => t.errors += 1,
            Ok(_) => t.errors += 1,
            Err(_) => {
                // transport is gone; the remaining requests can't be sent
                t.io_errors += 1;
                let unsent = (cfg.requests_per_conn - i - 1) as u64;
                t.sent += unsent;
                t.io_errors += unsent;
                break;
            }
        }
        if cfg.pace > Duration::ZERO {
            thread::sleep(cfg.pace);
        }
    }
    t.retries = client.retries_total();
    t
}

/// Drive `cfg.conns` parallel closed-loop clients against `addr`.
pub fn run_load(addr: &str, cfg: &LoadConfig) -> Result<LoadReport> {
    let started = Instant::now();
    let mut joins = Vec::with_capacity(cfg.conns);
    for idx in 0..cfg.conns {
        let addr = addr.to_string();
        let cfg = cfg.clone();
        joins.push(
            thread::Builder::new()
                .name(format!("a2q-loadgen-{idx}"))
                .spawn(move || run_client(&addr, &cfg, idx))
                .map_err(|e| Error::coordinator(format!("spawn load client: {e}")))?,
        );
    }
    let mut total = ThreadTally {
        sent: 0,
        ok: 0,
        rejected: 0,
        errors: 0,
        io_errors: 0,
        retries: 0,
        latencies_ms: Vec::new(),
    };
    for j in joins {
        let t = j
            .join()
            .map_err(|_| Error::coordinator("load client panicked"))?;
        total.sent += t.sent;
        total.ok += t.ok;
        total.rejected += t.rejected;
        total.errors += t.errors;
        total.io_errors += t.io_errors;
        total.retries += t.retries;
        total.latencies_ms.extend(t.latencies_ms);
    }
    let elapsed = started.elapsed();
    Ok(LoadReport {
        sent: total.sent,
        ok: total.ok,
        rejected: total.rejected,
        errors: total.errors,
        io_errors: total.io_errors,
        retries: total.retries,
        elapsed,
        p50_ms: percentile(&total.latencies_ms, 50.0),
        p99_ms: percentile(&total.latencies_ms, 99.0),
        achieved_ok_rps: total.ok as f64 / elapsed.as_secs_f64().max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let r = LoadReport {
            sent: 10,
            ok: 7,
            rejected: 2,
            errors: 1,
            io_errors: 0,
            retries: 3,
            elapsed: Duration::from_millis(500),
            p50_ms: 1.5,
            p99_ms: 9.0,
            achieved_ok_rps: 14.0,
        };
        let j = r.to_json();
        assert_eq!(j.req_f64("sent").unwrap(), 10.0);
        assert_eq!(j.req_f64("io_errors").unwrap(), 0.0);
        assert_eq!(j.req_f64("retries").unwrap(), 3.0);
        assert!(j.req_f64("p99_ms").unwrap() >= j.req_f64("p50_ms").unwrap());
    }

    #[test]
    fn retry_policy_defaults_are_sane() {
        let none = RetryPolicy::none();
        assert_eq!(none.max_retries, 0);
        let def = RetryPolicy::default();
        assert!(def.max_retries > 0);
        assert!(def.base_backoff <= def.max_backoff);
        assert!(def.deadline.is_none());
    }
}
