//! Per-client token-bucket rate limiter.
//!
//! Each client IP owns a bucket of capacity `burst` that refills at
//! `rate_per_sec`.  A request costs one token; an empty bucket yields
//! [`RateDecision::Deny`] with a `retry_after` hint (time until one token
//! refills) that the connection layer puts on the wire, so throttled
//! clients learn *when* to come back instead of hammering.
//!
//! All methods take an explicit `now` so behavior is testable with
//! synthetic clocks (no sleeping in tests).

use std::collections::HashMap;
use std::net::IpAddr;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Limiter policy.
#[derive(Debug, Clone)]
pub struct RateConfig {
    /// sustained tokens/sec per client; `<= 0` disables the limiter
    pub rate_per_sec: f64,
    /// bucket capacity (max burst)
    pub burst: f64,
    /// max tracked clients; beyond this, idle (refilled-to-full) buckets
    /// are evicted, and if none are evictable new clients are denied
    pub max_clients: usize,
}

impl Default for RateConfig {
    fn default() -> Self {
        RateConfig {
            rate_per_sec: 0.0,
            burst: 1.0,
            max_clients: 4096,
        }
    }
}

#[derive(Debug)]
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

/// Admission verdict for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateDecision {
    Allow,
    Deny { retry_after: Duration },
}

/// Ceiling on any `retry_after` hint.  A degenerate-but-positive rate
/// (e.g. `A2Q_RATE_RPS=1e-300`) makes `tokens / rate` overflow what
/// `Duration` can represent, and `Duration::from_secs_f64` *panics* on
/// overflow — on the accept path.  Beyond an hour the hint carries no
/// extra information for a client anyway.
const MAX_RETRY_AFTER: Duration = Duration::from_secs(3600);

/// Thread-safe per-IP token buckets.
#[derive(Debug)]
pub struct RateLimiter {
    cfg: RateConfig,
    buckets: Mutex<HashMap<IpAddr, TokenBucket>>,
}

impl RateLimiter {
    pub fn new(cfg: RateConfig) -> RateLimiter {
        RateLimiter {
            cfg,
            buckets: Mutex::new(HashMap::new()),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.rate_per_sec > 0.0
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, HashMap<IpAddr, TokenBucket>> {
        // a2q-lint: allow(panic-path) bucket arithmetic cannot panic while
        // holding the lock, so poisoning would itself be a prior bug
        self.buckets.lock().unwrap()
    }

    /// Time until `tokens` tokens refill at the configured rate, clamped
    /// to [`MAX_RETRY_AFTER`].  Never panics: non-finite or out-of-range
    /// seconds (tiny rates, huge deficits) saturate at the ceiling.
    fn refill_time(&self, tokens: f64) -> Duration {
        let secs = tokens / self.cfg.rate_per_sec;
        if !secs.is_finite() || secs < 0.0 {
            return MAX_RETRY_AFTER;
        }
        Duration::try_from_secs_f64(secs)
            .map(|d| d.min(MAX_RETRY_AFTER))
            .unwrap_or(MAX_RETRY_AFTER)
    }

    /// Time until one token refills at the configured rate.
    fn one_token(&self) -> Duration {
        self.refill_time(1.0)
    }

    /// Charge one token for `client`.  Disabled limiters always allow.
    pub fn check(&self, client: IpAddr, now: Instant) -> RateDecision {
        if !self.enabled() {
            return RateDecision::Allow;
        }
        let mut buckets = self.locked();
        if !buckets.contains_key(&client) && buckets.len() >= self.cfg.max_clients {
            // evict buckets that would be full anyway (idle long enough
            // that tracking them adds nothing)
            let (rate, burst) = (self.cfg.rate_per_sec, self.cfg.burst);
            buckets.retain(|_, b| {
                let dt = now.saturating_duration_since(b.last).as_secs_f64();
                b.tokens + dt * rate < burst
            });
            if buckets.len() >= self.cfg.max_clients {
                // table saturated with actively-limited clients: deny the
                // newcomer rather than grow without bound
                return RateDecision::Deny {
                    retry_after: self.one_token(),
                };
            }
        }
        let bucket = buckets.entry(client).or_insert(TokenBucket {
            tokens: self.cfg.burst,
            last: now,
        });
        let dt = now.saturating_duration_since(bucket.last).as_secs_f64();
        bucket.tokens = (bucket.tokens + dt * self.cfg.rate_per_sec).min(self.cfg.burst);
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            RateDecision::Allow
        } else {
            let deficit = 1.0 - bucket.tokens;
            RateDecision::Deny {
                retry_after: self.refill_time(deficit),
            }
        }
    }

    /// Number of tracked clients (diagnostics).
    pub fn tracked_clients(&self) -> usize {
        self.locked().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::Ipv4Addr;

    fn ip(last: u8) -> IpAddr {
        IpAddr::V4(Ipv4Addr::new(10, 0, 0, last))
    }

    fn limiter(rate: f64, burst: f64, max_clients: usize) -> RateLimiter {
        RateLimiter::new(RateConfig {
            rate_per_sec: rate,
            burst,
            max_clients,
        })
    }

    #[test]
    fn burst_then_deny_with_retry_hint() {
        let l = limiter(10.0, 3.0, 16);
        let t0 = Instant::now();
        for _ in 0..3 {
            assert_eq!(l.check(ip(1), t0), RateDecision::Allow);
        }
        match l.check(ip(1), t0) {
            RateDecision::Deny { retry_after } => {
                // one token refills in 1/10 s
                assert!(retry_after > Duration::ZERO);
                assert!(retry_after <= Duration::from_millis(101));
            }
            RateDecision::Allow => panic!("4th burst request must be denied"),
        }
    }

    #[test]
    fn refill_over_synthetic_time() {
        let l = limiter(10.0, 1.0, 16);
        let t0 = Instant::now();
        assert_eq!(l.check(ip(1), t0), RateDecision::Allow);
        assert!(matches!(l.check(ip(1), t0), RateDecision::Deny { .. }));
        // 100 ms refills exactly one token at 10/s
        let t1 = t0 + Duration::from_millis(100);
        assert_eq!(l.check(ip(1), t1), RateDecision::Allow);
        // refill clamps at burst: a long idle gap grants 1 token, not 50
        let t2 = t1 + Duration::from_secs(5);
        assert_eq!(l.check(ip(1), t2), RateDecision::Allow);
        assert!(matches!(l.check(ip(1), t2), RateDecision::Deny { .. }));
    }

    #[test]
    fn clients_are_limited_independently() {
        let l = limiter(1.0, 1.0, 16);
        let t0 = Instant::now();
        assert_eq!(l.check(ip(1), t0), RateDecision::Allow);
        assert!(matches!(l.check(ip(1), t0), RateDecision::Deny { .. }));
        // a different client still has its full bucket
        assert_eq!(l.check(ip(2), t0), RateDecision::Allow);
    }

    #[test]
    fn disabled_limiter_always_allows() {
        let l = limiter(0.0, 1.0, 1);
        let t0 = Instant::now();
        for i in 0..100u8 {
            assert_eq!(l.check(ip(i), t0), RateDecision::Allow);
        }
        assert_eq!(l.tracked_clients(), 0, "disabled limiter tracks nobody");
    }

    #[test]
    fn degenerate_rates_never_panic_and_clamp_retry_after() {
        // regression: 1.0 / 1e-300 overflows Duration and from_secs_f64
        // panicked on the accept path; the hint must clamp instead
        let t0 = Instant::now();
        for rate in [1e-300, f64::MIN_POSITIVE, 1e-9] {
            let l = limiter(rate, 1.0, 16);
            assert_eq!(l.check(ip(1), t0), RateDecision::Allow);
            match l.check(ip(1), t0) {
                RateDecision::Deny { retry_after } => {
                    assert!(retry_after <= MAX_RETRY_AFTER, "rate {rate}");
                    assert!(retry_after > Duration::ZERO, "rate {rate}");
                }
                RateDecision::Allow => panic!("rate {rate}: second request must be denied"),
            }
            // saturated-table deny path hits one_token() — same clamp
            let l = limiter(rate, 1.0, 1);
            assert_eq!(l.check(ip(1), t0), RateDecision::Allow);
            match l.check(ip(2), t0) {
                RateDecision::Deny { retry_after } => {
                    assert!(retry_after <= MAX_RETRY_AFTER, "rate {rate}")
                }
                RateDecision::Allow => panic!("rate {rate}: saturated table must deny"),
            }
        }
    }

    #[test]
    fn sane_rates_keep_exact_retry_hints() {
        // the clamp must not disturb the normal hint: 1 token at 10/s
        let l = limiter(10.0, 1.0, 16);
        let t0 = Instant::now();
        assert_eq!(l.check(ip(1), t0), RateDecision::Allow);
        match l.check(ip(1), t0) {
            RateDecision::Deny { retry_after } => {
                assert!(retry_after > Duration::from_millis(90));
                assert!(retry_after <= Duration::from_millis(101));
            }
            RateDecision::Allow => panic!("must deny"),
        }
    }

    #[test]
    fn eviction_bounds_the_table() {
        let l = limiter(10.0, 2.0, 4);
        let t0 = Instant::now();
        for i in 0..4u8 {
            assert_eq!(l.check(ip(i), t0), RateDecision::Allow);
        }
        assert_eq!(l.tracked_clients(), 4);
        // immediately, nobody is idle-full → the newcomer is denied
        assert!(matches!(l.check(ip(9), t0), RateDecision::Deny { .. }));
        // after the old buckets refill to full they become evictable and
        // the newcomer gets in
        let t1 = t0 + Duration::from_secs(1);
        assert_eq!(l.check(ip(9), t1), RateDecision::Allow);
        assert!(l.tracked_clients() <= 4);
    }
}
