//! The TCP server: accept loop, p99-driven batch tuner, graceful drain.
//!
//! Lifecycle:
//!
//! 1. [`NetServer::start`] binds, spawns the accept loop (one thread per
//!    connection — the coordinator's admission queue, not the thread
//!    count, is the real concurrency limiter) and, when the config sets a
//!    latency target, the adaptive-batching tuner.
//! 2. [`NetServer::drain`] shuts down gracefully: stop accepting, mark
//!    draining (new work is rejected on-protocol with `draining`), wait
//!    for every in-flight admitted request's reply to be written, then
//!    stop the coordinator's runners and report what was left.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::super::server::Coordinator;
use super::conn::{serve_conn, Shared};
use super::protocol::{write_frame, RejectCode, WireResponse};
use super::rate::{RateConfig, RateLimiter};
use super::NetConfig;

/// What drain left behind (all zeros on a clean shutdown).
#[derive(Debug, Clone)]
pub struct DrainReport {
    /// admitted requests whose reply was never written before the drain
    /// timeout expired (0 = every admitted request was answered)
    pub unreplied_in_flight: u64,
    /// connections still open when drain stopped waiting
    pub open_conns: u64,
    pub took: Duration,
}

/// A running TCP front end.
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: std::net::SocketAddr,
    stop_accept: Arc<AtomicBool>,
    accept_handle: Option<thread::JoinHandle<()>>,
    stop_tuner: Arc<AtomicBool>,
    tuner_handle: Option<thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `cfg.listen` and start serving `coordinator`'s models.
    pub fn start(coordinator: Coordinator, cfg: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .map_err(|e| Error::coordinator(format!("bind {}: {e}", cfg.listen)))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| Error::coordinator(format!("local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::coordinator(format!("set_nonblocking: {e}")))?;

        let coordinator = Arc::new(coordinator);
        let limiter = RateLimiter::new(RateConfig {
            rate_per_sec: cfg.rate_rps,
            burst: cfg.effective_burst(),
            max_clients: 4096,
        });
        let shared = Arc::new(Shared {
            coordinator: Arc::clone(&coordinator),
            cfg: cfg.clone(),
            limiter,
            draining: AtomicBool::new(false),
            drain_deadline: std::sync::Mutex::new(None),
            in_flight: std::sync::atomic::AtomicU64::new(0),
            open_conns: std::sync::atomic::AtomicU64::new(0),
            counters: Default::default(),
        });

        let stop_accept = Arc::new(AtomicBool::new(false));
        let accept_handle = {
            let shared = Arc::clone(&shared);
            let stop = Arc::clone(&stop_accept);
            thread::Builder::new()
                .name("a2q-accept".to_string())
                .spawn(move || accept_loop(listener, shared, stop))
                .map_err(|e| Error::coordinator(format!("spawn accept loop: {e}")))?
        };

        let stop_tuner = Arc::new(AtomicBool::new(false));
        let tuner_handle = if cfg.target_p99_us > 0 && !coordinator.adaptive_waits().is_empty()
        {
            let waits: Vec<_> = coordinator.adaptive_waits().to_vec();
            let coordinator = Arc::clone(&coordinator);
            let stop = Arc::clone(&stop_tuner);
            let target = cfg.target_p99_us as f64;
            let interval = cfg.tuner_interval;
            Some(
                thread::Builder::new()
                    .name("a2q-batch-tuner".to_string())
                    .spawn(move || {
                        while !stop.load(Ordering::SeqCst) {
                            thread::sleep(interval);
                            let p99 = coordinator.metrics().p99_latency_us;
                            for w in &waits {
                                w.observe_p99_us(p99, target);
                            }
                        }
                    })
                    .map_err(|e| Error::coordinator(format!("spawn tuner: {e}")))?,
            )
        } else {
            None
        };

        Ok(NetServer {
            shared,
            local_addr,
            stop_accept,
            accept_handle: Some(accept_handle),
            stop_tuner,
            tuner_handle,
        })
    }

    /// The bound address (useful with a `:0` listen config).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// The same metrics body a `Metrics` wire request returns (coordinator
    /// snapshot plus the net layer's admission counters).
    pub fn metrics_json(&self) -> crate::util::json::Json {
        self.shared.metrics_body()
    }

    /// Graceful shutdown: stop accepting, reject new work on-protocol,
    /// flush every admitted request's reply, stop the runners.
    pub fn drain(mut self) -> DrainReport {
        let started = Instant::now();
        self.stop_accept.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // record the drain deadline *before* flipping the flag so every
        // draining rejection can hint a retry past the remaining window
        let deadline = started + self.shared.cfg.drain_timeout;
        *self
            .shared
            .drain_deadline
            .lock()
            .unwrap_or_else(|e| e.into_inner()) = Some(deadline);
        self.shared.draining.store(true, Ordering::SeqCst);
        // wait for every admitted request's reply to be written
        while self.shared.in_flight.load(Ordering::SeqCst) > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
        let unreplied = self.shared.in_flight.load(Ordering::SeqCst);
        // now stop the pipeline: runners drain their queues and exit
        self.shared.coordinator.begin_shutdown();
        self.stop_tuner.store(true, Ordering::SeqCst);
        if let Some(h) = self.tuner_handle.take() {
            let _ = h.join();
        }
        // idle connections notice `draining` within one read poll
        let conn_deadline = Instant::now() + Duration::from_secs(1);
        while self.shared.open_conns.load(Ordering::SeqCst) > 0
            && Instant::now() < conn_deadline
        {
            thread::sleep(Duration::from_millis(5));
        }
        DrainReport {
            unreplied_in_flight: unreplied,
            open_conns: self.shared.open_conns.load(Ordering::SeqCst),
            took: started.elapsed(),
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // not a graceful drain — just make the background threads exit
        self.stop_accept.store(true, Ordering::SeqCst);
        self.stop_tuner.store(true, Ordering::SeqCst);
        self.shared.draining.store(true, Ordering::SeqCst);
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut stream, peer)) => {
                let open = shared.open_conns.load(Ordering::SeqCst);
                if open >= shared.cfg.max_conns as u64 {
                    // over the connection cap: still answer on-protocol
                    // (one rejection frame) instead of a silent close
                    let (kind, payload) = WireResponse::Rejected {
                        reason: RejectCode::Overloaded,
                        message: "connection limit reached".to_string(),
                        retry_after_ms: 100,
                    }
                    .encode();
                    let _ = write_frame(&mut stream, kind, &payload);
                    continue;
                }
                shared.open_conns.fetch_add(1, Ordering::SeqCst);
                let shared2 = Arc::clone(&shared);
                let spawned = thread::Builder::new()
                    .name("a2q-conn".to_string())
                    .spawn(move || {
                        serve_conn(stream, peer, Arc::clone(&shared2));
                        shared2.open_conns.fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    // thread exhaustion: undo the count; the stream drops
                    // (close) — the client sees a reset, the best we can
                    // do without a thread to write from
                    shared.open_conns.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(_) => {
                // transient accept error (EMFILE etc.): back off briefly
                thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{AdaptiveWait, BatcherConfig};
    use crate::coordinator::executor::MockExecutor;
    use crate::coordinator::net::client::{run_load, LoadConfig, NetClient, RetryPolicy};
    use crate::coordinator::net::protocol::{WireResponse, PROTOCOL_VERSION};

    fn batcher(queue_cap: usize) -> BatcherConfig {
        BatcherConfig {
            node_budget: 64,
            graph_slots: 8,
            max_wait: Duration::from_micros(500),
            queue_cap,
            adaptive_wait: None,
        }
    }

    fn server_with(latency: Duration, queue_cap: usize, cfg: NetConfig) -> NetServer {
        let mut c = Coordinator::new();
        c.add_model(
            "mock",
            Arc::new(MockExecutor {
                out_dim: 4,
                latency,
            }),
            batcher(queue_cap),
        );
        NetServer::start(c, cfg).unwrap()
    }

    fn addr_of(s: &NetServer) -> String {
        format!("{}", s.local_addr())
    }

    #[test]
    fn classify_roundtrip_and_ping_over_loopback() {
        let srv = server_with(Duration::ZERO, 64, NetConfig::default());
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        assert!(matches!(client.ping().unwrap(), WireResponse::Pong));
        match client.classify("mock", vec![0, 1, 2]).unwrap() {
            WireResponse::Ok {
                model, predictions, ..
            } => {
                assert_eq!(model, "mock");
                assert_eq!(predictions.len(), 3);
                assert_eq!(predictions[1].class, 1);
            }
            other => panic!("expected Ok, got {other:?}"),
        }
        let report = srv.drain();
        assert_eq!(report.unreplied_in_flight, 0);
    }

    #[test]
    fn unknown_model_rejected_on_protocol() {
        let srv = server_with(Duration::ZERO, 64, NetConfig::default());
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        match client.classify("nope", vec![0]).unwrap() {
            WireResponse::Rejected {
                reason, message, ..
            } => {
                assert_eq!(reason, super::RejectCode::UnknownModel);
                assert!(message.contains("nope"));
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        // the connection survives a rejection
        assert!(matches!(client.ping().unwrap(), WireResponse::Pong));
        srv.drain();
    }

    /// The overload contract: at ~10× capacity every request still gets an
    /// on-protocol reply — some `Ok`, some `Rejected{overloaded}` — and
    /// the transport never fails.
    #[test]
    fn overload_rejects_on_protocol_and_never_hangs() {
        let srv = server_with(Duration::from_millis(3), 2, NetConfig::default());
        let report = run_load(
            &addr_of(&srv),
            &LoadConfig {
                conns: 6,
                requests_per_conn: 15,
                model: "mock".to_string(),
                nodes_per_req: 1,
                node_space: 64,
                pace: Duration::ZERO,
                retry: RetryPolicy::none(),
            },
        )
        .unwrap();
        assert_eq!(report.sent, 90);
        assert_eq!(
            report.ok + report.rejected + report.errors,
            report.sent,
            "every request must be answered on-protocol: {report:?}"
        );
        assert_eq!(report.io_errors, 0, "no dropped connections: {report:?}");
        assert!(report.ok > 0, "some requests must succeed: {report:?}");
        srv.drain();
    }

    /// Deadline-aware retries: against a rate-limited server a retrying
    /// load run converts rejections into eventual successes, honouring
    /// the server's `retry_after_ms` hint between attempts.
    #[test]
    fn retrying_load_resolves_rate_limit_rejections() {
        let cfg = NetConfig {
            rate_rps: 50.0,
            rate_burst: 1.0,
            ..NetConfig::default()
        };
        let srv = server_with(Duration::ZERO, 64, cfg);
        let report = run_load(
            &addr_of(&srv),
            &LoadConfig {
                conns: 1,
                requests_per_conn: 5,
                retry: RetryPolicy {
                    max_retries: 10,
                    deadline: Some(Duration::from_secs(5)),
                    ..RetryPolicy::default()
                },
                ..LoadConfig::default()
            },
        )
        .unwrap();
        assert_eq!(report.ok, 5, "retries must resolve rate limiting: {report:?}");
        assert!(report.retries > 0, "expected at least one retry: {report:?}");
        assert_eq!(report.io_errors, 0, "{report:?}");
        srv.drain();
    }

    /// The drain retry hint derives from the remaining drain window, not
    /// a fixed constant: with a 30 s drain timeout the hint must point
    /// past the window, and it shrinks as the drain progresses.
    #[test]
    fn drain_retry_hint_tracks_remaining_window() {
        let cfg = NetConfig {
            drain_timeout: Duration::from_secs(30),
            ..NetConfig::default()
        };
        let srv = server_with(Duration::ZERO, 64, cfg);
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        // simulate a live drain: deadline recorded, then the flag
        *srv.shared.drain_deadline.lock().unwrap() =
            Some(Instant::now() + Duration::from_secs(30));
        srv.shared.draining.store(true, Ordering::SeqCst);
        match client.classify("mock", vec![0]).unwrap() {
            WireResponse::Rejected {
                reason,
                retry_after_ms,
                ..
            } => {
                assert_eq!(reason, super::RejectCode::Draining);
                assert!(
                    retry_after_ms > 25_000,
                    "hint must cover the remaining 30 s window, got {retry_after_ms}"
                );
            }
            other => panic!("expected draining rejection, got {other:?}"),
        }
        srv.drain();
    }

    #[test]
    fn rate_limited_client_gets_retry_hint() {
        let cfg = NetConfig {
            rate_rps: 1.0,
            rate_burst: 1.0,
            ..NetConfig::default()
        };
        let srv = server_with(Duration::ZERO, 64, cfg);
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        assert!(matches!(
            client.classify("mock", vec![0]).unwrap(),
            WireResponse::Ok { .. }
        ));
        match client.classify("mock", vec![1]).unwrap() {
            WireResponse::Rejected {
                reason,
                retry_after_ms,
                ..
            } => {
                assert_eq!(reason, super::RejectCode::RateLimited);
                assert!(retry_after_ms >= 1, "retry hint must be actionable");
            }
            other => panic!("expected rate-limit rejection, got {other:?}"),
        }
        // metrics requests are exempt: operators can always look
        assert!(client.metrics().is_ok());
        srv.drain();
    }

    #[test]
    fn metrics_endpoint_reports_counters() {
        let srv = server_with(Duration::from_millis(1), 64, NetConfig::default());
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        for i in 0..5u32 {
            client.classify("mock", vec![i]).unwrap();
        }
        let body = client.metrics().unwrap();
        assert_eq!(body.req_f64("responses").unwrap(), 5.0);
        assert!(body.req_f64("p99_latency_us").unwrap() > 0.0);
        let net = body.req("net").unwrap();
        assert!(net.req_f64("frames_in").unwrap() >= 5.0);
        assert_eq!(net.req_f64("replies_ok").unwrap(), 5.0);
        assert_eq!(net.req_f64("open_conns").unwrap(), 1.0);
        srv.drain();
    }

    #[test]
    fn malformed_frame_gets_error_reply_then_close() {
        let srv = server_with(Duration::ZERO, 64, NetConfig::default());
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        // declared length 1 violates the 2-byte minimum
        let mut bad = 1u32.to_be_bytes().to_vec();
        bad.push(PROTOCOL_VERSION);
        client.send_raw(&bad).unwrap();
        match client.read_reply().unwrap() {
            Some(WireResponse::Error { message }) => {
                assert!(message.contains("length"), "undescriptive: {message}");
            }
            other => panic!("expected an error reply, got {other:?}"),
        }
        // framing is lost → the server closes
        assert!(matches!(client.read_reply(), Ok(None) | Err(_)));
        srv.drain();
    }

    #[test]
    fn version_mismatch_answered_then_closed() {
        let srv = server_with(Duration::ZERO, 64, NetConfig::default());
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        // hand-build a frame with a bogus version byte
        let mut raw = 2u32.to_be_bytes().to_vec();
        raw.extend_from_slice(&[PROTOCOL_VERSION + 1, 0x05]);
        client.send_raw(&raw).unwrap();
        match client.read_reply().unwrap() {
            Some(WireResponse::Error { message }) => {
                assert!(
                    message.contains("version mismatch")
                        && message.contains(&format!("{PROTOCOL_VERSION}")),
                    "must name the supported version: {message}"
                );
            }
            other => panic!("expected version error, got {other:?}"),
        }
        assert!(matches!(client.read_reply(), Ok(None) | Err(_)));
        srv.drain();
    }

    /// The drain contract: requests in flight when drain starts still get
    /// their replies; new work is refused on-protocol.
    #[test]
    fn drain_replies_to_in_flight_and_refuses_new_work() {
        let srv = server_with(Duration::from_millis(40), 64, NetConfig::default());
        let addr = addr_of(&srv);
        let worker = {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                client.classify("mock", vec![0]).unwrap()
            })
        };
        // let the request get admitted, then drain while it executes
        thread::sleep(Duration::from_millis(10));
        let report = srv.drain();
        assert_eq!(
            report.unreplied_in_flight, 0,
            "drain lost admitted replies: {report:?}"
        );
        match worker.join().unwrap() {
            WireResponse::Ok { .. } | WireResponse::Rejected { .. } => {}
            other => panic!("in-flight request got {other:?}"),
        }
        // the listener is gone: new connections are refused outright
        assert!(NetClient::connect(addr).is_err());
    }

    /// End-to-end adaptive batching: under latency pressure the tuner
    /// shrinks the shared flush deadline.
    #[test]
    fn tuner_shrinks_adaptive_wait_under_pressure() {
        let wait = AdaptiveWait::new(
            Duration::from_millis(5),
            Duration::from_micros(100),
            Duration::from_millis(5),
        );
        let mut bc = batcher(64);
        bc.adaptive_wait = Some(wait.clone());
        let mut c = Coordinator::new();
        c.add_model(
            "mock",
            Arc::new(MockExecutor {
                out_dim: 4,
                latency: Duration::from_millis(2),
            }),
            bc,
        );
        let cfg = NetConfig {
            target_p99_us: 1, // everything is over target
            tuner_interval: Duration::from_millis(20),
            ..NetConfig::default()
        };
        let srv = NetServer::start(c, cfg).unwrap();
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        let before = wait.current();
        for i in 0..10u32 {
            client.classify("mock", vec![i]).unwrap();
            thread::sleep(Duration::from_millis(10));
        }
        let after = wait.current();
        assert!(
            after < before,
            "tuner never reacted: before={before:?} after={after:?}"
        );
        srv.drain();
    }

    #[test]
    fn draining_rejection_is_explicit() {
        let srv = server_with(Duration::ZERO, 64, NetConfig::default());
        let mut client = NetClient::connect(addr_of(&srv)).unwrap();
        // flip the drain flag directly (the connection stays open for one
        // more poll interval, long enough to observe the rejection)
        srv.shared.draining.store(true, Ordering::SeqCst);
        match client.classify("mock", vec![0]).unwrap() {
            WireResponse::Rejected {
                reason,
                retry_after_ms,
                ..
            } => {
                assert_eq!(reason, super::RejectCode::Draining);
                assert!(retry_after_ms >= 1);
            }
            other => panic!("expected draining rejection, got {other:?}"),
        }
        srv.drain();
    }

    #[test]
    fn connection_cap_rejects_on_protocol() {
        let cfg = NetConfig {
            max_conns: 1,
            ..NetConfig::default()
        };
        let srv = server_with(Duration::ZERO, 64, cfg);
        let mut first = NetClient::connect(addr_of(&srv)).unwrap();
        assert!(matches!(first.ping().unwrap(), WireResponse::Pong));
        // second connection: accepted at TCP level, answered with one
        // overloaded rejection frame, then closed
        let mut second = NetClient::connect(addr_of(&srv)).unwrap();
        match second.read_reply().unwrap() {
            Some(WireResponse::Rejected { reason, .. }) => {
                assert_eq!(reason, super::RejectCode::Overloaded);
            }
            other => panic!("expected overloaded rejection, got {other:?}"),
        }
        assert!(matches!(second.read_reply(), Ok(None) | Err(_)));
        // the first connection is unaffected
        assert!(matches!(first.ping().unwrap(), WireResponse::Pong));
        srv.drain();
    }
}
