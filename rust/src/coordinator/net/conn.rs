//! Per-connection loop: sequential request/reply over one TCP stream.
//!
//! Every admission outcome becomes an explicit frame: admitted requests
//! are answered `Ok`/`Error`, refused ones `Rejected` with a reason and a
//! `retry_after_ms` hint.  Connections poll with a short read timeout so
//! drain can end idle connections promptly; a malformed frame gets a
//! best-effort error reply and closes the connection (framing is lost).

use std::net::{IpAddr, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::fault;
use crate::util::json::Json;

use super::super::request::Payload;
use super::super::router::RejectReason;
use super::super::server::Coordinator;
use super::protocol::{
    read_frame_timeout, write_frame, Frame, ReadOutcome, RejectCode, WireRequest, WireResponse,
    PROTOCOL_VERSION,
};
use super::rate::{RateDecision, RateLimiter};
use super::NetConfig;

/// Read-poll interval: bounds how long an idle connection takes to notice
/// drain, and paces the mid-frame stall detector.
pub(crate) const READ_POLL: Duration = Duration::from_millis(250);

/// Retry hint for queue-full rejections — roughly one batching deadline.
const OVERLOAD_RETRY_MS: u64 = 10;
/// Fallback retry hint when rejecting during drain and no drain deadline
/// is known (the flag can be flipped without a running drain in tests);
/// a live drain derives the hint from its remaining window instead.
const DRAIN_RETRY_MS: u64 = 1000;
/// Margin added past the drain deadline: time for the process to exit
/// and a replacement to start listening, so the hinted retry does not
/// land on a socket mid-restart.
const DRAIN_RESTART_MARGIN_MS: u64 = 100;

/// Counters the net layer adds to the `/metrics` reply (admission-layer
/// events the coordinator's own metrics can't see).
#[derive(Debug, Default)]
pub(crate) struct NetCounters {
    pub frames_in: AtomicU64,
    pub replies_ok: AtomicU64,
    pub replies_error: AtomicU64,
    pub rejected_rate: AtomicU64,
    pub rejected_overload: AtomicU64,
    pub rejected_unknown: AtomicU64,
    pub rejected_draining: AtomicU64,
    pub malformed: AtomicU64,
}

impl NetCounters {
    fn bump(&self, code: RejectCode) {
        let c = match code {
            RejectCode::RateLimited => &self.rejected_rate,
            RejectCode::Overloaded => &self.rejected_overload,
            RejectCode::UnknownModel => &self.rejected_unknown,
            RejectCode::Draining => &self.rejected_draining,
        };
        c.fetch_add(1, Ordering::SeqCst);
    }

    fn to_json(&self, open_conns: u64, in_flight: u64, draining: bool) -> Json {
        Json::obj(vec![
            ("open_conns", Json::Num(open_conns as f64)),
            ("in_flight", Json::Num(in_flight as f64)),
            ("draining", Json::Bool(draining)),
            (
                "frames_in",
                Json::Num(self.frames_in.load(Ordering::SeqCst) as f64),
            ),
            (
                "replies_ok",
                Json::Num(self.replies_ok.load(Ordering::SeqCst) as f64),
            ),
            (
                "replies_error",
                Json::Num(self.replies_error.load(Ordering::SeqCst) as f64),
            ),
            (
                "rejected_rate_limited",
                Json::Num(self.rejected_rate.load(Ordering::SeqCst) as f64),
            ),
            (
                "rejected_overloaded",
                Json::Num(self.rejected_overload.load(Ordering::SeqCst) as f64),
            ),
            (
                "rejected_unknown_model",
                Json::Num(self.rejected_unknown.load(Ordering::SeqCst) as f64),
            ),
            (
                "rejected_draining",
                Json::Num(self.rejected_draining.load(Ordering::SeqCst) as f64),
            ),
            (
                "malformed_frames",
                Json::Num(self.malformed.load(Ordering::SeqCst) as f64),
            ),
        ])
    }
}

/// State shared by the accept loop, every connection, the tuner, and
/// drain.
pub(crate) struct Shared {
    pub coordinator: Arc<Coordinator>,
    pub cfg: NetConfig,
    pub limiter: RateLimiter,
    /// set once drain starts: inference/update requests are rejected
    pub draining: AtomicBool,
    /// when the running drain gives up waiting (`started + drain_timeout`,
    /// set by `NetServer::drain`): draining rejections hint clients to
    /// retry *after* this, not at a fixed delay into the drain window
    pub drain_deadline: Mutex<Option<Instant>>,
    /// admitted requests whose reply has not been written yet
    pub in_flight: AtomicU64,
    pub open_conns: AtomicU64,
    pub counters: NetCounters,
}

impl Shared {
    /// Retry hint for drain-time rejections, computed from the remaining
    /// drain window plus a restart margin.  The old fixed `1000 ms` hint
    /// made clients retry *into* a server configured to drain longer than
    /// that — straight into another rejection (or a dead socket).
    pub(crate) fn drain_retry_ms(&self) -> u64 {
        let deadline = *self
            .drain_deadline
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        match deadline {
            Some(d) => {
                let remaining = d.saturating_duration_since(Instant::now()).as_millis() as u64;
                remaining + DRAIN_RESTART_MARGIN_MS
            }
            None => DRAIN_RETRY_MS,
        }
    }

    pub fn metrics_body(&self) -> Json {
        let mut body = self.coordinator.metrics().to_json();
        if let Json::Obj(m) = &mut body {
            m.insert(
                "net".to_string(),
                self.counters.to_json(
                    self.open_conns.load(Ordering::SeqCst),
                    self.in_flight.load(Ordering::SeqCst),
                    self.draining.load(Ordering::SeqCst),
                ),
            );
        }
        body
    }
}

fn send(stream: &mut TcpStream, resp: &WireResponse) -> crate::error::Result<()> {
    // chaos hook: a fired fault behaves like a failed reply write (the
    // connection closes; the client sees a transport error)
    fault::point("net.write_frame")?;
    let (kind, payload) = resp.encode();
    write_frame(stream, kind, &payload)
}

fn rejection(code: RejectCode, message: String, retry_after_ms: u64) -> WireResponse {
    WireResponse::Rejected {
        reason: code,
        message,
        retry_after_ms,
    }
}

/// Serve one connection until EOF, error, or drain.  Consumes the stream.
pub(crate) fn serve_conn(mut stream: TcpStream, peer: SocketAddr, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    loop {
        match read_frame_timeout(&mut stream, shared.cfg.max_frame_bytes) {
            Ok(ReadOutcome::Frame(frame)) => {
                shared.counters.frames_in.fetch_add(1, Ordering::SeqCst);
                if !handle_frame(&mut stream, peer.ip(), &frame, &shared) {
                    break;
                }
            }
            Ok(ReadOutcome::Eof) => break,
            Ok(ReadOutcome::IdleTimeout) => {
                // idle poll: during drain there is nothing left to wait for
                if shared.draining.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(e) => {
                // framing is lost — tell the peer why, then close
                shared.counters.malformed.fetch_add(1, Ordering::SeqCst);
                let _ = send(
                    &mut stream,
                    &WireResponse::Error {
                        message: format!("{e}"),
                    },
                );
                break;
            }
        }
    }
}

/// Handle one frame; returns `false` when the connection must close.
fn handle_frame(stream: &mut TcpStream, client: IpAddr, frame: &Frame, shared: &Shared) -> bool {
    if frame.version != PROTOCOL_VERSION {
        let _ = send(
            stream,
            &WireResponse::Error {
                message: format!(
                    "protocol version mismatch: client sent {}, this server speaks {}",
                    frame.version, PROTOCOL_VERSION
                ),
            },
        );
        return false;
    }
    let req = match WireRequest::decode(frame) {
        Ok(req) => req,
        Err(e) => {
            // payload-level problem: framing is intact, reply and keep going
            let ok = send(
                stream,
                &WireResponse::Error {
                    message: format!("{e}"),
                },
            )
            .is_ok();
            shared.counters.replies_error.fetch_add(1, Ordering::SeqCst);
            return ok;
        }
    };
    let (model, payload) = match req {
        WireRequest::Ping => return send(stream, &WireResponse::Pong).is_ok(),
        WireRequest::Metrics => {
            // metrics are exempt from rate limiting and drain: operators
            // poll hardest exactly when the server is refusing work
            let body = shared.metrics_body();
            return send(stream, &WireResponse::Metrics { body }).is_ok();
        }
        WireRequest::Classify { model, nodes } => (model, Payload::ClassifyNodes(nodes)),
        WireRequest::Predict { model, graph } => (model, Payload::PredictGraph(graph)),
        WireRequest::Update { model, delta } => (model, Payload::UpdateGraph(delta)),
    };

    if shared.draining.load(Ordering::SeqCst) {
        shared.counters.bump(RejectCode::Draining);
        shared.coordinator.metrics_ref().record_rejected();
        return send(
            stream,
            &rejection(
                RejectCode::Draining,
                "server is draining for shutdown".to_string(),
                shared.drain_retry_ms(),
            ),
        )
        .is_ok();
    }
    if let RateDecision::Deny { retry_after } = shared.limiter.check(client, Instant::now()) {
        shared.counters.bump(RejectCode::RateLimited);
        shared.coordinator.metrics_ref().record_rejected();
        let retry_ms = (retry_after.as_millis() as u64).max(1);
        return send(
            stream,
            &rejection(
                RejectCode::RateLimited,
                "per-client rate limit exceeded".to_string(),
                retry_ms,
            ),
        )
        .is_ok();
    }

    let rx = match shared.coordinator.try_submit(&model, payload) {
        Ok(rx) => rx,
        Err(rej) => {
            // the Rejected carries the request (and its reply channel)
            // back, which is what lets us answer on-protocol here instead
            // of silently dropping the client
            let (code, message, retry) = match rej.reason {
                RejectReason::UnknownModel => (
                    RejectCode::UnknownModel,
                    format!("unknown model '{}'", rej.request.model),
                    0,
                ),
                RejectReason::QueueFull => (
                    RejectCode::Overloaded,
                    "admission queue full, retry later".to_string(),
                    OVERLOAD_RETRY_MS,
                ),
                RejectReason::Stopped => (
                    RejectCode::Draining,
                    "model runner stopped".to_string(),
                    shared.drain_retry_ms(),
                ),
                // no protocol change: an open breaker is a flavour of
                // overload, but the message + hint carry its cooldown
                RejectReason::BreakerOpen { retry_after_ms } => (
                    RejectCode::Overloaded,
                    format!(
                        "circuit breaker open for model '{}' (executor failing), retry later",
                        rej.request.model
                    ),
                    retry_after_ms.max(1),
                ),
            };
            shared.counters.bump(code);
            return send(stream, &rejection(code, message, retry)).is_ok();
        }
    };

    shared.in_flight.fetch_add(1, Ordering::SeqCst);
    let wire = match rx.recv_timeout(shared.cfg.request_timeout) {
        Ok(Ok(resp)) => {
            shared.counters.replies_ok.fetch_add(1, Ordering::SeqCst);
            WireResponse::Ok {
                model: resp.model,
                latency_us: resp.latency_us,
                batch_size: resp.batch_size,
                predictions: resp.predictions,
            }
        }
        Ok(Err(e)) => {
            shared.counters.replies_error.fetch_add(1, Ordering::SeqCst);
            WireResponse::Error {
                message: format!("{e}"),
            }
        }
        Err(_) => {
            shared.counters.replies_error.fetch_add(1, Ordering::SeqCst);
            WireResponse::Error {
                message: format!(
                    "no reply within {:?} (request timed out in the server)",
                    shared.cfg.request_timeout
                ),
            }
        }
    };
    let sent = send(stream, &wire).is_ok();
    // decrement only after the write attempt: drain's in_flight==0 must
    // mean every admitted request had its reply written (or its client
    // gone, which the failed write records just the same)
    shared.in_flight.fetch_sub(1, Ordering::SeqCst);
    sent
}
