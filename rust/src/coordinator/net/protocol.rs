//! Wire protocol: versioned length-prefixed frames with JSON payloads.
//!
//! Frame layout (all integers big-endian):
//!
//! ```text
//! ┌──────────┬─────────┬──────┬──────────────────┐
//! │ len: u32 │ ver: u8 │ kind │ payload (len−2 B)│
//! └──────────┴─────────┴──────┴──────────────────┘
//! ```
//!
//! `len` counts everything after itself (version + kind + payload), so the
//! minimum legal value is 2 (empty payload) and the maximum is bounded by
//! the server's configured frame cap.  Payloads are JSON via [`util::json`]
//! — binary framing keeps message boundaries exact and cheap to parse;
//! JSON bodies keep the format debuggable and versionable.
//!
//! Versioning: a frame whose `ver` byte differs from [`PROTOCOL_VERSION`]
//! is answered with a descriptive error frame and the connection is
//! closed.  Additive payload fields do not bump the version (decoders
//! ignore unknown fields); renames/semantic changes do.
//!
//! [`util::json`]: crate::util::json

use std::io::{ErrorKind, Read, Write};

use crate::error::{Error, Result};
use crate::graph::csr::Csr;
use crate::graph::delta::GraphDelta;
use crate::graph::io::SmallGraph;
use crate::util::json::{parse, Json};

use super::super::request::Prediction;

/// Current protocol version (the `ver` byte of every frame).
pub const PROTOCOL_VERSION: u8 = 1;

// request kinds (client → server)
pub const REQ_CLASSIFY: u8 = 0x01;
pub const REQ_PREDICT: u8 = 0x02;
pub const REQ_UPDATE: u8 = 0x03;
pub const REQ_METRICS: u8 = 0x04;
pub const REQ_PING: u8 = 0x05;

// response kinds (server → client); high bit set
pub const RESP_OK: u8 = 0x81;
pub const RESP_ERROR: u8 = 0x82;
pub const RESP_REJECTED: u8 = 0x83;
pub const RESP_METRICS: u8 = 0x84;
pub const RESP_PONG: u8 = 0x85;

/// One decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub version: u8,
    pub kind: u8,
    pub payload: Vec<u8>,
}

/// Why the server refused a request, as named on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCode {
    /// per-client token bucket empty
    RateLimited,
    /// the model's admission queue is full
    Overloaded,
    /// no such model registered
    UnknownModel,
    /// the server is draining for shutdown
    Draining,
}

impl RejectCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            RejectCode::RateLimited => "rate_limited",
            RejectCode::Overloaded => "overloaded",
            RejectCode::UnknownModel => "unknown_model",
            RejectCode::Draining => "draining",
        }
    }

    pub fn from_str(s: &str) -> Result<RejectCode> {
        match s {
            "rate_limited" => Ok(RejectCode::RateLimited),
            "overloaded" => Ok(RejectCode::Overloaded),
            "unknown_model" => Ok(RejectCode::UnknownModel),
            "draining" => Ok(RejectCode::Draining),
            other => Err(Error::json(format!("unknown reject code '{other}'"))),
        }
    }
}

/// Typed client → server message.
#[derive(Debug, Clone)]
pub enum WireRequest {
    Classify { model: String, nodes: Vec<u32> },
    Predict { model: String, graph: SmallGraph },
    Update { model: String, delta: GraphDelta },
    Metrics,
    Ping,
}

/// Typed server → client message.
#[derive(Debug, Clone)]
pub enum WireResponse {
    Ok {
        model: String,
        latency_us: u64,
        batch_size: usize,
        predictions: Vec<Prediction>,
    },
    Error {
        message: String,
    },
    Rejected {
        reason: RejectCode,
        message: String,
        retry_after_ms: u64,
    },
    Metrics {
        body: Json,
    },
    Pong,
}

// ------------------------------------------------------------------ frames

/// Write one frame.  `payload.len() + 2` must fit in u32 (callers encode
/// JSON bodies far below that).
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> Result<()> {
    let len = payload
        .len()
        .checked_add(2)
        .filter(|l| *l <= u32::MAX as usize)
        .ok_or_else(|| Error::coordinator("frame payload too large to encode"))?;
    w.write_all(&(len as u32).to_be_bytes())?;
    w.write_all(&[PROTOCOL_VERSION, kind])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Outcome of a timeout-aware frame read.
#[derive(Debug)]
pub enum ReadOutcome {
    Frame(Frame),
    /// clean EOF on a frame boundary
    Eof,
    /// read timeout with no header bytes consumed (connection idle)
    IdleTimeout,
}

enum FillStatus {
    Full,
    /// clean EOF before the first byte
    EofAtStart,
    /// timed out before the first byte (only when `allow_idle`)
    IdleAtStart,
}

/// How many consecutive mid-frame read timeouts we tolerate before
/// declaring the peer stalled.  With the connection loop's ~250 ms poll
/// this is on the order of a minute.
const MAX_MID_FRAME_TIMEOUTS: u32 = 240;

fn fill(r: &mut impl Read, buf: &mut [u8], allow_idle: bool) -> Result<FillStatus> {
    let mut got = 0usize;
    let mut timeouts = 0u32;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(FillStatus::EofAtStart);
                }
                return Err(Error::coordinator("unexpected EOF mid-frame"));
            }
            Ok(n) => {
                got += n;
                timeouts = 0;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                if got == 0 && allow_idle {
                    return Ok(FillStatus::IdleAtStart);
                }
                timeouts += 1;
                if timeouts > MAX_MID_FRAME_TIMEOUTS {
                    return Err(Error::coordinator("peer stalled mid-frame"));
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(FillStatus::Full)
}

/// Read one frame from a stream that may have a read timeout configured.
/// Distinguishes a clean EOF / idle timeout at a frame boundary from a
/// truncated frame (the latter is an error: framing is lost).
pub fn read_frame_timeout(r: &mut impl Read, max_frame: usize) -> Result<ReadOutcome> {
    let mut header = [0u8; 4];
    match fill(r, &mut header, true)? {
        FillStatus::EofAtStart => return Ok(ReadOutcome::Eof),
        FillStatus::IdleAtStart => return Ok(ReadOutcome::IdleTimeout),
        FillStatus::Full => {}
    }
    let len = u32::from_be_bytes(header) as usize;
    if len < 2 {
        return Err(Error::coordinator(format!(
            "malformed frame: declared length {len} < 2"
        )));
    }
    if len > max_frame {
        return Err(Error::coordinator(format!(
            "frame too large: declared length {len} exceeds cap {max_frame}"
        )));
    }
    let mut body = vec![0u8; len];
    match fill(r, &mut body, false)? {
        FillStatus::Full => {}
        // fill() only reports the start-states when allow_idle/got==0;
        // a clean EOF here means the peer quit mid-frame
        _ => return Err(Error::coordinator("unexpected EOF mid-frame")),
    }
    let payload = body.split_off(2);
    Ok(ReadOutcome::Frame(Frame {
        version: body[0],
        kind: body[1],
        payload,
    }))
}

/// Blocking read of one frame; `Ok(None)` is a clean EOF.
pub fn read_frame(r: &mut impl Read, max_frame: usize) -> Result<Option<Frame>> {
    match read_frame_timeout(r, max_frame)? {
        ReadOutcome::Frame(f) => Ok(Some(f)),
        ReadOutcome::Eof => Ok(None),
        ReadOutcome::IdleTimeout => Err(Error::coordinator("read timed out waiting for frame")),
    }
}

// ------------------------------------------------------------- JSON bodies
//
// The f32/edge-list conventions (non-finite floats as `null`, edges as
// `[src, dst]` pairs) live next to `GraphDelta` so the persistence WAL
// and the wire protocol share one codec.

use crate::graph::delta::{json_edges as edges_to_json, json_edges_from};
use crate::graph::delta::{json_f32s as f32s_to_json, json_f32s_from};

fn f32s_from_json(j: &Json, field: &str) -> Result<Vec<f32>> {
    json_f32s_from(j, field)
}

fn edges_from_json(j: &Json, field: &str) -> Result<Vec<(u32, u32)>> {
    json_edges_from(j, field)
}

fn graph_to_json(g: &SmallGraph) -> Json {
    Json::obj(vec![
        ("num_nodes", Json::Num(g.num_nodes() as f64)),
        ("edges", edges_to_json(&g.csr.edge_list())),
        ("features", f32s_to_json(&g.features)),
    ])
}

fn graph_from_json(j: &Json) -> Result<SmallGraph> {
    let n = j.req_usize("num_nodes")?;
    let edges = edges_from_json(j.req("edges")?, "edges")?;
    let features = f32s_from_json(j.req("features")?, "features")?;
    Ok(SmallGraph {
        csr: Csr::from_edges(n, &edges)?,
        features,
        target_class: 0,
        target_value: 0.0,
    })
}

fn delta_to_json(d: &GraphDelta) -> Json {
    d.to_json()
}

fn delta_from_json(j: &Json) -> Result<GraphDelta> {
    GraphDelta::from_json(j)
}

fn check_version(frame: &Frame) -> Result<()> {
    if frame.version != PROTOCOL_VERSION {
        return Err(Error::coordinator(format!(
            "protocol version mismatch: peer sent {}, this server speaks {}",
            frame.version, PROTOCOL_VERSION
        )));
    }
    Ok(())
}

fn payload_json(frame: &Frame) -> Result<Json> {
    let text = std::str::from_utf8(&frame.payload)
        .map_err(|_| Error::json("frame payload is not valid UTF-8"))?;
    parse(text)
}

impl WireRequest {
    /// Encode into `(kind, payload)` for [`write_frame`].
    pub fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            WireRequest::Classify { model, nodes } => {
                let body = Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    (
                        "nodes",
                        Json::Arr(nodes.iter().map(|n| Json::Num(*n as f64)).collect()),
                    ),
                ]);
                (REQ_CLASSIFY, body.to_string().into_bytes())
            }
            WireRequest::Predict { model, graph } => {
                let body = Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("graph", graph_to_json(graph)),
                ]);
                (REQ_PREDICT, body.to_string().into_bytes())
            }
            WireRequest::Update { model, delta } => {
                let body = Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("delta", delta_to_json(delta)),
                ]);
                (REQ_UPDATE, body.to_string().into_bytes())
            }
            WireRequest::Metrics => (REQ_METRICS, Vec::new()),
            WireRequest::Ping => (REQ_PING, Vec::new()),
        }
    }

    pub fn decode(frame: &Frame) -> Result<WireRequest> {
        check_version(frame)?;
        match frame.kind {
            REQ_CLASSIFY => {
                let j = payload_json(frame)?;
                let nodes = j
                    .req("nodes")?
                    .as_arr()
                    .ok_or_else(|| Error::json("field 'nodes' is not an array"))?
                    .iter()
                    .map(|v| {
                        v.as_f64()
                            .filter(|n| *n >= 0.0 && *n <= u32::MAX as f64)
                            .map(|n| n as u32)
                            .ok_or_else(|| Error::json("field 'nodes' has a bad id"))
                    })
                    .collect::<Result<Vec<u32>>>()?;
                Ok(WireRequest::Classify {
                    model: j.req_str("model")?.to_string(),
                    nodes,
                })
            }
            REQ_PREDICT => {
                let j = payload_json(frame)?;
                Ok(WireRequest::Predict {
                    model: j.req_str("model")?.to_string(),
                    graph: graph_from_json(j.req("graph")?)?,
                })
            }
            REQ_UPDATE => {
                let j = payload_json(frame)?;
                Ok(WireRequest::Update {
                    model: j.req_str("model")?.to_string(),
                    delta: delta_from_json(j.req("delta")?)?,
                })
            }
            REQ_METRICS => Ok(WireRequest::Metrics),
            REQ_PING => Ok(WireRequest::Ping),
            other => Err(Error::coordinator(format!(
                "unknown request kind 0x{other:02x}"
            ))),
        }
    }
}

impl WireResponse {
    pub fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            WireResponse::Ok {
                model,
                latency_us,
                batch_size,
                predictions,
            } => {
                let preds = Json::Arr(
                    predictions
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("output", f32s_to_json(&p.output)),
                                ("class", Json::Num(p.class as f64)),
                            ])
                        })
                        .collect(),
                );
                let body = Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("latency_us", Json::Num(*latency_us as f64)),
                    ("batch_size", Json::Num(*batch_size as f64)),
                    ("predictions", preds),
                ]);
                (RESP_OK, body.to_string().into_bytes())
            }
            WireResponse::Error { message } => {
                let body = Json::obj(vec![("message", Json::Str(message.clone()))]);
                (RESP_ERROR, body.to_string().into_bytes())
            }
            WireResponse::Rejected {
                reason,
                message,
                retry_after_ms,
            } => {
                let body = Json::obj(vec![
                    ("reason", Json::Str(reason.as_str().to_string())),
                    ("message", Json::Str(message.clone())),
                    ("retry_after_ms", Json::Num(*retry_after_ms as f64)),
                ]);
                (RESP_REJECTED, body.to_string().into_bytes())
            }
            WireResponse::Metrics { body } => (RESP_METRICS, body.to_string().into_bytes()),
            WireResponse::Pong => (RESP_PONG, Vec::new()),
        }
    }

    pub fn decode(frame: &Frame) -> Result<WireResponse> {
        check_version(frame)?;
        match frame.kind {
            RESP_OK => {
                let j = payload_json(frame)?;
                let preds = j
                    .req("predictions")?
                    .as_arr()
                    .ok_or_else(|| Error::json("field 'predictions' is not an array"))?
                    .iter()
                    .map(|p| {
                        Ok(Prediction {
                            output: f32s_from_json(p.req("output")?, "output")?,
                            class: p.req_usize("class")?,
                        })
                    })
                    .collect::<Result<Vec<Prediction>>>()?;
                Ok(WireResponse::Ok {
                    model: j.req_str("model")?.to_string(),
                    latency_us: j.req_f64("latency_us")? as u64,
                    batch_size: j.req_usize("batch_size")?,
                    predictions: preds,
                })
            }
            RESP_ERROR => {
                let j = payload_json(frame)?;
                Ok(WireResponse::Error {
                    message: j.req_str("message")?.to_string(),
                })
            }
            RESP_REJECTED => {
                let j = payload_json(frame)?;
                Ok(WireResponse::Rejected {
                    reason: RejectCode::from_str(j.req_str("reason")?)?,
                    message: j.req_str("message")?.to_string(),
                    retry_after_ms: j.req_f64("retry_after_ms")? as u64,
                })
            }
            RESP_METRICS => Ok(WireResponse::Metrics {
                body: payload_json(frame)?,
            }),
            RESP_PONG => Ok(WireResponse::Pong),
            other => Err(Error::coordinator(format!(
                "unknown response kind 0x{other:02x}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};
    use std::io::Cursor;

    const MAX: usize = 4 << 20;

    fn roundtrip_frame(kind: u8, payload: &[u8]) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind, payload).unwrap();
        read_frame(&mut Cursor::new(buf), MAX).unwrap().unwrap()
    }

    fn roundtrip_request(req: &WireRequest) -> WireRequest {
        let (kind, payload) = req.encode();
        let frame = roundtrip_frame(kind, &payload);
        WireRequest::decode(&frame).unwrap()
    }

    fn roundtrip_response(resp: &WireResponse) -> WireResponse {
        let (kind, payload) = resp.encode();
        let frame = roundtrip_frame(kind, &payload);
        WireResponse::decode(&frame).unwrap()
    }

    #[test]
    fn frame_roundtrip_and_eof() {
        let f = roundtrip_frame(REQ_PING, b"");
        assert_eq!(f.version, PROTOCOL_VERSION);
        assert_eq!(f.kind, REQ_PING);
        assert!(f.payload.is_empty());
        // two frames then clean EOF
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_PING, b"").unwrap();
        write_frame(&mut buf, REQ_METRICS, b"x").unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur, MAX).unwrap().unwrap().kind, REQ_PING);
        assert_eq!(
            read_frame(&mut cur, MAX).unwrap().unwrap().payload,
            b"x".to_vec()
        );
        assert!(read_frame(&mut cur, MAX).unwrap().is_none());
    }

    /// Roundtrip property over randomly generated requests/responses, on
    /// the repo-wide prop runner (A2Q_PROP_SEED replays one case).
    #[test]
    fn request_roundtrip_property() {
        property("wire request roundtrip", 60, |g: &mut Gen| {
            let model: String = format!("m{}", g.usize_range(0, 1000));
            let req = match g.usize_range(0, 5) {
                0 => WireRequest::Classify {
                    model: model.clone(),
                    nodes: (0..g.usize_range(0, 20)).map(|_| g.usize_range(0, 500) as u32).collect(),
                },
                1 => {
                    let n = g.usize_range(1, 12);
                    let mut edges = Vec::new();
                    for _ in 0..g.usize_range(0, 3 * n) {
                        edges.push((
                            g.usize_range(0, n) as u32,
                            g.usize_range(0, n) as u32,
                        ));
                    }
                    WireRequest::Predict {
                        model: model.clone(),
                        graph: SmallGraph {
                            csr: Csr::from_edges(n, &edges).unwrap(),
                            features: g.vec_uniform(n * 4, -2.0, 2.0),
                            target_class: 0,
                            target_value: 0.0,
                        },
                    }
                }
                2 => {
                    let add_nodes = g.usize_range(0, 4);
                    WireRequest::Update {
                        model: model.clone(),
                        delta: GraphDelta {
                            add_nodes,
                            new_features: g.vec_uniform(add_nodes * 4, -1.0, 1.0),
                            add_edges: vec![(0, 1), (2, 3)],
                            remove_edges: vec![(1, 0)],
                        },
                    }
                }
                3 => WireRequest::Metrics,
                _ => WireRequest::Ping,
            };
            // encode is deterministic (sorted JSON objects), so byte
            // equality of re-encodings is structural equality
            let decoded = roundtrip_request(&req);
            assert_eq!(
                req.encode(),
                decoded.encode(),
                "decode(encode(req)) re-encodes differently"
            );
        });
    }

    #[test]
    fn response_roundtrip_preserves_fields() {
        let resp = WireResponse::Ok {
            model: "gcn".into(),
            latency_us: 1234,
            batch_size: 7,
            predictions: vec![
                Prediction {
                    output: vec![0.5, -1.25],
                    class: 0,
                },
                Prediction {
                    output: vec![f32::NAN, 3.0],
                    class: 1,
                },
            ],
        };
        match roundtrip_response(&resp) {
            WireResponse::Ok {
                model,
                latency_us,
                batch_size,
                predictions,
            } => {
                assert_eq!(model, "gcn");
                assert_eq!(latency_us, 1234);
                assert_eq!(batch_size, 7);
                assert_eq!(predictions.len(), 2);
                assert_eq!(predictions[0].output, vec![0.5, -1.25]);
                // non-finite floats travel as null and come back NaN
                assert!(predictions[1].output[0].is_nan());
                assert_eq!(predictions[1].output[1], 3.0);
                assert_eq!(predictions[1].class, 1);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        match roundtrip_response(&WireResponse::Rejected {
            reason: RejectCode::RateLimited,
            message: "slow down".into(),
            retry_after_ms: 250,
        }) {
            WireResponse::Rejected {
                reason,
                message,
                retry_after_ms,
            } => {
                assert_eq!(reason, RejectCode::RateLimited);
                assert_eq!(message, "slow down");
                assert_eq!(retry_after_ms, 250);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    /// Malformed input must produce descriptive errors, never panics.
    #[test]
    fn malformed_frames_error_cleanly() {
        property("malformed frames never panic", 80, |g: &mut Gen| {
            // a valid frame, truncated at a random cut point
            let mut buf = Vec::new();
            let payload = format!(r#"{{"model":"m","nodes":[{}]}}"#, g.usize_range(0, 9));
            write_frame(&mut buf, REQ_CLASSIFY, payload.as_bytes()).unwrap();
            let cut = g.usize_range(1, buf.len());
            let out = read_frame(&mut Cursor::new(&buf[..cut]), MAX);
            match out {
                Err(e) => {
                    let msg = format!("{e}");
                    assert!(
                        msg.contains("EOF") || msg.contains("length"),
                        "undescriptive: {msg}"
                    );
                }
                Ok(Some(_)) => panic!("truncated frame decoded as complete"),
                Ok(None) => panic!("truncated frame read as clean EOF at cut {cut}"),
            }
        });

        // declared length below the 2-byte minimum
        let mut short = 1u32.to_be_bytes().to_vec();
        short.push(PROTOCOL_VERSION);
        let err = read_frame(&mut Cursor::new(short), MAX).unwrap_err();
        assert!(format!("{err}").contains("length 1 < 2"));

        // declared length beyond the cap: rejected before allocation
        let mut big = (u32::MAX).to_be_bytes().to_vec();
        big.extend_from_slice(&[PROTOCOL_VERSION, REQ_PING]);
        let err = read_frame(&mut Cursor::new(big), 1024).unwrap_err();
        assert!(format!("{err}").contains("exceeds cap"));

        // bad version byte
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_PING, b"").unwrap();
        buf[4] = 99; // version byte
        let frame = read_frame(&mut Cursor::new(buf), MAX).unwrap().unwrap();
        let err = WireRequest::decode(&frame).unwrap_err();
        assert!(format!("{err}").contains("version mismatch"));

        // unknown kind
        let mut buf = Vec::new();
        write_frame(&mut buf, 0x7f, b"").unwrap();
        let frame = read_frame(&mut Cursor::new(buf), MAX).unwrap().unwrap();
        assert!(format!("{}", WireRequest::decode(&frame).unwrap_err())
            .contains("unknown request kind"));

        // invalid JSON payload
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_CLASSIFY, b"{not json").unwrap();
        let frame = read_frame(&mut Cursor::new(buf), MAX).unwrap().unwrap();
        assert!(WireRequest::decode(&frame).is_err());

        // non-UTF-8 payload
        let mut buf = Vec::new();
        write_frame(&mut buf, REQ_CLASSIFY, &[0xff, 0xfe, 0x00]).unwrap();
        let frame = read_frame(&mut Cursor::new(buf), MAX).unwrap().unwrap();
        assert!(format!("{}", WireRequest::decode(&frame).unwrap_err()).contains("UTF-8"));
    }

    #[test]
    fn graph_and_delta_payloads_roundtrip_exactly() {
        let g = SmallGraph {
            csr: Csr::from_edges(4, &[(0, 1), (1, 2), (3, 0)]).unwrap(),
            features: vec![0.25, -1.5, 3.0, 0.0, 7.5, -0.125, 2.0, 1.0],
            target_class: 0,
            target_value: 0.0,
        };
        let req = WireRequest::Predict {
            model: "m".into(),
            graph: g.clone(),
        };
        match roundtrip_request(&req) {
            WireRequest::Predict { graph, .. } => {
                assert_eq!(graph.num_nodes(), 4);
                assert_eq!(graph.csr.edge_list(), g.csr.edge_list());
                // f32 → f64 → JSON → f64 → f32 is exact for finite values
                assert_eq!(graph.features, g.features);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        let req = WireRequest::Update {
            model: "m".into(),
            delta: GraphDelta {
                add_nodes: 2,
                new_features: vec![1.0, 2.0, 3.0, 4.0],
                add_edges: vec![(4, 5), (5, 4)],
                remove_edges: vec![(0, 1)],
            },
        };
        match roundtrip_request(&req) {
            WireRequest::Update { delta, .. } => {
                assert_eq!(delta.add_nodes, 2);
                assert_eq!(delta.new_features, vec![1.0, 2.0, 3.0, 4.0]);
                assert_eq!(delta.add_edges, vec![(4, 5), (5, 4)]);
                assert_eq!(delta.remove_edges, vec![(0, 1)]);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }
}
