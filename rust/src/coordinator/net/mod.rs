//! TCP front end for the coordinator (std-only, no async runtime).
//!
//! Wire format: versioned length-prefixed frames carrying JSON payloads —
//! see [`protocol`].  Every admission decision becomes an explicit
//! on-protocol reply: admitted requests get an `Ok`/`Error` frame, refused
//! requests get a `Rejected` frame naming the reason (`rate_limited`,
//! `overloaded`, `unknown_model`, `draining`) and a `retry_after_ms` hint —
//! a client never learns about overload via a dropped connection.
//!
//! * [`protocol`] — frame codec + typed request/response payloads.
//! * [`rate`] — per-client token-bucket rate limiter.
//! * [`conn`] — per-connection loop (sequential request/reply).
//! * [`server`] — accept loop, p99-driven batch tuner, graceful drain.
//! * [`client`] — blocking client + closed-loop load generator.

use std::time::Duration;

use crate::error::{Error, Result};

pub mod client;
pub(crate) mod conn;
pub mod protocol;
pub mod rate;
pub mod server;

pub use client::{run_load, LoadConfig, LoadReport, NetClient, RetryPolicy};
pub use protocol::{Frame, RejectCode, WireRequest, WireResponse, PROTOCOL_VERSION};
pub use rate::{RateConfig, RateDecision, RateLimiter};
pub use server::{DrainReport, NetServer};

/// Front-end configuration.  [`NetConfig::from_env`] reads the documented
/// `A2Q_*` knobs; every field also has a plain-code default for tests.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// listen address, e.g. `127.0.0.1:7292` (`:0` picks a free port)
    pub listen: String,
    /// max simultaneously open connections; excess accepts are answered
    /// with an `overloaded` rejection frame and closed
    pub max_conns: usize,
    /// max frame length accepted from a peer (guards allocation)
    pub max_frame_bytes: usize,
    /// per-client sustained request rate (requests/sec); `0` disables
    /// rate limiting
    pub rate_rps: f64,
    /// per-client burst allowance (token-bucket capacity); `0` derives
    /// `max(2 × rate_rps, 1)`
    pub rate_burst: f64,
    /// how long drain waits for in-flight replies before giving up
    pub drain_timeout: Duration,
    /// per-request reply deadline (covers queue + execution)
    pub request_timeout: Duration,
    /// adaptive-batching latency target (µs): the tuner shrinks the flush
    /// deadline when observed p99 exceeds this; `0` disables the tuner
    pub target_p99_us: u64,
    /// how often the tuner samples p99 and adjusts
    pub tuner_interval: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            listen: "127.0.0.1:0".to_string(),
            max_conns: 256,
            max_frame_bytes: 4 << 20,
            rate_rps: 0.0,
            rate_burst: 0.0,
            drain_timeout: Duration::from_secs(5),
            request_timeout: Duration::from_secs(30),
            target_p99_us: 0,
            tuner_interval: Duration::from_millis(200),
        }
    }
}

fn env_parsed<T: std::str::FromStr>(name: &str, raw: &str) -> Result<T> {
    raw.parse::<T>()
        .map_err(|_| Error::config(format!("{name}: cannot parse '{raw}'")))
}

impl NetConfig {
    /// Build a config from the environment, starting from the defaults.
    /// Every knob is registered in the README table (a2q-lint R6).
    pub fn from_env() -> Result<NetConfig> {
        let mut cfg = NetConfig::default();
        if let Ok(v) = std::env::var("A2Q_LISTEN") {
            cfg.listen = v;
        }
        if let Ok(v) = std::env::var("A2Q_MAX_CONNS") {
            cfg.max_conns = env_parsed::<usize>("A2Q_MAX_CONNS", &v)?.max(1);
        }
        if let Ok(v) = std::env::var("A2Q_MAX_FRAME_BYTES") {
            cfg.max_frame_bytes = env_parsed::<usize>("A2Q_MAX_FRAME_BYTES", &v)?.max(64);
        }
        if let Ok(v) = std::env::var("A2Q_RATE_RPS") {
            cfg.rate_rps = env_parsed::<f64>("A2Q_RATE_RPS", &v)?;
            if !cfg.rate_rps.is_finite() || cfg.rate_rps < 0.0 {
                return Err(Error::config(format!(
                    "A2Q_RATE_RPS: must be a finite non-negative rate, got '{v}'"
                )));
            }
        }
        if let Ok(v) = std::env::var("A2Q_RATE_BURST") {
            cfg.rate_burst = env_parsed::<f64>("A2Q_RATE_BURST", &v)?;
            if !cfg.rate_burst.is_finite() || cfg.rate_burst < 0.0 {
                return Err(Error::config(format!(
                    "A2Q_RATE_BURST: must be a finite non-negative count, got '{v}'"
                )));
            }
        }
        if let Ok(v) = std::env::var("A2Q_DRAIN_TIMEOUT_MS") {
            cfg.drain_timeout =
                Duration::from_millis(env_parsed::<u64>("A2Q_DRAIN_TIMEOUT_MS", &v)?);
        }
        if let Ok(v) = std::env::var("A2Q_REQUEST_TIMEOUT_MS") {
            cfg.request_timeout =
                Duration::from_millis(env_parsed::<u64>("A2Q_REQUEST_TIMEOUT_MS", &v)?.max(1));
        }
        if let Ok(v) = std::env::var("A2Q_TARGET_P99_US") {
            cfg.target_p99_us = env_parsed::<u64>("A2Q_TARGET_P99_US", &v)?;
        }
        Ok(cfg)
    }

    /// The effective token-bucket capacity (see `rate_burst`).
    pub fn effective_burst(&self) -> f64 {
        if self.rate_burst > 0.0 {
            self.rate_burst
        } else {
            (self.rate_rps * 2.0).max(1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = NetConfig::default();
        assert!(c.listen.ends_with(":0"));
        assert!(c.max_conns >= 1);
        assert!(c.max_frame_bytes >= 64);
        assert_eq!(c.rate_rps, 0.0, "rate limiting off by default");
        assert_eq!(c.target_p99_us, 0, "tuner off by default");
    }

    #[test]
    fn burst_derivation() {
        let mut c = NetConfig::default();
        c.rate_rps = 10.0;
        assert_eq!(c.effective_burst(), 20.0);
        c.rate_burst = 5.0;
        assert_eq!(c.effective_burst(), 5.0);
        c.rate_rps = 0.0;
        c.rate_burst = 0.0;
        assert_eq!(c.effective_burst(), 1.0);
    }

    #[test]
    fn bad_env_values_error_descriptively() {
        let err = env_parsed::<usize>("A2Q_MAX_CONNS", "not-a-number").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("A2Q_MAX_CONNS") && msg.contains("not-a-number"));
    }
}
