//! The `Coordinator`: per-model runner threads behind a router.
//!
//! Data path:  submit() → router (bounded queue, admission control)
//!             → runner thread (dynamic batcher) → executor → reply channel.
//!
//! One runner thread per model variant keeps the executable's thread
//! affinity simple (PJRT CPU executions are serialized per executable) and
//! makes per-model batching state lock-free.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

use super::batcher::{BatcherConfig, DynamicBatcher};
use super::executor::BatchExecutor;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{Payload, Prediction, Request, Response};
use super::router::Router;

/// Coordinator-level configuration.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

/// The serving front end.
pub struct Coordinator {
    router: Router,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new() -> Coordinator {
        Coordinator {
            router: Router::new(),
            metrics: Arc::new(Metrics::default()),
            stop: Arc::new(AtomicBool::new(false)),
            handles: Vec::new(),
        }
    }

    /// Register a model: spawns its runner thread.
    pub fn add_model(
        &mut self,
        name: &str,
        executor: Arc<dyn BatchExecutor>,
        cfg: BatcherConfig,
    ) {
        let rx = self.router.register(name, cfg.queue_cap);
        let metrics = Arc::clone(&self.metrics);
        let stop = Arc::clone(&self.stop);
        let name_owned = name.to_string();
        self.handles.push(
            thread::Builder::new()
                .name(format!("a2q-runner-{name_owned}"))
                .spawn(move || runner_loop(name_owned, rx, executor, cfg, metrics, stop))
                .expect("spawn runner"),
        );
    }

    pub fn models(&self) -> Vec<String> {
        self.router.models()
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(
        &self,
        model: &str,
        payload: Payload,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            model: model.to_string(),
            payload,
            enqueued: Instant::now(),
            reply: tx,
        };
        match self.router.route(req) {
            Ok(()) => {
                self.metrics.record_admitted();
                Ok(rx)
            }
            Err(e) => {
                self.metrics.record_rejected();
                Err(e)
            }
        }
    }

    /// Submit and wait for the reply.
    pub fn submit_blocking(&self, model: &str, payload: Payload) -> Result<Response> {
        let rx = self.submit(model, payload)?;
        rx.recv()
            .map_err(|_| Error::coordinator("runner dropped reply"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Stop all runners and join them.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // dropping the router closes the queues, waking runners
        self.router = Router::new();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

fn runner_loop(
    _model: String,
    rx: mpsc::Receiver<Request>,
    executor: Arc<dyn BatchExecutor>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut batcher = DynamicBatcher::new(cfg.clone());
    let poll = cfg.max_wait.min(Duration::from_millis(1)).max(Duration::from_micros(100));
    let mut disconnected = false;
    loop {
        if stop.load(Ordering::SeqCst) && batcher.pending_len() == 0 {
            break;
        }
        // pull what's available, bounded wait to honour deadlines
        match rx.recv_timeout(poll) {
            Ok(req) => {
                if let Err(rejected) = batcher.offer(req) {
                    metrics.record_rejected();
                    let _ = rejected
                        .reply
                        .send(Err(Error::coordinator("overloaded: batcher queue full")));
                }
                // drain burst without waiting
                while let Ok(req) = rx.try_recv() {
                    if let Err(rejected) = batcher.offer(req) {
                        metrics.record_rejected();
                        let _ = rejected
                            .reply
                            .send(Err(Error::coordinator("overloaded: batcher queue full")));
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
        }
        let force = disconnected || stop.load(Ordering::SeqCst);
        while let Some(batch) = batcher.flush(Instant::now(), force) {
            execute_batch(batch, executor.as_ref(), &metrics);
            if !force {
                break;
            }
        }
        if disconnected && batcher.pending_len() == 0 {
            break;
        }
    }
}

fn execute_batch(batch: Vec<Request>, executor: &dyn BatchExecutor, metrics: &Metrics) {
    metrics.record_batch(batch.len());
    let batch_size = batch.len();
    let (classify, predict) = DynamicBatcher::split_payloads(batch);

    if !classify.is_empty() {
        // coalesce all node queries onto one full-graph forward
        let mut all_ids: Vec<u32> = Vec::new();
        let mut spans = Vec::with_capacity(classify.len());
        for req in &classify {
            if let Payload::ClassifyNodes(ids) = &req.payload {
                spans.push((all_ids.len(), ids.len()));
                all_ids.extend_from_slice(ids);
            }
        }
        let t0 = Instant::now();
        let result = executor.run_node_batch(&all_ids);
        let exec_us = t0.elapsed().as_micros() as u64;
        match result {
            Ok(outputs) => {
                for (req, (lo, len)) in classify.into_iter().zip(spans) {
                    let preds = outputs[lo..lo + len]
                        .iter()
                        .map(|o| Prediction::from_logits(o.clone()))
                        .collect();
                    respond(req, preds, batch_size, exec_us, metrics);
                }
            }
            Err(e) => fail_all(classify, e, metrics),
        }
    }

    if !predict.is_empty() {
        let graphs: Vec<&crate::graph::io::SmallGraph> = predict
            .iter()
            .filter_map(|r| match &r.payload {
                Payload::PredictGraph(g) => Some(g),
                _ => None,
            })
            .collect();
        let t0 = Instant::now();
        let result = executor.run_graph_batch(&graphs);
        let exec_us = t0.elapsed().as_micros() as u64;
        match result {
            Ok(outputs) => {
                for (req, out) in predict.into_iter().zip(outputs) {
                    let preds = vec![Prediction::from_logits(out)];
                    respond(req, preds, batch_size, exec_us, metrics);
                }
            }
            Err(e) => fail_all(predict, e, metrics),
        }
    }
}

fn respond(
    req: Request,
    predictions: Vec<Prediction>,
    batch_size: usize,
    _exec_us: u64,
    metrics: &Metrics,
) {
    let latency_us = req.enqueued.elapsed().as_micros() as u64;
    let queue_us = latency_us.saturating_sub(_exec_us);
    metrics.record_response(latency_us, queue_us);
    let model = req.model.clone();
    let _ = req.reply.send(Ok(Response {
        predictions,
        model,
        latency_us,
        batch_size,
    }));
}

fn fail_all(reqs: Vec<Request>, err: Error, metrics: &Metrics) {
    let msg = format!("{err}");
    for req in reqs {
        metrics.record_error();
        let _ = req
            .reply
            .send(Err(Error::coordinator(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;
    use crate::graph::csr::Csr;
    use crate::graph::io::SmallGraph;

    fn batcher_cfg() -> BatcherConfig {
        BatcherConfig {
            node_budget: 64,
            graph_slots: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 64,
        }
    }

    fn coordinator() -> Coordinator {
        let mut c = Coordinator::new();
        c.add_model("mock", Arc::new(MockExecutor::default()), batcher_cfg());
        c
    }

    #[test]
    fn classify_roundtrip() {
        let c = coordinator();
        let resp = c
            .submit_blocking("mock", Payload::ClassifyNodes(vec![0, 1, 2]))
            .unwrap();
        assert_eq!(resp.predictions.len(), 3);
        assert_eq!(resp.predictions[1].class, 1);
        c.shutdown();
    }

    #[test]
    fn graph_roundtrip() {
        let c = coordinator();
        let g = SmallGraph {
            csr: Csr::from_edges(3, &[(0, 1), (1, 0)]).unwrap(),
            features: vec![0.0; 6],
            target_class: 0,
            target_value: 0.0,
        };
        let resp = c.submit_blocking("mock", Payload::PredictGraph(g)).unwrap();
        assert_eq!(resp.predictions.len(), 1);
        assert_eq!(resp.predictions[0].class, 3 % 2);
        c.shutdown();
    }

    #[test]
    fn unknown_model_rejected_and_counted() {
        let c = coordinator();
        assert!(c.submit("nope", Payload::ClassifyNodes(vec![0])).is_err());
        assert_eq!(c.metrics().rejected, 1);
        c.shutdown();
    }

    #[test]
    fn batching_under_concurrent_load() {
        let c = Arc::new({
            let mut c = Coordinator::new();
            c.add_model(
                "mock",
                Arc::new(MockExecutor {
                    out_dim: 4,
                    latency: Duration::from_micros(300),
                }),
                batcher_cfg(),
            );
            c
        });
        let mut joins = Vec::new();
        for t in 0..4 {
            let c = Arc::clone(&c);
            joins.push(thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let ids = vec![(t * 25 + i) as u32 % 64];
                    if let Ok(resp) = c.submit_blocking("mock", Payload::ClassifyNodes(ids))
                    {
                        assert_eq!(resp.predictions.len(), 1);
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 100);
        let snap = c.metrics().clone();
        assert_eq!(snap.responses, 100);
        // batching actually happened under concurrency
        assert!(snap.batches <= 100);
        assert!(snap.mean_batch_size >= 1.0);
        Arc::try_unwrap(c).ok().map(|c| c.shutdown());
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = coordinator();
        let rx = c.submit("mock", Payload::ClassifyNodes(vec![5])).unwrap();
        c.shutdown();
        // request either answered before shutdown or during drain
        let out = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert!(out.is_ok());
    }
}
