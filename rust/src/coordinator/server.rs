//! The `Coordinator`: per-model runner threads behind a router.
//!
//! Data path:  submit() → router (bounded queue, **the** admission-control
//!             point) → runner thread (dynamic batcher) → executor → reply
//!             channel.
//!
//! One runner thread per model variant keeps the executable's thread
//! affinity simple (PJRT CPU executions are serialized per executable) and
//! makes per-model batching state lock-free.  Batch execution runs behind
//! a panic boundary: an executor panic fails the one batch that triggered
//! it (each client gets a coordinator error, the `errors` metric is
//! bumped) and the runner keeps serving instead of stranding every queued
//! client.
//!
//! Runner threads are *supervised* ([`supervised_runner`]): a panic that
//! escapes even the batch boundary (response-path bug, injected
//! `runner.poll` fault) is caught on the runner thread itself and the loop
//! respawns with exponential backoff under a restart budget
//! ([`SuperviseConfig`]).  The queue receiver survives the respawn —
//! mpsc receivers do not poison — so requests admitted before the crash
//! are served by the next incarnation.  Each model also carries a
//! [`CircuitBreaker`]: `try_submit` rejects fast (with a `retry_after_ms`
//! hint) while the model's executor is failing every batch.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::fault;

use super::batcher::{AdaptiveWait, BatcherConfig, DynamicBatcher};
use super::executor::BatchExecutor;
use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{Payload, Prediction, Request, Response};
use super::router::{RejectReason, Rejected, Router};
use super::supervise::{CircuitBreaker, SuperviseConfig};

/// Coordinator-level configuration.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

/// The serving front end.
pub struct Coordinator {
    // RwLock so a shared handle (the net front end holds Arc<Coordinator>)
    // can initiate drain: begin_shutdown swaps in an empty router, which
    // closes every runner queue.  The read path (submit) never blocks on
    // another reader.
    router: RwLock<Router>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    /// live tuning handles of models configured with an adaptive wait
    adaptive: Vec<AdaptiveWait>,
    /// restart/breaker policy captured by models registered after it is set
    supervise: SuperviseConfig,
    /// per-model circuit breakers, consulted before routing (few models:
    /// a sorted-insert Vec keeps lookup simple and iteration deterministic)
    breakers: Vec<(String, Arc<CircuitBreaker>)>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl Coordinator {
    pub fn new() -> Coordinator {
        Coordinator {
            router: RwLock::new(Router::new()),
            metrics: Arc::new(Metrics::default()),
            stop: Arc::new(AtomicBool::new(false)),
            adaptive: Vec::new(),
            supervise: SuperviseConfig::default(),
            breakers: Vec::new(),
            handles: Vec::new(),
        }
    }

    /// Override the restart/breaker policy.  Applies to models registered
    /// *after* the call (each runner captures the policy at
    /// [`Self::add_model`] time), so set it before registering.
    pub fn set_supervision(&mut self, cfg: SuperviseConfig) {
        self.supervise = cfg;
    }

    fn router_read(&self) -> std::sync::RwLockReadGuard<'_, Router> {
        // a2q-lint: allow(panic-path) routing never panics while holding
        // the lock, so poisoning would itself be a prior bug
        self.router.read().unwrap()
    }

    fn router_write(&self) -> std::sync::RwLockWriteGuard<'_, Router> {
        // a2q-lint: allow(panic-path) registration/drain never panic while
        // holding the lock, so poisoning would itself be a prior bug
        self.router.write().unwrap()
    }

    /// Register a model: spawns its runner thread.
    pub fn add_model(
        &mut self,
        name: &str,
        executor: Arc<dyn BatchExecutor>,
        cfg: BatcherConfig,
    ) {
        let rx = self.router_write().register(name, cfg.queue_cap);
        if let Some(w) = &cfg.adaptive_wait {
            self.adaptive.push(w.clone());
        }
        let breaker = Arc::new(CircuitBreaker::new(
            &self.supervise,
            name,
            Arc::clone(&self.metrics),
        ));
        self.breakers.push((name.to_string(), Arc::clone(&breaker)));
        let metrics = Arc::clone(&self.metrics);
        let stop = Arc::clone(&self.stop);
        let sup = self.supervise.clone();
        let name_owned = name.to_string();
        self.handles.push(
            thread::Builder::new()
                .name(format!("a2q-runner-{name_owned}"))
                .spawn(move || {
                    supervised_runner(name_owned, rx, executor, cfg, metrics, stop, sup, breaker)
                })
                // a2q-lint: allow(panic-path) thread spawn fails only on OS
                // resource exhaustion during model registration
                .expect("spawn runner"),
        );
    }

    /// The model's circuit breaker (if registered).
    fn breaker(&self, model: &str) -> Option<&Arc<CircuitBreaker>> {
        self.breakers
            .iter()
            .find(|(n, _)| n == model)
            .map(|(_, b)| b)
    }

    /// Current breaker state tag of a model ("closed"/"open"/"half_open");
    /// `None` for unknown models.  Diagnostics — the live gauge is also in
    /// [`MetricsSnapshot::breaker_states`].
    pub fn breaker_state(&self, model: &str) -> Option<&'static str> {
        self.breaker(model).map(|b| b.state_str())
    }

    pub fn models(&self) -> Vec<String> {
        self.router_read().models()
    }

    /// Tuning handles of every model registered with an adaptive flush
    /// deadline (the net front end's p99 tuner feeds them).
    pub fn adaptive_waits(&self) -> &[AdaptiveWait] {
        &self.adaptive
    }

    /// Submit a request; on rejection the [`Rejected`] carries the request
    /// — reply channel included — back to the caller, so a front end can
    /// answer the client explicitly (on-protocol rejection frame) instead
    /// of dropping the connection.
    pub fn try_submit(
        &self,
        model: &str,
        payload: Payload,
    ) -> std::result::Result<mpsc::Receiver<Result<Response>>, Rejected> {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            model: model.to_string(),
            payload,
            enqueued: Instant::now(),
            reply: tx,
        };
        // breaker gate before routing: while the model's executor is
        // failing every batch, reject fast with a retry hint instead of
        // queueing the request behind a failing runner
        if let Some(b) = self.breaker(model) {
            if let Some(retry_after_ms) = b.check_reject() {
                self.metrics.record_rejected();
                return Err(Rejected {
                    request: req,
                    reason: RejectReason::BreakerOpen { retry_after_ms },
                });
            }
        }
        match self.router_read().route(req) {
            Ok(()) => {
                self.metrics.record_admitted();
                Ok(rx)
            }
            Err(rej) => {
                self.metrics.record_rejected();
                Err(rej)
            }
        }
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(
        &self,
        model: &str,
        payload: Payload,
    ) -> Result<mpsc::Receiver<Result<Response>>> {
        self.try_submit(model, payload).map_err(|r| r.into_error())
    }

    /// Submit and wait for the reply.
    pub fn submit_blocking(&self, model: &str, payload: Payload) -> Result<Response> {
        let rx = self.submit(model, payload)?;
        rx.recv()
            .map_err(|_| Error::coordinator("runner dropped reply"))?
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// The shared metrics sink (the net front end counts its own
    /// admission-layer rejections here too, so `/metrics` sees them).
    pub(crate) fn metrics_ref(&self) -> &Metrics {
        &self.metrics
    }

    /// Initiate drain from a shared handle: stop admitting and close every
    /// runner queue.  Runners finish what was already admitted — recv
    /// yields the buffered backlog before reporting disconnect — flush
    /// their batchers, reply to every request, and exit.  New submits are
    /// rejected as unknown-model/stopped.
    pub fn begin_shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // swapping in an empty router drops the queue senders, which wakes
        // runners with Disconnected once the backlog is drained
        *self.router_write() = Router::new();
    }

    /// Stop all runners and join them (drains: every admitted request is
    /// answered before the runner exits).
    pub fn shutdown(mut self) {
        self.begin_shutdown();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
    }
}

/// Supervisor body of the per-model runner thread.  Runs [`runner_loop`]
/// behind a panic boundary; a panic that escapes the loop (response-path
/// bug, injected `runner.poll` fault) triggers a *logical respawn*: the
/// loop restarts on this same thread with exponential backoff, bounded by
/// [`SuperviseConfig::restart_budget`].  The queue receiver is owned here
/// and survives every respawn — mpsc receivers do not poison — so
/// requests admitted before the crash are served by the next incarnation
/// (requests already pulled into the crashed incarnation's batcher get
/// disconnect errors: their reply senders died with it, exactly one
/// error reply per request).  On budget exhaustion the receiver drops:
/// later submits are rejected as `stopped`.
#[allow(clippy::too_many_arguments)]
fn supervised_runner(
    model: String,
    rx: mpsc::Receiver<Request>,
    executor: Arc<dyn BatchExecutor>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    sup: SuperviseConfig,
    breaker: Arc<CircuitBreaker>,
) {
    let mut restarts: u32 = 0;
    loop {
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            runner_loop(&model, &rx, executor.as_ref(), &cfg, &metrics, &stop, &breaker)
        }));
        match outcome {
            // clean exit: queue disconnected and fully drained
            Ok(()) => return,
            Err(payload) => {
                if restarts >= sup.restart_budget {
                    eprintln!(
                        "a2q-runner-{model}: restart budget ({}) exhausted after panic: {}; \
                         giving up — new submits will be rejected",
                        sup.restart_budget,
                        panic_message(payload.as_ref()),
                    );
                    return;
                }
                restarts += 1;
                metrics.record_runner_restart();
                // exponential backoff, sliced so drain is not held up for
                // the full backoff when a shutdown starts mid-sleep
                let mut left = sup.backoff_for(restarts);
                while !left.is_zero() && !stop.load(Ordering::SeqCst) {
                    let step = left.min(Duration::from_millis(10));
                    thread::sleep(step);
                    left = left.saturating_sub(step);
                }
            }
        }
    }
}

fn runner_loop(
    _model: &str,
    rx: &mpsc::Receiver<Request>,
    executor: &dyn BatchExecutor,
    cfg: &BatcherConfig,
    metrics: &Metrics,
    stop: &AtomicBool,
    breaker: &CircuitBreaker,
) {
    let mut batcher = DynamicBatcher::new(cfg.clone());
    let poll = cfg.max_wait.min(Duration::from_millis(1)).max(Duration::from_micros(100));
    let mut disconnected = false;
    // Drain contract: the runner exits only once its queue has reported
    // Disconnected (mpsc yields the buffered backlog first) AND the batcher
    // is empty — so every admitted request is answered, never silently
    // dropped.  `stop` alone never breaks the loop: an early exit on stop
    // used to strand requests still sitting in the router queue, whose
    // clients then saw "runner dropped reply" instead of a real answer.
    loop {
        // chaos hook: `err` and `panic` actions both kill this loop
        // incarnation, exercising the supervisor's respawn path
        if let Err(e) = fault::point("runner.poll") {
            panic!("{e}");
        }
        // pull what's available, bounded wait to honour deadlines.  The
        // router already admitted everything arriving here (its bounded
        // queue is the single backpressure point), so the batcher never
        // rejects what we hand it — re-applying a cap there double-counted
        // admission.  The burst drain stops once the local backlog reaches
        // queue_cap, though: leaving the rest in the router queue is what
        // makes it fill up and reject new submits under sustained
        // overload (otherwise the backlog would grow without bound).
        match rx.recv_timeout(poll) {
            Ok(req) => {
                batcher.offer(req);
                // drain burst without waiting, up to the backlog bound
                while batcher.pending_len() < cfg.queue_cap {
                    match rx.try_recv() {
                        Ok(req) => batcher.offer(req),
                        Err(_) => break,
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => disconnected = true,
        }
        let force = disconnected || stop.load(Ordering::SeqCst);
        while let Some(batch) = batcher.flush(Instant::now(), force) {
            let ok = execute_batch_isolated(batch, executor, metrics);
            breaker.on_batch_result(ok);
            if !force {
                break;
            }
        }
        if disconnected && batcher.pending_len() == 0 {
            break;
        }
    }
}

/// Run one batch behind a panic boundary.  Executors can panic on
/// malformed state (shape asserts, missing-tensor `expect`s, out-of-range
/// indices); without isolation one such panic kills the per-model runner
/// and strands every queued client.
///
/// The *primary* boundary is inside [`execute_batch`]: each executor call
/// is caught individually and converted into the ordinary error path, so
/// exactly the requests of the failing sub-batch get a coordinator error
/// and an `errors` tick — requests already answered (e.g. the classify
/// half of a mixed batch) are untouched.  This outer boundary is a
/// last-resort backstop for panics in the response plumbing itself; it
/// keeps the runner alive and errors out every reply clone rather than
/// leaving clients hung (already-answered receivers just see a dropped
/// duplicate, at the cost of some over-counted errors in that rare case).
///
/// Returns whether the whole batch succeeded (every sub-batch answered
/// `Ok`) — the per-model circuit breaker counts one observation per batch.
fn execute_batch_isolated(
    batch: Vec<Request>,
    executor: &dyn BatchExecutor,
    metrics: &Metrics,
) -> bool {
    let replies: Vec<_> = batch.iter().map(|r| r.reply.clone()).collect();
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        execute_batch(batch, executor, metrics)
    }));
    match outcome {
        Ok(ok) => ok,
        Err(payload) => {
            let msg = panic_message(payload.as_ref());
            for reply in replies {
                metrics.record_error();
                let _ = reply.send(Err(Error::coordinator(format!(
                    "coordinator response path panicked: {msg}"
                ))));
            }
            false
        }
    }
}

/// Call an executor entry point with panics converted to `Err`, so the
/// caller's normal error handling (fail exactly this sub-batch, bump
/// `errors` per request) applies to panics too.
fn run_caught<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(result) => result,
        Err(payload) => Err(Error::coordinator(format!(
            "executor panicked: {}",
            panic_message(payload.as_ref())
        ))),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn execute_batch(batch: Vec<Request>, executor: &dyn BatchExecutor, metrics: &Metrics) -> bool {
    let mut all_ok = true;
    metrics.record_batch(batch.len());
    let batch_size = batch.len();
    // Queue wait is measured from admission to *batch* execution start.
    // `exec_us` is per-sub-batch, so deriving queue time as latency − exec
    // (the old scheme) charged requests in a later sub-batch for the
    // earlier sub-batch's execution as if it were queueing.
    let batch_start = Instant::now();
    // Resident-graph updates: the batcher flushes them as singleton
    // batches (ordering barriers), so this partition normally yields the
    // whole batch or nothing; handling it generically keeps a misbehaving
    // batcher from ever feeding an update into split_payloads.  A reply
    // carries no predictions; failures take the ordinary error path.
    let (updates, rest): (Vec<Request>, Vec<Request>) =
        batch.into_iter().partition(|r| r.is_update());
    for req in updates {
        let t0 = Instant::now();
        let result = run_caught(|| {
            fault::point("executor.update")?;
            match &req.payload {
                Payload::UpdateGraph(delta) => executor.apply_delta(delta),
                _ => unreachable!("partitioned as update"),
            }
        });
        let exec_us = t0.elapsed().as_micros() as u64;
        match result {
            Ok(report) => {
                metrics.record_update(
                    report.shards_touched as u64,
                    report.halo_nodes as u64,
                );
                respond(req, Vec::new(), batch_size, batch_start, exec_us, metrics);
            }
            Err(e) => {
                all_ok = false;
                fail_all(vec![req], e, metrics);
            }
        }
    }
    let (classify, predict) = DynamicBatcher::split_payloads(rest);

    if !classify.is_empty() {
        // coalesce all node queries onto one full-graph forward
        let mut all_ids: Vec<u32> = Vec::new();
        let mut spans = Vec::with_capacity(classify.len());
        for req in &classify {
            if let Payload::ClassifyNodes(ids) = &req.payload {
                spans.push((all_ids.len(), ids.len()));
                all_ids.extend_from_slice(ids);
            }
        }
        let t0 = Instant::now();
        let result = run_caught(|| {
            fault::point("executor.classify")?;
            executor.run_node_batch(&all_ids)
        });
        let exec_us = t0.elapsed().as_micros() as u64;
        match result {
            // Executor output counts are untrusted: a short (or long)
            // return used to panic the slicing below *outside* run_caught,
            // killing the runner thread — that model then answered "runner
            // stopped" forever.  Fail the sub-batch with a descriptive
            // error instead; the runner keeps serving.
            Ok(outputs) if outputs.len() != all_ids.len() => {
                let got = outputs.len();
                all_ok = false;
                fail_all(
                    classify,
                    Error::coordinator(format!(
                        "executor returned {got} outputs for {} queried nodes",
                        all_ids.len()
                    )),
                    metrics,
                );
            }
            Ok(outputs) => {
                for (req, (lo, len)) in classify.into_iter().zip(spans) {
                    let preds = outputs[lo..lo + len]
                        .iter()
                        .map(|o| Prediction::from_logits(o.clone()))
                        .collect();
                    respond(req, preds, batch_size, batch_start, exec_us, metrics);
                }
            }
            Err(e) => {
                all_ok = false;
                fail_all(classify, e, metrics);
            }
        }
    }

    if !predict.is_empty() {
        let graphs: Vec<&crate::graph::io::SmallGraph> = predict
            .iter()
            .filter_map(|r| match &r.payload {
                Payload::PredictGraph(g) => Some(g),
                _ => None,
            })
            .collect();
        let want = graphs.len();
        let t0 = Instant::now();
        let result = run_caught(|| executor.run_graph_batch(&graphs));
        let exec_us = t0.elapsed().as_micros() as u64;
        match result {
            // Same untrusted-count rule as the classify path, with the
            // opposite failure mode: `zip` silently truncated to the
            // shorter side, so short output dropped the tail requests'
            // reply senders and their blocked clients saw only a generic
            // "runner dropped reply".  Fail the whole sub-batch loudly.
            Ok(outputs) if outputs.len() != want => {
                let got = outputs.len();
                all_ok = false;
                fail_all(
                    predict,
                    Error::coordinator(format!(
                        "executor returned {got} outputs for {want} graphs"
                    )),
                    metrics,
                );
            }
            Ok(outputs) => {
                for (req, out) in predict.into_iter().zip(outputs) {
                    let preds = vec![Prediction::from_logits(out)];
                    respond(req, preds, batch_size, batch_start, exec_us, metrics);
                }
            }
            Err(e) => {
                all_ok = false;
                fail_all(predict, e, metrics);
            }
        }
    }
    all_ok
}

fn respond(
    req: Request,
    predictions: Vec<Prediction>,
    batch_size: usize,
    batch_start: Instant,
    exec_us: u64,
    metrics: &Metrics,
) {
    let latency_us = req.enqueued.elapsed().as_micros() as u64;
    let queue_us = batch_start.saturating_duration_since(req.enqueued).as_micros() as u64;
    metrics.record_response(latency_us, queue_us, exec_us);
    let model = req.model.clone();
    let _ = req.reply.send(Ok(Response {
        predictions,
        model,
        latency_us,
        batch_size,
    }));
}

fn fail_all(reqs: Vec<Request>, err: Error, metrics: &Metrics) {
    let msg = format!("{err}");
    for req in reqs {
        metrics.record_error();
        let _ = req
            .reply
            .send(Err(Error::coordinator(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;
    use crate::graph::csr::Csr;
    use crate::graph::io::SmallGraph;

    fn batcher_cfg() -> BatcherConfig {
        BatcherConfig {
            node_budget: 64,
            graph_slots: 4,
            max_wait: Duration::from_millis(2),
            queue_cap: 64,
            adaptive_wait: None,
        }
    }

    fn coordinator() -> Coordinator {
        let mut c = Coordinator::new();
        c.add_model("mock", Arc::new(MockExecutor::default()), batcher_cfg());
        c
    }

    #[test]
    fn classify_roundtrip() {
        let c = coordinator();
        let resp = c
            .submit_blocking("mock", Payload::ClassifyNodes(vec![0, 1, 2]))
            .unwrap();
        assert_eq!(resp.predictions.len(), 3);
        assert_eq!(resp.predictions[1].class, 1);
        c.shutdown();
    }

    #[test]
    fn graph_roundtrip() {
        let c = coordinator();
        let g = SmallGraph {
            csr: Csr::from_edges(3, &[(0, 1), (1, 0)]).unwrap(),
            features: vec![0.0; 6],
            target_class: 0,
            target_value: 0.0,
        };
        let resp = c.submit_blocking("mock", Payload::PredictGraph(g)).unwrap();
        assert_eq!(resp.predictions.len(), 1);
        assert_eq!(resp.predictions[0].class, 3 % 2);
        c.shutdown();
    }

    #[test]
    fn unknown_model_rejected_and_counted() {
        let c = coordinator();
        assert!(c.submit("nope", Payload::ClassifyNodes(vec![0])).is_err());
        assert_eq!(c.metrics().rejected, 1);
        c.shutdown();
    }

    #[test]
    fn batching_under_concurrent_load() {
        let c = Arc::new({
            let mut c = Coordinator::new();
            c.add_model(
                "mock",
                Arc::new(MockExecutor {
                    out_dim: 4,
                    latency: Duration::from_micros(300),
                }),
                batcher_cfg(),
            );
            c
        });
        let mut joins = Vec::new();
        for t in 0..4 {
            let c = Arc::clone(&c);
            joins.push(thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let ids = vec![(t * 25 + i) as u32 % 64];
                    if let Ok(resp) = c.submit_blocking("mock", Payload::ClassifyNodes(ids))
                    {
                        assert_eq!(resp.predictions.len(), 1);
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(total, 100);
        let snap = c.metrics().clone();
        assert_eq!(snap.responses, 100);
        // batching actually happened under concurrency
        assert!(snap.batches <= 100);
        assert!(snap.mean_batch_size >= 1.0);
        Arc::try_unwrap(c).ok().map(|c| c.shutdown());
    }

    /// Panics on the first node batch, serves normally afterwards —
    /// models the "one corrupt request / transient bad state" failure.
    struct PanicOnceExecutor {
        panicked: std::sync::atomic::AtomicBool,
    }

    impl BatchExecutor for PanicOnceExecutor {
        fn run_node_batch(&self, node_ids: &[u32]) -> crate::error::Result<Vec<Vec<f32>>> {
            if !self.panicked.swap(true, Ordering::SeqCst) {
                panic!("injected executor panic");
            }
            Ok(node_ids.iter().map(|_| vec![1.0, 0.0]).collect())
        }
        fn run_graph_batch(
            &self,
            graphs: &[&SmallGraph],
        ) -> crate::error::Result<Vec<Vec<f32>>> {
            Ok(graphs.iter().map(|_| vec![1.0, 0.0]).collect())
        }
        fn capacity(&self) -> (usize, usize) {
            (1024, 16)
        }
        fn out_dim(&self) -> usize {
            2
        }
    }

    #[test]
    fn panicking_executor_fails_one_batch_but_model_keeps_serving() {
        let mut c = Coordinator::new();
        c.add_model(
            "flaky",
            Arc::new(PanicOnceExecutor {
                panicked: std::sync::atomic::AtomicBool::new(false),
            }),
            batcher_cfg(),
        );
        // first batch: the executor panic must come back as an error reply,
        // not a hung client on a dead runner
        let err = c
            .submit_blocking("flaky", Payload::ClassifyNodes(vec![0]))
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("panicked"), "unexpected reply: {msg}");
        assert!(msg.contains("injected executor panic"), "payload lost: {msg}");
        // the runner survived: the same model keeps serving
        let resp = c
            .submit_blocking("flaky", Payload::ClassifyNodes(vec![1, 2]))
            .unwrap();
        assert_eq!(resp.predictions.len(), 2);
        let snap = c.metrics();
        assert!(snap.errors >= 1, "errors metric not bumped: {snap:?}");
        c.shutdown();
    }

    /// Node batches succeed, graph batches always panic — for testing that
    /// a mixed batch fails only the panicking half.
    struct GraphPanicExecutor;

    impl BatchExecutor for GraphPanicExecutor {
        fn run_node_batch(&self, node_ids: &[u32]) -> crate::error::Result<Vec<Vec<f32>>> {
            Ok(node_ids.iter().map(|_| vec![1.0, 0.0]).collect())
        }
        fn run_graph_batch(
            &self,
            _graphs: &[&SmallGraph],
        ) -> crate::error::Result<Vec<Vec<f32>>> {
            panic!("graph side exploded");
        }
        fn capacity(&self) -> (usize, usize) {
            (1024, 16)
        }
        fn out_dim(&self) -> usize {
            2
        }
    }

    #[test]
    fn mixed_batch_panic_fails_only_the_panicking_half() {
        let metrics = Metrics::default();
        let (ctx, crx) = mpsc::channel();
        let classify = Request {
            model: "m".into(),
            payload: Payload::ClassifyNodes(vec![0]),
            enqueued: Instant::now(),
            reply: ctx,
        };
        let (ptx, prx) = mpsc::channel();
        let predict = Request {
            model: "m".into(),
            payload: Payload::PredictGraph(SmallGraph {
                csr: Csr::from_edges(2, &[(0, 1)]).unwrap(),
                features: vec![0.0; 4],
                target_class: 0,
                target_value: 0.0,
            }),
            enqueued: Instant::now(),
            reply: ptx,
        };
        execute_batch_isolated(vec![classify, predict], &GraphPanicExecutor, &metrics);
        // the classify half was answered normally...
        let ok = crx.try_recv().unwrap();
        assert!(ok.is_ok(), "classify half should have succeeded: {ok:?}");
        // ...the predict half got the panic as an error, counted exactly once
        let err = prx.try_recv().unwrap().unwrap_err();
        assert!(format!("{err}").contains("graph side exploded"));
        let snap = metrics.snapshot();
        assert_eq!(snap.errors, 1, "only the panicking half counts as errors");
        assert_eq!(snap.responses, 1);
        // no stray duplicate replies on either channel
        assert!(crx.try_recv().is_err());
        assert!(prx.try_recv().is_err());
    }

    /// Misbehaving executor: always returns one output fewer than asked —
    /// the untrusted-output-count failure the validation guards against.
    struct ShortOutputExecutor;

    impl BatchExecutor for ShortOutputExecutor {
        fn run_node_batch(&self, node_ids: &[u32]) -> crate::error::Result<Vec<Vec<f32>>> {
            Ok(node_ids.iter().skip(1).map(|_| vec![1.0, 0.0]).collect())
        }
        fn run_graph_batch(
            &self,
            graphs: &[&SmallGraph],
        ) -> crate::error::Result<Vec<Vec<f32>>> {
            Ok(graphs.iter().skip(1).map(|_| vec![1.0, 0.0]).collect())
        }
        fn capacity(&self) -> (usize, usize) {
            (1024, 16)
        }
        fn out_dim(&self) -> usize {
            2
        }
    }

    /// Regression (classify path): a short executor return used to panic
    /// `outputs[lo..lo + len]` outside `run_caught`, permanently killing
    /// the runner — every later submit to that model answered "runner
    /// stopped".  Now the sub-batch fails with a descriptive error and the
    /// runner keeps serving.
    #[test]
    fn short_classify_output_fails_batch_but_runner_survives() {
        let mut c = Coordinator::new();
        c.add_model("short", Arc::new(ShortOutputExecutor), batcher_cfg());
        let err = c
            .submit_blocking("short", Payload::ClassifyNodes(vec![0, 1]))
            .unwrap_err();
        let msg = format!("{err}");
        assert!(
            msg.contains("outputs") && msg.contains("queried nodes"),
            "want a descriptive count-mismatch error, got: {msg}"
        );
        // the runner survived: the next request is answered (with the same
        // descriptive error — the executor is still short), not hung on a
        // dead queue
        let err2 = c
            .submit_blocking("short", Payload::ClassifyNodes(vec![2]))
            .unwrap_err();
        assert!(format!("{err2}").contains("queried nodes"));
        let snap = c.metrics();
        assert_eq!(snap.responses, 0);
        assert!(snap.errors >= 2, "both requests must count as errors");
        c.shutdown();
    }

    /// Regression (predict path): `zip` truncation silently dropped the
    /// tail requests' reply senders, so their clients only ever saw a
    /// generic "runner dropped reply".  Both requests of the sub-batch
    /// must now receive the descriptive count-mismatch error.
    #[test]
    fn short_predict_output_fails_every_request_in_the_sub_batch() {
        let metrics = Metrics::default();
        let mk = || {
            let (tx, rx) = mpsc::channel();
            (
                Request {
                    model: "m".into(),
                    payload: Payload::PredictGraph(SmallGraph {
                        csr: Csr::from_edges(2, &[(0, 1)]).unwrap(),
                        features: vec![0.0; 4],
                        target_class: 0,
                        target_value: 0.0,
                    }),
                    enqueued: Instant::now(),
                    reply: tx,
                },
                rx,
            )
        };
        let (r1, rx1) = mk();
        let (r2, rx2) = mk();
        execute_batch_isolated(vec![r1, r2], &ShortOutputExecutor, &metrics);
        for rx in [rx1, rx2] {
            let err = rx
                .try_recv()
                .expect("reply sender dropped — client would hang on a generic disconnect")
                .unwrap_err();
            assert!(format!("{err}").contains("graphs"), "got: {err}");
        }
        let snap = metrics.snapshot();
        assert_eq!(snap.errors, 2);
        assert_eq!(snap.responses, 0);
    }

    /// Classify executes slowly before the fast predict sub-batch of the
    /// same admission batch.  Queue wait is admission → *batch* start, so
    /// the predict request must not be charged the classify sub-batch's
    /// execution as queueing (the old latency − own-exec derivation did).
    #[test]
    fn queue_time_excludes_sibling_sub_batch_execution() {
        struct SlowClassifyExecutor;
        impl BatchExecutor for SlowClassifyExecutor {
            fn run_node_batch(&self, node_ids: &[u32]) -> crate::error::Result<Vec<Vec<f32>>> {
                thread::sleep(Duration::from_millis(20));
                Ok(node_ids.iter().map(|_| vec![1.0, 0.0]).collect())
            }
            fn run_graph_batch(
                &self,
                graphs: &[&SmallGraph],
            ) -> crate::error::Result<Vec<Vec<f32>>> {
                Ok(graphs.iter().map(|_| vec![1.0, 0.0]).collect())
            }
            fn capacity(&self) -> (usize, usize) {
                (1024, 16)
            }
            fn out_dim(&self) -> usize {
                2
            }
        }
        let metrics = Metrics::default();
        let (ctx, _crx) = mpsc::channel();
        let classify = Request {
            model: "m".into(),
            payload: Payload::ClassifyNodes(vec![0]),
            enqueued: Instant::now(),
            reply: ctx,
        };
        let (ptx, prx) = mpsc::channel();
        let predict = Request {
            model: "m".into(),
            payload: Payload::PredictGraph(SmallGraph {
                csr: Csr::from_edges(2, &[(0, 1)]).unwrap(),
                features: vec![0.0; 4],
                target_class: 0,
                target_value: 0.0,
            }),
            enqueued: Instant::now(),
            reply: ptx,
        };
        execute_batch_isolated(vec![classify, predict], &SlowClassifyExecutor, &metrics);
        assert!(prx.try_recv().unwrap().is_ok());
        let snap = metrics.snapshot();
        // both requests entered execution immediately after formation: the
        // worst queue wait must be far below the classify sub-batch's
        // 20 ms execution (pre-fix the predict request recorded ~20 ms)
        assert!(
            snap.p99_queue_us < 10_000.0,
            "sibling sub-batch execution leaked into queue wait: p99_queue={}µs",
            snap.p99_queue_us
        );
    }

    /// Drain contract: once a request is admitted, shutdown must answer it
    /// — never drop it from the queue on the way out.
    #[test]
    fn drain_replies_to_every_admitted_request() {
        let mut c = Coordinator::new();
        c.add_model(
            "mock",
            Arc::new(MockExecutor {
                out_dim: 2,
                latency: Duration::from_micros(300),
            }),
            batcher_cfg(),
        );
        let mut rxs = Vec::new();
        for i in 0..40u32 {
            if let Ok(rx) = c.submit("mock", Payload::ClassifyNodes(vec![i % 64])) {
                rxs.push(rx);
            }
        }
        let admitted = rxs.len();
        assert!(admitted > 0);
        // shared-handle drain path (what the net front end uses), then the
        // owning join
        c.begin_shutdown();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx
                .recv_timeout(Duration::from_secs(2))
                .unwrap_or_else(|_| panic!("admitted request {i}/{admitted} lost its reply"));
            assert!(out.is_ok(), "admitted request {i} errored during drain");
        }
        assert_eq!(c.metrics().responses as usize, admitted);
        // a submit after drain started is rejected, not hung
        assert!(c.submit("mock", Payload::ClassifyNodes(vec![0])).is_err());
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = coordinator();
        let rx = c.submit("mock", Payload::ClassifyNodes(vec![5])).unwrap();
        c.shutdown();
        // request either answered before shutdown or during drain
        let out = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert!(out.is_ok());
    }

    /// A mutable resident "graph": apply_delta bumps the version, node
    /// batches report the version they were served under — so a reply
    /// proves which updates the executor had applied when it ran.
    struct VersionedExecutor {
        version: std::sync::atomic::AtomicU64,
        latency: Duration,
    }

    impl VersionedExecutor {
        fn new(latency: Duration) -> Self {
            VersionedExecutor {
                version: std::sync::atomic::AtomicU64::new(0),
                latency,
            }
        }
    }

    impl BatchExecutor for VersionedExecutor {
        fn run_node_batch(&self, node_ids: &[u32]) -> crate::error::Result<Vec<Vec<f32>>> {
            std::thread::sleep(self.latency);
            let v = self.version.load(Ordering::SeqCst) as f32;
            Ok(node_ids.iter().map(|_| vec![v]).collect())
        }
        fn run_graph_batch(
            &self,
            graphs: &[&SmallGraph],
        ) -> crate::error::Result<Vec<Vec<f32>>> {
            Ok(graphs.iter().map(|_| vec![0.0]).collect())
        }
        fn apply_delta(
            &self,
            _delta: &crate::graph::delta::GraphDelta,
        ) -> crate::error::Result<super::super::executor::DeltaReport> {
            std::thread::sleep(self.latency);
            let epoch = self.version.fetch_add(1, Ordering::SeqCst) + 1;
            Ok(super::super::executor::DeltaReport {
                epoch,
                num_nodes: 8,
                recomputed_rows: 1,
                new_nodes: 0,
                shards_touched: 0,
                halo_nodes: 0,
            })
        }
        fn capacity(&self) -> (usize, usize) {
            (1024, 16)
        }
        fn out_dim(&self) -> usize {
            1
        }
    }

    #[test]
    fn update_then_classify_never_serves_stale_logits() {
        let mut c = Coordinator::new();
        c.add_model(
            "dyn",
            Arc::new(VersionedExecutor::new(Duration::ZERO)),
            batcher_cfg(),
        );
        for i in 1..=5u64 {
            let resp = c
                .submit_blocking(
                    "dyn",
                    Payload::UpdateGraph(crate::graph::delta::GraphDelta::default()),
                )
                .unwrap();
            assert!(resp.predictions.is_empty(), "updates carry no predictions");
            // a classify admitted after the update's reply must see it
            let resp = c
                .submit_blocking("dyn", Payload::ClassifyNodes(vec![0]))
                .unwrap();
            assert!(
                resp.predictions[0].output[0] >= i as f32,
                "stale logits: saw {} after update {i}",
                resp.predictions[0].output[0]
            );
        }
        assert_eq!(c.metrics().updates, 5);
        c.shutdown();
    }

    #[test]
    fn interleaved_updates_and_classifies_under_overload_account_exactly_once() {
        // tiny queue + slow executor forces overload rejections while a
        // mutator interleaves updates: the invariants are (1) a classify
        // admitted after update i completed never reports a version < i,
        // and (2) every submit is counted exactly once as admitted or
        // rejected, with every admitted request answered exactly once.
        let mut cfg = batcher_cfg();
        cfg.queue_cap = 2;
        cfg.max_wait = Duration::from_micros(200);
        let mut c = Coordinator::new();
        c.add_model(
            "dyn",
            Arc::new(VersionedExecutor::new(Duration::from_micros(400))),
            cfg,
        );
        let c = Arc::new(c);
        let completed_updates = Arc::new(std::sync::atomic::AtomicU64::new(0));

        let mut joins = Vec::new();
        {
            // the mutating client
            let c = Arc::clone(&c);
            let completed = Arc::clone(&completed_updates);
            joins.push(thread::spawn(move || {
                let (mut ok, mut rejected) = (0u64, 0u64);
                for _ in 0..30 {
                    match c.submit(
                        "dyn",
                        Payload::UpdateGraph(crate::graph::delta::GraphDelta::default()),
                    ) {
                        Ok(rx) => {
                            let resp = rx.recv().expect("runner alive").expect("update ok");
                            assert!(resp.predictions.is_empty());
                            completed.fetch_add(1, Ordering::SeqCst);
                            ok += 1;
                        }
                        Err(_) => rejected += 1,
                    }
                }
                (ok, rejected, 0u64)
            }));
        }
        for t in 0..3 {
            let c = Arc::clone(&c);
            let completed = Arc::clone(&completed_updates);
            joins.push(thread::spawn(move || {
                let (mut ok, mut rejected, mut stale) = (0u64, 0u64, 0u64);
                for i in 0..40 {
                    let floor = completed.load(Ordering::SeqCst);
                    match c.submit("dyn", Payload::ClassifyNodes(vec![(t * 40 + i) as u32])) {
                        Ok(rx) => {
                            let resp = rx.recv().expect("runner alive").expect("classify ok");
                            ok += 1;
                            if resp.predictions[0].output[0] < floor as f32 {
                                stale += 1;
                            }
                        }
                        Err(_) => rejected += 1,
                    }
                }
                (ok, rejected, stale)
            }));
        }
        let (mut admitted, mut rejected, mut stale) = (0u64, 0u64, 0u64);
        for j in joins {
            let (ok, rej, st) = j.join().unwrap();
            admitted += ok;
            rejected += rej;
            stale += st;
        }
        assert_eq!(stale, 0, "served logits older than a completed update");
        assert_eq!(admitted + rejected, 30 + 3 * 40, "every submit counted once");
        let snap = c.metrics();
        assert_eq!(snap.requests, admitted, "admitted counted exactly once");
        assert_eq!(snap.rejected, rejected, "rejected counted exactly once");
        assert_eq!(snap.responses, admitted, "every admitted request answered");
        assert_eq!(snap.errors, 0);
        Arc::try_unwrap(c).ok().map(|c| c.shutdown());
    }

    /// Fails every batch until healed — drives the breaker open through
    /// real runner traffic (errors, not panics: the runner itself lives).
    struct FlakyExecutor {
        healthy: AtomicBool,
    }

    impl BatchExecutor for FlakyExecutor {
        fn run_node_batch(&self, node_ids: &[u32]) -> crate::error::Result<Vec<Vec<f32>>> {
            if !self.healthy.load(Ordering::SeqCst) {
                return Err(Error::coordinator("induced executor failure"));
            }
            Ok(node_ids.iter().map(|_| vec![1.0, 0.0]).collect())
        }
        fn run_graph_batch(
            &self,
            graphs: &[&SmallGraph],
        ) -> crate::error::Result<Vec<Vec<f32>>> {
            Ok(graphs.iter().map(|_| vec![1.0, 0.0]).collect())
        }
        fn capacity(&self) -> (usize, usize) {
            (1024, 16)
        }
        fn out_dim(&self) -> usize {
            2
        }
    }

    /// Circuit breaker over live coordinator traffic: consecutive failed
    /// batches open it (fast `BreakerOpen` rejections with a retry hint),
    /// and once the executor heals, the half-open probe closes it again.
    #[test]
    fn breaker_opens_under_failing_executor_and_recovers() {
        let mut c = Coordinator::new();
        c.set_supervision(SuperviseConfig {
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(50),
            ..SuperviseConfig::default()
        });
        let exec = Arc::new(FlakyExecutor {
            healthy: AtomicBool::new(false),
        });
        c.add_model(
            "flaky",
            Arc::clone(&exec) as Arc<dyn BatchExecutor>,
            batcher_cfg(),
        );
        assert_eq!(c.breaker_state("flaky"), Some("closed"));
        // serialized failing submits: each is its own batch, so three
        // consecutive failures open the breaker
        let mut saw_breaker_rejection = false;
        for i in 0..50 {
            match c.try_submit("flaky", Payload::ClassifyNodes(vec![0])) {
                Ok(rx) => {
                    let out = rx.recv().expect("runner alive");
                    assert!(out.is_err(), "unhealed executor replied ok");
                }
                Err(rej) => match rej.reason {
                    RejectReason::BreakerOpen { retry_after_ms } => {
                        assert!(retry_after_ms >= 1, "hint must be actionable");
                        saw_breaker_rejection = true;
                        break;
                    }
                    other => panic!("unexpected rejection {other:?} at submit {i}"),
                },
            }
        }
        assert!(saw_breaker_rejection, "breaker never opened");
        assert_eq!(c.breaker_state("flaky"), Some("open"));
        let snap = c.metrics();
        assert!(snap.breaker_opens >= 1);
        assert!(snap.breaker_rejected >= 1);
        assert_eq!(
            snap.breaker_states,
            vec![("flaky".to_string(), "open".to_string())]
        );

        // heal, wait out the cooldown: the next submit is the half-open
        // probe and its success closes the breaker
        exec.healthy.store(true, Ordering::SeqCst);
        thread::sleep(Duration::from_millis(60));
        let resp = c
            .submit_blocking("flaky", Payload::ClassifyNodes(vec![1]))
            .expect("probe after cooldown should be admitted and succeed");
        assert_eq!(resp.predictions.len(), 1);
        // the probe's batch result lands just after its reply; poll briefly
        let deadline = Instant::now() + Duration::from_secs(2);
        while c.breaker_state("flaky") != Some("closed") && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(c.breaker_state("flaky"), Some("closed"));
        // service is back to normal
        let resp = c
            .submit_blocking("flaky", Payload::ClassifyNodes(vec![2, 3]))
            .unwrap();
        assert_eq!(resp.predictions.len(), 2);
        c.shutdown();
    }

    /// Hot weight swap under live coordinator traffic: every classify
    /// reply is served whole from either the old or the new weights —
    /// never a mixture — and the epoch bumps exactly once.
    #[test]
    fn hot_swap_under_coordinator_traffic_is_atomic() {
        use crate::coordinator::executor::{synthetic_node_session, NativeExecutor};
        use crate::util::threadpool::ParallelConfig;

        let (model, ds) = synthetic_node_session(24, 7).unwrap();
        let exec = Arc::new(
            NativeExecutor::new(model.clone(), Some(&ds))
                .unwrap()
                .with_parallelism(ParallelConfig::serial()),
        );
        let all: Vec<u32> = (0..24).collect();
        let before = exec.run_node_batch(&all).unwrap();

        let mut v2 = model.clone();
        v2.name = "synthetic-gcn-v2".into();
        for w in v2.layers[0].w.as_mut().unwrap().data.iter_mut() {
            *w = -*w;
        }
        // reference: the same swap on an idle twin session pins the
        // expected post-swap bits
        let after = {
            let solo = NativeExecutor::new(model, Some(&ds))
                .unwrap()
                .with_parallelism(ParallelConfig::serial());
            solo.hot_swap(v2.clone()).unwrap();
            solo.run_node_batch(&all).unwrap()
        };
        assert_ne!(before, after);

        let mut c = Coordinator::new();
        c.add_model(
            "live",
            Arc::clone(&exec) as Arc<dyn BatchExecutor>,
            batcher_cfg(),
        );
        let c = Arc::new(c);
        let mut joins = Vec::new();
        for _ in 0..4 {
            let c = Arc::clone(&c);
            let all = all.clone();
            let before = before.clone();
            let after = after.clone();
            joins.push(thread::spawn(move || {
                let mut served_new = 0u64;
                for _ in 0..30 {
                    let resp = c
                        .submit_blocking("live", Payload::ClassifyNodes(all.clone()))
                        .expect("classify under swap");
                    let rows: Vec<Vec<f32>> =
                        resp.predictions.iter().map(|p| p.output.clone()).collect();
                    if rows == after {
                        served_new += 1;
                    } else {
                        assert_eq!(rows, before, "torn batch under hot swap");
                    }
                }
                served_new
            }));
        }
        // swap mid-traffic
        thread::sleep(Duration::from_millis(2));
        let report = exec.hot_swap(v2).unwrap();
        assert_eq!(report.epoch, 1, "exactly one bump under traffic");
        let _served_new: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();
        assert_eq!(exec.epoch(), 1, "no second bump ever happened");
        // the swap is visible to everything admitted from now on
        let resp = c
            .submit_blocking("live", Payload::ClassifyNodes(all.clone()))
            .unwrap();
        let rows: Vec<Vec<f32>> = resp.predictions.iter().map(|p| p.output.clone()).collect();
        assert_eq!(rows, after);
        Arc::try_unwrap(c).ok().map(|c| c.shutdown());
    }
}
