//! Execution backends behind the coordinator.
//!
//! * [`PjrtExecutor`] — runs the AOT HLO artifact through `runtime::Engine`
//!   (the production path: python never touched).
//! * [`NativeExecutor`] — pure-rust integer/fp path (`gnn::infer`), used as
//!   a cross-check backend and for environments without the PJRT library.
//! * [`MockExecutor`] — deterministic fake for coordinator unit tests.
//!
//! Both real executors are **prepared sessions**: everything derivable
//! from the loaded model alone is computed at construction
//! ([`gnn::PreparedModel`], the resident graph's
//! [`AggregationPlan`]), and full-graph node-level logits are cached under
//! an explicit **epoch** version — `run_node_batch` is a slice-copy after
//! the first batch of an epoch, and [`NativeExecutor::bump_epoch`] /
//! [`PjrtExecutor::bump_epoch`] invalidate the cache when a future weight
//! or feature swap mutates the resident state.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::gnn::{
    forward_fp_prepared_with_plan, forward_int_prepared_with_plan, GnnModel, GraphInput,
    PreparedModel,
};
use crate::graph::batch::GraphBatch;
use crate::graph::io::{Dataset, NodeData, SmallGraph};
use crate::graph::norm::{AggregationPlan, EdgeForm};
use crate::runtime::engine::EngineHandle;
use crate::runtime::{ExecInput, ModelArtifact};
use crate::tensor::Matrix;
use crate::util::threadpool::ParallelConfig;

/// A backend able to run the two batch kinds.
pub trait BatchExecutor: Send + Sync {
    /// Full-graph node classification; returns per-queried-node logits.
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;
    /// Batched graph-level prediction; returns per-graph outputs.
    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>>;
    /// Executable batch capacity (nodes, graph slots); node-level models
    /// report (N, 0).
    fn capacity(&self) -> (usize, usize);
    fn out_dim(&self) -> usize;
}

/// Versioned full-graph logits cache: the resident graph and model are
/// immutable within an epoch, so the full forward runs once per epoch and
/// every subsequent node batch is a row slice-copy.
struct LogitsCache<T> {
    epoch: AtomicU64,
    slot: Mutex<Option<(u64, Arc<T>)>>,
}

impl<T> LogitsCache<T> {
    fn new() -> Self {
        LogitsCache {
            epoch: AtomicU64::new(0),
            slot: Mutex::new(None),
        }
    }

    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn bump(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Fetch the cached value for the current epoch, computing (outside the
    /// lock) and installing it on miss.  A concurrent [`Self::bump`] during
    /// compute keeps the stale result out of the cache — the caller still
    /// gets the value it computed.
    fn get_or_compute(&self, compute: impl FnOnce() -> Result<T>) -> Result<Arc<T>> {
        let epoch = self.epoch();
        if let Some((e, cached)) = self.slot.lock().unwrap().as_ref() {
            if *e == epoch {
                return Ok(Arc::clone(cached));
            }
        }
        let value = Arc::new(compute()?);
        let mut guard = self.slot.lock().unwrap();
        if self.epoch() == epoch {
            *guard = Some((epoch, Arc::clone(&value)));
        }
        Ok(value)
    }
}

// ---------------------------------------------------------------------------
// PJRT
// ---------------------------------------------------------------------------

/// Runs the compiled HLO artifact (via the engine service thread).
pub struct PjrtExecutor {
    engine: EngineHandle,
    key: String,
    node: Option<NodeSide>,
    graph_caps: Option<(usize, usize, usize)>, // (nodes, edges, graphs)
    feat_dim: usize,
    out_dim: usize,
    /// surviving logical parameter indices (XLA drops unused entry params)
    param_map: Vec<usize>,
    /// weight tensors appended after the data inputs (manifest order)
    weight_inputs: Vec<ExecInput>,
    /// versioned full-graph logits (node-level serving hot path)
    logits: LogitsCache<Vec<f32>>,
}

struct NodeSide {
    features: Vec<f32>,
    edges: EdgeForm,
    num_nodes: usize,
}

impl PjrtExecutor {
    /// Build from an artifact + its dataset (node-level needs the resident
    /// graph; graph-level needs only capacities).
    pub fn new(
        engine: EngineHandle,
        artifact: &ModelArtifact,
        dataset: Option<&Dataset>,
    ) -> Result<PjrtExecutor> {
        engine.load_artifact(artifact)?;
        let param_map = artifact.param_map()?;
        let weight_inputs = artifact.weight_inputs()?;
        let mut node = None;
        let mut graph_caps = None;
        if artifact.node_level {
            let ds = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(NodeSide {
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        } else {
            graph_caps = Some((
                artifact.num_nodes,
                artifact.num_edges,
                artifact.graph_capacity,
            ));
        }
        Ok(PjrtExecutor {
            engine,
            key: artifact.name.clone(),
            node,
            graph_caps,
            feat_dim: artifact.in_dim,
            out_dim: artifact.out_dim,
            param_map,
            weight_inputs,
            logits: LogitsCache::new(),
        })
    }

    /// Append the weight parameters, then keep only the logical inputs the
    /// compiled program still expects (XLA drops unused entry params).
    fn select_params(&self, data: Vec<ExecInput>) -> Vec<ExecInput> {
        let mut logical: Vec<Option<ExecInput>> = data
            .into_iter()
            .chain(self.weight_inputs.iter().cloned())
            .map(Some)
            .collect();
        self.param_map
            .iter()
            .filter_map(|&l| logical.get_mut(l).and_then(|slot| slot.take()))
            .collect()
    }

    fn logits_full_graph(&self) -> Result<Vec<f32>> {
        let side = self
            .node
            .as_ref()
            .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(side.features.clone(), side.num_nodes, self.feat_dim),
            ExecInput::i32_1d(side.edges.src.clone()),
            ExecInput::i32_1d(side.edges.dst.clone()),
            ExecInput::f32_1d(side.edges.gcn_w.clone()),
            ExecInput::f32_1d(side.edges.sum_w.clone()),
        ]);
        self.engine.execute(&self.key, inputs)
    }

    /// Invalidate the full-graph logits cache (call after swapping the
    /// resident weights or features on the engine side).
    pub fn bump_epoch(&self) {
        self.logits.bump();
    }

    /// Current logits-cache epoch (diagnostics).
    pub fn epoch(&self) -> u64 {
        self.logits.epoch()
    }
}

impl BatchExecutor for PjrtExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        // PJRT execution of the full graph is identical for every node
        // batch of an epoch — serve subsequent batches from the cache.
        let logits = self.logits.get_or_compute(|| self.logits_full_graph())?;
        let c = self.out_dim;
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if (v + 1) * c > logits.len() {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits[v * c..(v + 1) * c].to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let (cap_n, cap_e, cap_g) = self
            .graph_caps
            .ok_or_else(|| Error::coordinator("not a graph-level executor"))?;
        let batch = GraphBatch::pack(graphs, self.feat_dim, cap_n, cap_e, cap_g)?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(batch.features, cap_n, self.feat_dim),
            ExecInput::i32_1d(batch.src),
            ExecInput::i32_1d(batch.dst),
            ExecInput::f32_1d(batch.gcn_w),
            ExecInput::f32_1d(batch.sum_w),
            ExecInput::i32_1d(batch.node2graph),
            ExecInput::f32_1d(batch.node_mask),
        ]);
        let out = self.engine.execute(&self.key, inputs)?;
        let c = self.out_dim;
        Ok((0..graphs.len()).map(|g| out[g * c..(g + 1) * c].to_vec()).collect())
    }

    fn capacity(&self) -> (usize, usize) {
        match (&self.node, self.graph_caps) {
            (Some(n), _) => (n.num_nodes, 0),
            (None, Some((n, _e, g))) => (n, g),
            _ => (0, 0),
        }
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

// ---------------------------------------------------------------------------
// Native
// ---------------------------------------------------------------------------

/// Pure-rust backend over `gnn::infer` (fp emulation by default, true
/// integer path opt-in), holding a prepared session: quantized weights,
/// integer codes, and NNS tables are computed once in [`Self::new`], the
/// resident graph's [`AggregationPlan`] is built once, and full-graph
/// node-level logits are cached per epoch.  Carries its own
/// [`ParallelConfig`] so the serving stack controls the intra-op
/// parallelism budget per executor.
pub struct NativeExecutor {
    prepared: PreparedModel,
    node: Option<NodeSide>,
    caps: (usize, usize, usize),
    parallel: ParallelConfig,
    use_int_path: bool,
    /// destination-grouped plan of the resident graph (node-level gcn/gin)
    resident_plan: Option<AggregationPlan>,
    /// versioned full-graph logits (node-level serving hot path)
    logits: LogitsCache<Matrix<f32>>,
}

impl NativeExecutor {
    /// Prepare a serving session from a loaded model.  This is the
    /// model-load validation boundary: malformed static state (missing
    /// layer tensors, non-finite or mismatched quant steps, empty NNS
    /// tables) is rejected here instead of panicking on the first request.
    pub fn new(model: GnnModel, dataset: Option<&Dataset>) -> Result<NativeExecutor> {
        let mut node = None;
        if model.node_level {
            let ds: &NodeData = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(NodeSide {
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        }
        let prepared = PreparedModel::prepare(model)?;
        let model = &prepared.model;
        let caps = (
            model.num_nodes,
            model
                .manifest
                .get("num_edges")
                .and_then(|v| v.as_usize())
                .unwrap_or(model.num_nodes * 8),
            model.graph_capacity.max(1),
        );
        let resident_plan = node.as_ref().and_then(|side: &NodeSide| {
            (model.arch != "gat")
                .then(|| AggregationPlan::build(&side.edges.dst, side.edges.num_nodes))
        });
        Ok(NativeExecutor {
            prepared,
            node,
            caps,
            parallel: ParallelConfig::from_env(),
            use_int_path: false,
            resident_plan,
            logits: LogitsCache::new(),
        })
    }

    /// Set the intra-op parallelism budget (builder style).
    pub fn with_parallelism(mut self, cfg: ParallelConfig) -> NativeExecutor {
        self.parallel = cfg;
        self
    }

    /// Route through `forward_int` (true integer arithmetic over packed
    /// codes) instead of the fp emulation.
    pub fn with_int_path(mut self, on: bool) -> NativeExecutor {
        self.use_int_path = on;
        self
    }

    pub fn parallelism(&self) -> ParallelConfig {
        self.parallel
    }

    /// The prepared session this executor serves from.
    pub fn prepared(&self) -> &PreparedModel {
        &self.prepared
    }

    /// The retained model metadata (note: raw layer weight tensors are
    /// released at preparation — the prepared matrices are the serving
    /// source of truth).
    pub fn model(&self) -> &GnnModel {
        &self.prepared.model
    }

    /// Invalidate the full-graph logits cache.  Call after a weight or
    /// resident-feature swap; the next node batch recomputes under the new
    /// epoch while in-flight batches keep serving the old one.
    pub fn bump_epoch(&self) {
        self.logits.bump();
    }

    /// Current logits-cache epoch (diagnostics).
    pub fn epoch(&self) -> u64 {
        self.logits.epoch()
    }

    fn forward(&self, input: &GraphInput, plan: Option<&AggregationPlan>) -> Matrix<f32> {
        if self.use_int_path {
            forward_int_prepared_with_plan(&self.prepared, input, plan, &self.parallel)
        } else {
            forward_fp_prepared_with_plan(&self.prepared, input, plan, &self.parallel)
        }
    }

    fn full_graph_logits(&self) -> Result<Arc<Matrix<f32>>> {
        let side = self
            .node
            .as_ref()
            .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
        self.logits.get_or_compute(|| {
            let input =
                GraphInput::node_level(&side.features, self.prepared.model.in_dim, &side.edges);
            Ok(self.forward(&input, self.resident_plan.as_ref()))
        })
    }
}

impl BatchExecutor for NativeExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        // full forward once per epoch; every batch after that is a
        // row slice-copy off the cached logits
        let logits = self.full_graph_logits()?;
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if v >= logits.rows {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits.row(v).to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let (cap_n, cap_e, cap_g) = self.caps;
        let batch = GraphBatch::pack(graphs, self.prepared.model.in_dim, cap_n, cap_e, cap_g)?;
        let input = GraphInput::batch(&batch);
        // client-supplied edges differ per batch, so no resident plan here
        let out = self.forward(&input, None);
        Ok((0..graphs.len()).map(|g| out.row(g).to_vec()).collect())
    }

    fn capacity(&self) -> (usize, usize) {
        if self.prepared.model.node_level {
            (self.caps.0, 0)
        } else {
            (self.caps.0, self.caps.2)
        }
    }

    fn out_dim(&self) -> usize {
        self.prepared.model.out_dim
    }
}

// ---------------------------------------------------------------------------
// Mock
// ---------------------------------------------------------------------------

/// Deterministic test double: returns node id / node count as "logits",
/// optionally sleeping to emulate execution latency.
pub struct MockExecutor {
    pub out_dim: usize,
    pub latency: std::time::Duration,
}

impl Default for MockExecutor {
    fn default() -> Self {
        MockExecutor {
            out_dim: 2,
            latency: std::time::Duration::ZERO,
        }
    }
}

impl BatchExecutor for MockExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(node_ids
            .iter()
            .map(|&v| {
                let mut out = vec![0.0; self.out_dim];
                out[v as usize % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(graphs
            .iter()
            .map(|g| {
                let mut out = vec![0.0; self.out_dim];
                out[g.num_nodes() % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn capacity(&self) -> (usize, usize) {
        (1024, 16)
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::{forward_fp_with, LayerParams, QuantMethod};
    use crate::graph::csr::Csr;
    use crate::quant::mixed::NodeQuantParams;
    use crate::util::json::Json;

    #[test]
    fn mock_is_deterministic() {
        let m = MockExecutor::default();
        let out = m.run_node_batch(&[0, 1, 2]).unwrap();
        assert_eq!(out[0], vec![1.0, 0.0]);
        assert_eq!(out[1], vec![0.0, 1.0]);
        assert_eq!(out[2], vec![1.0, 0.0]);
    }

    fn tiny_session() -> (GnnModel, Dataset) {
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.5, 1.0]).unwrap();
        let model = GnnModel {
            name: "tiny".into(),
            arch: "gcn".into(),
            dataset: "unit".into(),
            method: QuantMethod::A2q,
            layers: vec![LayerParams {
                w: Some(w),
                b: vec![0.1, -0.1],
                w_steps: vec![0.05, 0.05],
                feat: Some(NodeQuantParams::new(vec![0.1; 3], vec![4; 3], true).unwrap()),
                ..Default::default()
            }],
            head: None,
            dq_steps: vec![],
            skip_input_quant: false,
            node_level: true,
            num_nodes: 3,
            in_dim: 2,
            out_dim: 2,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        };
        let csr = Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap();
        let ds = Dataset::Node(NodeData {
            name: "unit".into(),
            csr,
            num_features: 2,
            num_classes: 2,
            features: vec![0.3, -0.2, 0.15, 0.4, -0.35, 0.05],
            labels: vec![0, 1, 0],
            train_mask: vec![false; 3],
            val_mask: vec![false; 3],
            test_mask: vec![false; 3],
        });
        (model, ds)
    }

    #[test]
    fn native_cached_batches_match_unprepared_forward() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let Dataset::Node(nd) = &ds else { unreachable!() };
        let ef = EdgeForm::from_csr(&nd.csr);
        let input = GraphInput::node_level(&nd.features, 2, &ef);
        let want = forward_fp_with(&model, &input, &ParallelConfig::serial());

        // first batch computes + caches, second serves from the cache —
        // both bitwise identical to the per-call shim
        for _ in 0..2 {
            let out = exec.run_node_batch(&[0, 1, 2]).unwrap();
            for (v, row) in out.iter().enumerate() {
                assert_eq!(row.as_slice(), want.row(v));
            }
        }
        assert_eq!(exec.epoch(), 0);
    }

    #[test]
    fn native_epoch_bump_invalidates_but_stays_consistent() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let before = exec.run_node_batch(&[0, 2]).unwrap();
        exec.bump_epoch();
        assert_eq!(exec.epoch(), 1);
        // immutable state ⇒ recompute under the new epoch is identical
        let after = exec.run_node_batch(&[0, 2]).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn native_out_of_range_node_is_an_error_not_a_panic() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let err = exec.run_node_batch(&[99]).unwrap_err();
        assert!(format!("{err}").contains("out of range"));
    }

    #[test]
    fn native_rejects_malformed_model_at_construction() {
        let (mut model, ds) = tiny_session();
        model.layers[0].w = None;
        let err = NativeExecutor::new(model, Some(&ds)).unwrap_err();
        assert!(format!("{err}").contains("missing w"));
    }
}
