//! Execution backends behind the coordinator.
//!
//! * [`PjrtExecutor`] — runs the AOT HLO artifact through `runtime::Engine`
//!   (the production path: python never touched).
//! * [`NativeExecutor`] — pure-rust integer/fp path (`gnn::infer`), used as
//!   a cross-check backend and for environments without the PJRT library.
//! * [`MockExecutor`] — deterministic fake for coordinator unit tests.
//!
//! Both real executors are **prepared sessions**: everything derivable
//! from the loaded model alone is computed at construction
//! ([`gnn::PreparedModel`], the resident graph's
//! [`AggregationPlan`]), and full-graph node-level logits are cached under
//! an explicit **epoch** version — `run_node_batch` is a slice-copy after
//! the first batch of an epoch, and [`NativeExecutor::bump_epoch`] /
//! [`PjrtExecutor::bump_epoch`] invalidate the cache when a weight or
//! feature swap mutates the resident state.
//!
//! [`NativeExecutor::apply_delta`] is the **dynamic-graph serving path**:
//! a [`GraphDelta`] is applied incrementally (CSR row repair, GCN-weight
//! splice, sort-free plan reconstruction — all bitwise-identical to a
//! from-scratch rebuild), unseen nodes get their quantization parameters
//! assigned online through the paper's NNS, the epoch bumps exactly once,
//! and only the delta's L-hop reverse frontier of logits rows is
//! recomputed against the resident per-layer activation cache — untouched
//! rows survive the epoch change bit-for-bit.
//!
//! [`NativeExecutor::with_shards`] turns a node-level session into a
//! **sharded resident**: the graph is partitioned degree-aware
//! (`graph::shard`), epoch recomputes run shard-parallel with a
//! halo-exchange step between layers (`gnn::forward_{fp,int}_sharded`,
//! bitwise identical to the single-shard path), node batches are served
//! from per-shard logits blocks, and `apply_delta` rebuilds only the
//! owning shards' local views — the epoch bump stays exactly-once and
//! atomic *across* shards.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::error::{Error, Result};
use crate::gnn::incremental::{build_assign_tables, patch_activations, NnsAssignTables};
use crate::gnn::{
    forward_fp_prepared_recording, forward_fp_prepared_with_plan, forward_fp_sharded,
    forward_fp_sharded_recording, forward_int_prepared_recording,
    forward_int_prepared_with_plan, forward_int_sharded, forward_int_sharded_recording,
    GnnModel, GraphInput, PreparedModel,
};
use crate::graph::batch::GraphBatch;
use crate::graph::csr::Csr;
use crate::graph::delta::{dirty_frontier, GraphDelta};
use crate::graph::io::{Dataset, NodeData, SmallGraph};
use crate::graph::norm::{AggregationPlan, EdgeForm};
use crate::graph::shard::{HaloStats, ShardedGraph};
use crate::quant::mixed::NodeQuantParams;
use crate::runtime::engine::EngineHandle;
use crate::runtime::persist::{
    PersistConfig, Persistence, Snapshot, SnapshotLayer, SnapshotParams,
};
use crate::runtime::{ExecInput, ModelArtifact};
use crate::tensor::Matrix;
use crate::util::threadpool::ParallelConfig;

/// Outcome of one applied [`GraphDelta`].
#[derive(Debug, Clone)]
pub struct DeltaReport {
    /// logits-cache epoch after the update (bumps exactly once per delta)
    pub epoch: u64,
    /// resident node count after the update
    pub num_nodes: usize,
    /// final-layer logits rows recomputed (the L-hop reverse frontier)
    pub recomputed_rows: usize,
    /// nodes appended (each got NNS-assigned quantization parameters)
    pub new_nodes: usize,
    /// sharded residents: shards whose local view was rebuilt (owners of
    /// dirty rows + shards mirroring a degree-changed node); 0 unsharded
    pub shards_touched: usize,
    /// sharded residents: Σ mirrored halo nodes after the update; 0
    /// unsharded
    pub halo_nodes: usize,
}

/// Outcome of attaching durable state ([`NativeExecutor::with_persistence`]):
/// what crash recovery found on disk and where it left the session.
#[derive(Debug, Clone)]
pub struct RestoreReport {
    /// a snapshot was found and installed
    pub restored_snapshot: bool,
    /// epoch the snapshot was taken at (0 when none)
    pub snapshot_epoch: u64,
    /// WAL-tail deltas replayed on top of the snapshot
    pub replayed_deltas: usize,
    /// torn/corrupt bytes dropped off the WAL tail
    pub dropped_bytes: u64,
    /// human-readable reason the tail was dropped, if it was
    pub dropped_note: Option<String>,
    /// logits-cache epoch after recovery (snapshot epoch + one bump per
    /// replayed delta — matches the continuous session)
    pub epoch: u64,
    /// resident node count after recovery
    pub num_nodes: usize,
}

/// Outcome of one [`NativeExecutor::hot_swap`].
#[derive(Debug, Clone)]
pub struct SwapReport {
    /// logits-cache epoch after the swap (bumps exactly once per swap)
    pub epoch: u64,
    /// name of the model now serving
    pub model_name: String,
    /// resident-size accounting of the freshly prepared session in bytes
    pub prepared_bytes: usize,
    /// durable sessions: the post-swap snapshot landed (`false` means the
    /// swap is live in memory but NOT durable — see the persistence note)
    pub snapshot_installed: bool,
}

/// A backend able to run the two batch kinds.
pub trait BatchExecutor: Send + Sync {
    /// Full-graph node classification; returns per-queried-node logits.
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;
    /// Batched graph-level prediction; returns per-graph outputs.
    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>>;
    /// Mutate the resident graph.  Backends without a mutable resident
    /// graph keep this default rejection.
    fn apply_delta(&self, _delta: &GraphDelta) -> Result<DeltaReport> {
        Err(Error::coordinator(
            "this executor does not support resident-graph updates",
        ))
    }
    /// Executable batch capacity (nodes, graph slots); node-level models
    /// report (N, 0).
    fn capacity(&self) -> (usize, usize);
    fn out_dim(&self) -> usize;
}

/// Versioned full-graph logits cache: the resident graph and model are
/// immutable within an epoch, so the full forward runs once per epoch and
/// every subsequent node batch is a row slice-copy.
struct LogitsCache<T> {
    epoch: AtomicU64,
    slot: Mutex<Option<(u64, Arc<T>)>>,
}

impl<T> LogitsCache<T> {
    fn new() -> Self {
        LogitsCache {
            epoch: AtomicU64::new(0),
            slot: Mutex::new(None),
        }
    }

    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn bump(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Lock the cache slot — the one audited lock acquisition.
    fn locked(&self) -> MutexGuard<'_, Option<(u64, Arc<T>)>> {
        // a2q-lint: allow(panic-path) poisoning requires a prior panic while
        // holding this short-lived lock; there is no state to salvage
        self.slot.lock().unwrap()
    }

    /// Fetch the cached value for the current epoch, computing (outside the
    /// lock) and installing it on miss.  The closure receives the epoch
    /// the computation is for.  A concurrent [`Self::bump`] during compute
    /// keeps the stale result out of the cache — the caller still gets the
    /// value it computed.
    fn get_or_compute(&self, compute: impl FnOnce(u64) -> Result<T>) -> Result<Arc<T>> {
        let epoch = self.epoch();
        if let Some((e, cached)) = self.locked().as_ref() {
            if *e == epoch {
                return Ok(Arc::clone(cached));
            }
        }
        let value = Arc::new(compute(epoch)?);
        let mut guard = self.locked();
        if self.epoch() == epoch {
            *guard = Some((epoch, Arc::clone(&value)));
        }
        Ok(value)
    }

    /// Install a value for `epoch` (no-op if the epoch already moved on) —
    /// the partial-invalidation path primes the new epoch with its patched
    /// logits so the next batch is a slice-copy, not a recompute.
    fn set(&self, epoch: u64, value: Arc<T>) {
        let mut guard = self.locked();
        if self.epoch() == epoch {
            *guard = Some((epoch, value));
        }
    }

    /// Crash recovery: pin the counter to the snapshot's epoch and drop any
    /// cached value.  Each replayed delta then bumps exactly as the
    /// continuous session did, so the recovered epoch matches it.
    fn restore_epoch(&self, epoch: u64) {
        let mut guard = self.locked();
        *guard = None;
        self.epoch.store(epoch, Ordering::Release);
    }
}

// ---------------------------------------------------------------------------
// PJRT
// ---------------------------------------------------------------------------

/// Runs the compiled HLO artifact (via the engine service thread).
pub struct PjrtExecutor {
    engine: EngineHandle,
    key: String,
    node: Option<PjrtNodeSide>,
    graph_caps: Option<(usize, usize, usize)>, // (nodes, edges, graphs)
    feat_dim: usize,
    out_dim: usize,
    /// surviving logical parameter indices (XLA drops unused entry params)
    param_map: Vec<usize>,
    /// weight tensors appended after the data inputs (manifest order)
    weight_inputs: Vec<ExecInput>,
    /// versioned full-graph logits (node-level serving hot path)
    logits: LogitsCache<Vec<f32>>,
}

struct PjrtNodeSide {
    features: Vec<f32>,
    edges: EdgeForm,
    num_nodes: usize,
}

impl PjrtExecutor {
    /// Build from an artifact + its dataset (node-level needs the resident
    /// graph; graph-level needs only capacities).
    pub fn new(
        engine: EngineHandle,
        artifact: &ModelArtifact,
        dataset: Option<&Dataset>,
    ) -> Result<PjrtExecutor> {
        engine.load_artifact(artifact)?;
        let param_map = artifact.param_map()?;
        let weight_inputs = artifact.weight_inputs()?;
        let mut node = None;
        let mut graph_caps = None;
        if artifact.node_level {
            let ds = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(PjrtNodeSide {
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        } else {
            graph_caps = Some((
                artifact.num_nodes,
                artifact.num_edges,
                artifact.graph_capacity,
            ));
        }
        Ok(PjrtExecutor {
            engine,
            key: artifact.name.clone(),
            node,
            graph_caps,
            feat_dim: artifact.in_dim,
            out_dim: artifact.out_dim,
            param_map,
            weight_inputs,
            logits: LogitsCache::new(),
        })
    }

    /// Append the weight parameters, then keep only the logical inputs the
    /// compiled program still expects (XLA drops unused entry params).
    fn select_params(&self, data: Vec<ExecInput>) -> Vec<ExecInput> {
        let mut logical: Vec<Option<ExecInput>> = data
            .into_iter()
            .chain(self.weight_inputs.iter().cloned())
            .map(Some)
            .collect();
        self.param_map
            .iter()
            .filter_map(|&l| logical.get_mut(l).and_then(|slot| slot.take()))
            .collect()
    }

    fn logits_full_graph(&self) -> Result<Vec<f32>> {
        let side = self
            .node
            .as_ref()
            .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(side.features.clone(), side.num_nodes, self.feat_dim),
            ExecInput::i32_1d(side.edges.src.clone()),
            ExecInput::i32_1d(side.edges.dst.clone()),
            ExecInput::f32_1d(side.edges.gcn_w.clone()),
            ExecInput::f32_1d(side.edges.sum_w.clone()),
        ]);
        self.engine.execute(&self.key, inputs)
    }

    /// Invalidate the full-graph logits cache (call after swapping the
    /// resident weights or features on the engine side).
    pub fn bump_epoch(&self) {
        self.logits.bump();
    }

    /// Current logits-cache epoch (diagnostics).
    pub fn epoch(&self) -> u64 {
        self.logits.epoch()
    }
}

impl BatchExecutor for PjrtExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        // PJRT execution of the full graph is identical for every node
        // batch of an epoch — serve subsequent batches from the cache.
        let logits = self
            .logits
            .get_or_compute(|_epoch| self.logits_full_graph())?;
        let c = self.out_dim;
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if (v + 1) * c > logits.len() {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits[v * c..(v + 1) * c].to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let (cap_n, cap_e, cap_g) = self
            .graph_caps
            .ok_or_else(|| Error::coordinator("not a graph-level executor"))?;
        let batch = GraphBatch::pack(graphs, self.feat_dim, cap_n, cap_e, cap_g)?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(batch.features, cap_n, self.feat_dim),
            ExecInput::i32_1d(batch.src),
            ExecInput::i32_1d(batch.dst),
            ExecInput::f32_1d(batch.gcn_w),
            ExecInput::f32_1d(batch.sum_w),
            ExecInput::i32_1d(batch.node2graph),
            ExecInput::f32_1d(batch.node_mask),
        ]);
        let out = self.engine.execute(&self.key, inputs)?;
        let c = self.out_dim;
        Ok((0..graphs.len()).map(|g| out[g * c..(g + 1) * c].to_vec()).collect())
    }

    fn capacity(&self) -> (usize, usize) {
        match (&self.node, self.graph_caps) {
            (Some(n), _) => (n.num_nodes, 0),
            (None, Some((n, _e, g))) => (n, g),
            _ => (0, 0),
        }
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

// ---------------------------------------------------------------------------
// Native
// ---------------------------------------------------------------------------

/// Resident graph state of a node-level session.
struct NodeSide {
    csr: Csr,
    features: Vec<f32>,
    edges: EdgeForm,
    num_nodes: usize,
}

/// Sharded resident state: the partitioned graph plus one epoch-tagged
/// logits block per shard (rows in the shard's `owned` order).  Blocks
/// are installed atomically under the state lock with the session's
/// single epoch counter — the epoch bump of a delta is exactly-once
/// *across* shards, never per shard.
struct ShardedState {
    graph: ShardedGraph,
    /// per-shard `LogitsCache` slot: `(epoch, owned-row logits block)`
    logits: Vec<Option<(u64, Arc<Matrix<f32>>)>>,
}

/// Everything [`NativeExecutor::apply_delta`] mutates, behind one lock:
/// prepared model state (per-node quantization parameters grow with the
/// graph), the resident graph, its plan, the per-layer activation cache,
/// the frozen NNS assignment tables, and (sharded sessions) the per-shard
/// local views + logits blocks.
struct Resident {
    prepared: PreparedModel,
    node: Option<NodeSide>,
    /// destination-grouped plan of the resident graph (node-level gcn/gin)
    plan: Option<AggregationPlan>,
    caps: (usize, usize, usize),
    /// per-layer activations of the resident graph, tagged with the
    /// logits-cache epoch they belong to (`acts[0]` input features,
    /// `acts[L]` logits) — what incremental deltas patch
    acts: Option<(u64, Vec<Matrix<f32>>)>,
    /// NNS lookup tables over the originally-learned per-node params,
    /// frozen at the first delta (later deltas must not search previously
    /// assigned copies)
    assign_tables: Option<Vec<NnsAssignTables>>,
    /// sharded resident mode ([`NativeExecutor::with_shards`])
    sharded: Option<ShardedState>,
}

/// Scatter a full `[N, C]` logits matrix into per-shard owned-row blocks
/// tagged with `epoch`.  Untouched rows land bit-identically (the block is
/// a row copy), so a delta's unaffected shards keep serving the same bits.
fn refresh_shard_logits(sh: &mut ShardedState, logits: &Matrix<f32>, epoch: u64) {
    debug_assert_eq!(sh.logits.len(), sh.graph.num_shards());
    for (s, local) in sh.graph.shards.iter().enumerate() {
        let mut block = Matrix::zeros(local.owned.len(), logits.cols);
        for (li, &gid) in local.owned.iter().enumerate() {
            block.row_mut(li).copy_from_slice(logits.row(gid as usize));
        }
        sh.logits[s] = Some((epoch, Arc::new(block)));
    }
}

/// Frontier-proportional alternative to [`refresh_shard_logits`] for the
/// delta patch path: rows outside the recomputed `frontier` are
/// bit-identical across the epoch (the partial-invalidation invariant),
/// so only frontier rows are rewritten in place and blocks whose shard
/// gained appended nodes grow at the tail (owned lists grow append-only
/// with maximal ids, so existing row positions are stable; the frontier
/// contains every appended node by construction).  Returns `false` —
/// leaving the blocks untouched — when any block is missing or stale for
/// `old_epoch`, in which case the caller falls back to the full scatter.
fn patch_shard_logits(
    sh: &mut ShardedState,
    logits: &Matrix<f32>,
    old_epoch: u64,
    new_epoch: u64,
    frontier: &[u32],
) -> bool {
    debug_assert_eq!(sh.logits.len(), sh.graph.num_shards());
    let patchable = sh.logits.iter().zip(&sh.graph.shards).all(|(b, local)| {
        matches!(b, Some((e, blk))
            if *e == old_epoch
                && blk.cols == logits.cols
                && blk.rows <= local.owned.len())
    });
    if !patchable {
        return false;
    }
    for (slot, local) in sh.logits.iter_mut().zip(&sh.graph.shards) {
        // a2q-lint: allow(panic-path) the patchable scan above proved
        // every slot is Some at old_epoch
        let (e, blk) = slot.as_mut().expect("checked patchable above");
        if blk.rows < local.owned.len() {
            let old = Arc::make_mut(blk);
            let mut grown = Matrix::zeros(local.owned.len(), logits.cols);
            grown.data[..old.data.len()].copy_from_slice(&old.data);
            for (li, &gid) in local.owned.iter().enumerate().skip(old.rows) {
                grown.row_mut(li).copy_from_slice(logits.row(gid as usize));
            }
            *old = grown;
        }
        *e = new_epoch;
    }
    for &v in frontier {
        let (s, pos) = sh.graph.locate(v);
        // a2q-lint: allow(panic-path) the patchable scan above proved
        // every slot is Some at old_epoch
        let (_, blk) = sh.logits[s].as_mut().expect("checked patchable above");
        Arc::make_mut(blk)
            .row_mut(pos)
            .copy_from_slice(logits.row(v as usize));
    }
    true
}

/// Capture the resident mutable state as a [`Snapshot`]: the post-delta
/// graph (CSR + features), the possibly NNS-extended per-node quant
/// params, and the epoch counter.  Weights are deliberately absent —
/// they come from the artifact on disk.
fn snapshot_resident(st: &Resident, epoch: u64) -> Result<Snapshot> {
    let side = st
        .node
        .as_ref()
        .ok_or_else(|| Error::coordinator("snapshots need a node-level session"))?;
    let model = &st.prepared.model;
    let capture = |p: &NodeQuantParams| SnapshotParams {
        steps: p.steps.clone(),
        bits: p.bits.clone(),
        signed: p.signed,
    };
    let layers = model
        .layers
        .iter()
        .map(|l| SnapshotLayer {
            feat: l.feat.as_ref().map(capture),
            feat2: l.feat2.as_ref().map(capture),
        })
        .collect();
    Ok(Snapshot {
        epoch,
        model_name: model.name.clone(),
        arch: model.arch.clone(),
        in_dim: model.in_dim as u32,
        out_dim: model.out_dim as u32,
        num_nodes: side.num_nodes as u64,
        indptr: side.csr.indptr.clone(),
        indices: side.csr.indices.clone(),
        features: side.features.clone(),
        layers,
    })
}

/// Deterministic single-layer A²Q GCN session over a preferential-
/// attachment graph — the shared fixture behind `a2q-serve --synthetic`
/// and the crash-recovery CI leg.  Fully reproducible from
/// `(num_nodes, seed)`, so two processes built from the same pair serve
/// bitwise-identical logits.
pub fn synthetic_node_session(num_nodes: usize, seed: u64) -> Result<(GnnModel, Dataset)> {
    use crate::util::rng::Rng;
    let n = num_nodes.max(4);
    let in_dim = 4;
    let out_dim = 3;
    let mut rng = Rng::new(seed);
    let csr = crate::graph::generate::preferential_attachment(&mut rng, n, 2);
    let features: Vec<f32> = (0..n * in_dim)
        .map(|_| rng.uniform(-1.0, 1.0) as f32)
        .collect();
    let w = Matrix::from_vec(
        in_dim,
        out_dim,
        (0..in_dim * out_dim)
            .map(|_| rng.uniform(-0.5, 0.5) as f32)
            .collect(),
    )?;
    let b: Vec<f32> = (0..out_dim).map(|_| rng.uniform(-0.1, 0.1) as f32).collect();
    let model = GnnModel {
        name: "synthetic-gcn".into(),
        arch: "gcn".into(),
        dataset: "synthetic".into(),
        method: crate::gnn::QuantMethod::A2q,
        layers: vec![crate::gnn::LayerParams {
            w: Some(w),
            b,
            w_steps: vec![0.05; out_dim],
            feat: Some(NodeQuantParams::new(vec![0.1; n], vec![4; n], true)?),
            ..Default::default()
        }],
        head: None,
        dq_steps: Vec::new(),
        skip_input_quant: false,
        node_level: true,
        num_nodes: n,
        in_dim,
        out_dim,
        heads: 1,
        graph_capacity: n * 4,
        accuracy: 0.0,
        avg_bits: 4.0,
        expected_head: Vec::new(),
        manifest: crate::util::json::Json::Null,
    };
    let data = NodeData {
        name: "synthetic".into(),
        csr,
        num_features: in_dim,
        num_classes: out_dim,
        features,
        labels: vec![0; n],
        train_mask: vec![false; n],
        val_mask: vec![false; n],
        test_mask: vec![false; n],
    };
    Ok((model, Dataset::Node(data)))
}

/// Pure-rust backend over `gnn::infer` (fp emulation by default, true
/// integer path opt-in), holding a prepared session: quantized weights,
/// integer codes, and NNS tables are computed once in [`Self::new`], the
/// resident graph's [`AggregationPlan`] is built once, and full-graph
/// node-level logits are cached per epoch.  Carries its own
/// [`ParallelConfig`] so the serving stack controls the intra-op
/// parallelism budget per executor.  [`Self::apply_delta`] mutates the
/// resident graph in place (reads block only for the duration of the
/// incremental repair).
pub struct NativeExecutor {
    state: RwLock<Resident>,
    parallel: ParallelConfig,
    use_int_path: bool,
    /// set by the first [`Self::apply_delta`]: only dynamic sessions pay
    /// the per-layer activation recording (L+1 matrix clones + a write
    /// lock) on the epoch's first classify batch — static sessions keep
    /// the plain forward
    dynamic: std::sync::atomic::AtomicBool,
    /// versioned full-graph logits (node-level serving hot path)
    logits: LogitsCache<Matrix<f32>>,
    /// attached durability sink ([`Self::with_persistence`]): applied
    /// deltas are WAL-logged before commit and resident state is
    /// snapshotted on the configured cadence.  `None` = volatile session.
    persist: Mutex<Option<Persistence>>,
}

impl NativeExecutor {
    /// Prepare a serving session from a loaded model.  This is the
    /// model-load validation boundary: malformed static state (missing
    /// layer tensors, non-finite or mismatched quant steps, empty NNS
    /// tables) is rejected here instead of panicking on the first request.
    pub fn new(model: GnnModel, dataset: Option<&Dataset>) -> Result<NativeExecutor> {
        let mut node = None;
        if model.node_level {
            let ds: &NodeData = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(NodeSide {
                csr: ds.csr.clone(),
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        }
        let prepared = PreparedModel::prepare(model)?;
        let model = &prepared.model;
        let caps = (
            model.num_nodes,
            model
                .manifest
                .get("num_edges")
                .and_then(|v| v.as_usize())
                .unwrap_or(model.num_nodes * 8),
            model.graph_capacity.max(1),
        );
        let plan = node.as_ref().and_then(|side: &NodeSide| {
            (model.arch != "gat")
                .then(|| AggregationPlan::build(&side.edges.dst, side.edges.num_nodes))
        });
        Ok(NativeExecutor {
            state: RwLock::new(Resident {
                prepared,
                node,
                plan,
                caps,
                acts: None,
                assign_tables: None,
                sharded: None,
            }),
            parallel: ParallelConfig::from_env(),
            use_int_path: false,
            dynamic: std::sync::atomic::AtomicBool::new(false),
            logits: LogitsCache::new(),
            persist: Mutex::new(None),
        })
    }

    /// Set the intra-op parallelism budget (builder style).
    pub fn with_parallelism(mut self, cfg: ParallelConfig) -> NativeExecutor {
        self.parallel = cfg;
        self
    }

    /// Route through `forward_int` (true integer arithmetic over packed
    /// codes) instead of the fp emulation.
    pub fn with_int_path(mut self, on: bool) -> NativeExecutor {
        self.use_int_path = on;
        self
    }

    /// Read-lock the resident state — the one audited read acquisition.
    fn resident(&self) -> RwLockReadGuard<'_, Resident> {
        // a2q-lint: allow(panic-path) poisoning requires a prior panic while
        // holding the lock; the resident state is unrecoverable past that
        self.state.read().unwrap()
    }

    /// Write-lock the resident state — the one audited write acquisition.
    fn resident_mut(&self) -> RwLockWriteGuard<'_, Resident> {
        // a2q-lint: allow(panic-path) poisoning requires a prior panic while
        // holding the lock; the resident state is unrecoverable past that
        self.state.write().unwrap()
    }

    /// Switch this session into **sharded resident mode**: the resident
    /// graph is partitioned into `num_shards` shards by the degree-aware
    /// partitioner, full-graph recomputes run shard-parallel
    /// (`forward_{fp,int}_sharded`, bitwise identical to the single-shard
    /// path), node batches are served from per-shard logits blocks, and
    /// [`Self::apply_delta`] rebuilds only the owning shards' local views.
    /// Node-level gcn/gin sessions only.
    pub fn with_shards(self, num_shards: usize) -> Result<NativeExecutor> {
        {
            let mut st = self.resident_mut();
            let model = &st.prepared.model;
            if model.arch == "gat" || model.head.is_some() || !model.node_level {
                return Err(Error::coordinator(
                    "sharded residents need a node-level gcn/gin session",
                ));
            }
            let side = st.node.as_ref().ok_or_else(|| {
                Error::coordinator("sharded residents need a resident node dataset")
            })?;
            let graph = ShardedGraph::build(&side.csr, &side.edges, num_shards)?;
            let s = graph.num_shards();
            st.sharded = Some(ShardedState {
                graph,
                logits: vec![None; s],
            });
        }
        Ok(self)
    }

    /// Shard layout of a sharded session: `(num_shards, halo stats)`.
    pub fn shard_stats(&self) -> Option<(usize, HaloStats)> {
        let st = self.resident();
        st.sharded
            .as_ref()
            .map(|s| (s.graph.num_shards(), s.graph.halo_stats()))
    }

    pub fn parallelism(&self) -> ParallelConfig {
        self.parallel
    }

    /// Resident-size accounting of the prepared session in bytes.
    pub fn prepared_bytes(&self) -> usize {
        self.resident().prepared.prepared_bytes()
    }

    /// Current resident node count (grows with applied deltas).
    pub fn resident_nodes(&self) -> usize {
        let st = self.resident();
        st.node
            .as_ref()
            .map(|s| s.num_nodes)
            .unwrap_or(st.caps.0)
    }

    /// Clone of the resident graph's aggregation plan (tests/diagnostics).
    pub fn resident_plan(&self) -> Option<AggregationPlan> {
        self.resident().plan.clone()
    }

    /// Per-layer clones of the resident feature-quantization parameters
    /// (`(feat, feat2)` per layer) — after deltas these include the
    /// NNS-assigned entries for appended nodes, which is exactly what a
    /// from-scratch rebuild needs to reproduce the served logits
    /// (`rust/tests/delta_parity.rs`).
    pub fn resident_quant_params(
        &self,
    ) -> Vec<(Option<NodeQuantParams>, Option<NodeQuantParams>)> {
        let st = self.resident();
        st.prepared
            .model
            .layers
            .iter()
            .map(|l| (l.feat.clone(), l.feat2.clone()))
            .collect()
    }

    /// Invalidate the full-graph logits cache.  Call after a weight or
    /// resident-feature swap; the next node batch recomputes under the new
    /// epoch while in-flight batches keep serving the old one.
    pub fn bump_epoch(&self) {
        self.logits.bump();
    }

    /// Current logits-cache epoch (diagnostics).
    pub fn epoch(&self) -> u64 {
        self.logits.epoch()
    }

    /// Lock the persistence slot — the one audited acquisition.
    fn persist_lock(&self) -> MutexGuard<'_, Option<Persistence>> {
        // a2q-lint: allow(panic-path) poisoning requires a prior panic while
        // holding this short-lived lock; there is no state to salvage
        self.persist.lock().unwrap()
    }

    /// Log-before-commit: append the delta to the WAL (if one is
    /// attached) and return the record's on-disk length for a possible
    /// [`Self::wal_rollback`].  Called under the resident write lock so
    /// WAL order always equals commit order.  An append failure rejects
    /// the delta — no commit without a durable record.
    fn wal_append(&self, delta: &GraphDelta) -> Result<Option<u64>> {
        let mut guard = self.persist_lock();
        match guard.as_mut() {
            Some(p) => Ok(Some(p.append_delta(delta)?)),
            None => Ok(None),
        }
    }

    /// Unwrite the record a rejected delta logged, so the WAL never
    /// replays a delta the resident refused to commit.
    fn wal_rollback(&self, logged: Option<u64>) {
        let Some(record_bytes) = logged else { return };
        let mut guard = self.persist_lock();
        if let Some(p) = guard.as_mut() {
            if let Err(e) = p.rollback_last(record_bytes) {
                p.set_note(format!(
                    "WAL rollback of a rejected delta failed — recovery replay \
                     will stop at it with an error: {e}"
                ));
            }
        }
    }

    /// Cut a snapshot when the WAL hit the configured cadence.  Failures
    /// are non-fatal: the WAL is retained, recovery just replays a longer
    /// tail, and the reason is surfaced via [`Self::persistence_note`].
    fn maybe_snapshot(&self, st: &Resident, epoch: u64) {
        if st.node.is_none() {
            return;
        }
        let mut guard = self.persist_lock();
        let Some(p) = guard.as_mut() else { return };
        if !p.snapshot_due() {
            return;
        }
        match snapshot_resident(st, epoch) {
            Ok(snap) => {
                if let Err(e) = p.install_snapshot(&snap) {
                    p.set_note(format!(
                        "snapshot install failed (WAL retained; recovery \
                         replays it): {e}"
                    ));
                }
            }
            Err(e) => p.set_note(format!("snapshot capture failed (WAL retained): {e}")),
        }
    }

    /// Attach durable state under `cfg.dir` (builder style), running crash
    /// recovery first: install the newest valid snapshot, replay the WAL
    /// tail through the exact incremental-repair path live deltas take,
    /// and only then start logging.  The recovered session serves logits
    /// **bit-for-bit** equal to a continuously-running one
    /// (`rust/tests/persist_recovery.rs`); a WAL that does not match the
    /// loaded artifact is a hard error, not a silent divergence.
    pub fn with_persistence(
        self,
        cfg: PersistConfig,
    ) -> Result<(NativeExecutor, RestoreReport)> {
        let (persistence, recovery) = Persistence::open(cfg)?;
        let mut report = RestoreReport {
            restored_snapshot: false,
            snapshot_epoch: 0,
            replayed_deltas: 0,
            dropped_bytes: recovery.dropped_bytes,
            dropped_note: recovery.dropped_note.clone(),
            epoch: 0,
            num_nodes: 0,
        };
        if let Some(snap) = &recovery.snapshot {
            self.restore_snapshot(snap)?;
            report.restored_snapshot = true;
            report.snapshot_epoch = snap.epoch;
        }
        let total = recovery.deltas.len();
        for (i, delta) in recovery.deltas.iter().enumerate() {
            self.apply_delta_impl(delta, false).map_err(|e| {
                Error::coordinator(format!(
                    "WAL replay failed at record {}/{total}: {e} — the log does \
                     not match the loaded artifact; remove the state dir to \
                     start fresh",
                    i + 1
                ))
            })?;
        }
        if report.restored_snapshot || total > 0 {
            // the recovered session is as dynamic as the one that wrote
            // the log: keep the activation cache warm for future deltas
            self.dynamic.store(true, Ordering::Release);
        }
        report.replayed_deltas = total;
        report.epoch = self.logits.epoch();
        report.num_nodes = self.resident_nodes();
        *self.persist_lock() = Some(persistence);
        Ok((self, report))
    }

    /// Install a crash-recovery [`Snapshot`] into the resident state.
    /// [`Self::with_persistence`] replays the WAL tail on top.
    fn restore_snapshot(&self, snap: &Snapshot) -> Result<()> {
        let mut guard = self.resident_mut();
        let st = &mut *guard;
        if st.node.is_none() {
            return Err(Error::coordinator(
                "snapshot restore needs a node-level session",
            ));
        }
        {
            let m = &st.prepared.model;
            if m.name != snap.model_name {
                return Err(Error::artifact(format!(
                    "snapshot belongs to model '{}' but the session loaded \
                     '{}' — after a hot swap, restart against the swapped \
                     artifact",
                    snap.model_name, m.name
                )));
            }
            if m.arch != snap.arch
                || m.in_dim != snap.in_dim as usize
                || m.out_dim != snap.out_dim as usize
                || m.layers.len() != snap.layers.len()
            {
                return Err(Error::artifact(format!(
                    "snapshot shape mismatch: disk has {} {}→{} ({} layers), \
                     the loaded artifact is {} {}→{} ({} layers)",
                    snap.arch,
                    snap.in_dim,
                    snap.out_dim,
                    snap.layers.len(),
                    m.arch,
                    m.in_dim,
                    m.out_dim,
                    m.layers.len()
                )));
            }
        }
        let csr = Csr {
            indptr: snap.indptr.clone(),
            indices: snap.indices.clone(),
        };
        csr.validate()?;
        let n = csr.num_nodes();
        if n as u64 != snap.num_nodes {
            return Err(Error::artifact(format!(
                "snapshot claims {} nodes but its CSR has {n}",
                snap.num_nodes
            )));
        }
        if snap.features.len() != n * snap.in_dim as usize {
            return Err(Error::artifact(format!(
                "snapshot features are {} floats, want {} ({n} nodes × {} dims)",
                snap.features.len(),
                n * snap.in_dim as usize,
                snap.in_dim
            )));
        }
        let edges = EdgeForm::from_csr(&csr);
        let plan = (st.prepared.model.arch != "gat")
            .then(|| AggregationPlan::build(&edges.dst, edges.num_nodes));
        // sharded sessions re-partition the restored graph from scratch;
        // shard parity pins bitwise-identical logits for any partition,
        // so the layout difference vs the evolved one is invisible
        let new_sharded = match st.sharded.as_ref() {
            Some(sh) => {
                let graph = ShardedGraph::build(&csr, &edges, sh.graph.num_shards())?;
                let s = graph.num_shards();
                Some(ShardedState {
                    graph,
                    logits: vec![None; s],
                })
            }
            None => None,
        };
        // freeze the NNS assignment tables over the artifact's learned
        // params BEFORE installing the snapshot's extended copies —
        // replayed deltas must assign exactly like the continuous session,
        // which froze its tables at its first delta
        if st.assign_tables.is_none() {
            st.assign_tables = Some(build_assign_tables(&st.prepared)?);
        }
        for (l, (lay, sl)) in st
            .prepared
            .model
            .layers
            .iter_mut()
            .zip(&snap.layers)
            .enumerate()
        {
            if sl.feat.is_some() != lay.feat.is_some()
                || sl.feat2.is_some() != lay.feat2.is_some()
            {
                return Err(Error::artifact(format!(
                    "snapshot layer {l} quantization params do not match the \
                     loaded model's shape"
                )));
            }
            if let Some(p) = &sl.feat {
                lay.feat =
                    Some(NodeQuantParams::new(p.steps.clone(), p.bits.clone(), p.signed)?);
            }
            if let Some(p) = &sl.feat2 {
                lay.feat2 =
                    Some(NodeQuantParams::new(p.steps.clone(), p.bits.clone(), p.signed)?);
            }
        }
        let side = st.node.as_mut().ok_or_else(|| {
            Error::coordinator("snapshot restore needs a node-level session")
        })?;
        side.csr = csr;
        side.features = snap.features.clone();
        side.edges = edges;
        side.num_nodes = n;
        st.plan = plan;
        st.sharded = new_sharded;
        st.prepared.model.num_nodes = n;
        st.caps.0 = n;
        st.acts = None;
        drop(guard);
        self.logits.restore_epoch(snap.epoch);
        Ok(())
    }

    /// Atomic hot weight swap: install a re-prepared model under traffic.
    ///
    /// Update-barrier semantics: the expensive `prepare` (integer codes,
    /// NNS tables) runs **outside** any lock on a model grafted with the
    /// resident per-node state; the write lock is held only for the
    /// pointer-sized install + one epoch bump.  In-flight batches finish
    /// on the old epoch's cached logits, the next batch recomputes under
    /// the new weights — no torn or stale reads, sharded or not (stale
    /// per-shard blocks are epoch-tagged and recompute on first use).
    ///
    /// Durable sessions force a post-swap snapshot so pre-swap WAL deltas
    /// can never replay under the new weights; if that snapshot fails the
    /// swap is live but **not** durable (`SwapReport::snapshot_installed`
    /// is `false` and [`Self::persistence_note`] says why).
    pub fn hot_swap(&self, mut model: GnnModel) -> Result<SwapReport> {
        // phase 1 (read lock): compatibility gate + clone the resident
        // per-node quant params — the incoming weights must serve the
        // *evolved* graph, NNS-appended entries included
        let (num_nodes, graph_capacity, grafts) = {
            let st = self.resident();
            let cur = &st.prepared.model;
            if model.arch != cur.arch
                || model.node_level != cur.node_level
                || model.in_dim != cur.in_dim
                || model.out_dim != cur.out_dim
                || model.layers.len() != cur.layers.len()
                || model.head.is_some() != cur.head.is_some()
                || model.heads != cur.heads
            {
                return Err(Error::coordinator(format!(
                    "hot swap needs a shape-compatible model: session is {} \
                     {}→{} ({} layers), incoming '{}' is {} {}→{} ({} layers)",
                    cur.arch,
                    cur.in_dim,
                    cur.out_dim,
                    cur.layers.len(),
                    model.name,
                    model.arch,
                    model.in_dim,
                    model.out_dim,
                    model.layers.len()
                )));
            }
            let grafts: Vec<(Option<NodeQuantParams>, Option<NodeQuantParams>)> = cur
                .layers
                .iter()
                .map(|l| (l.feat.clone(), l.feat2.clone()))
                .collect();
            (cur.num_nodes, cur.graph_capacity, grafts)
        };
        // phase 2 (no lock): graft into the RAW model, then prepare —
        // prepare re-derives codes and NNS tables from the grafted
        // params, so the swapped session is self-consistent
        model.num_nodes = num_nodes;
        model.graph_capacity = graph_capacity;
        for (lay, (f, f2)) in model.layers.iter_mut().zip(grafts) {
            if f.is_some() {
                lay.feat = f;
            }
            if f2.is_some() {
                lay.feat2 = f2;
            }
        }
        let fresh = PreparedModel::prepare(model)?;
        let prepared_bytes = fresh.prepared_bytes();
        let model_name = fresh.model.name.clone();
        // phase 3 (write lock): install + exactly-once epoch bump
        let mut guard = self.resident_mut();
        let st = &mut *guard;
        if st.prepared.model.num_nodes != num_nodes {
            // a delta appended nodes between phases 1 and 3 — the grafted
            // params are stale for the grown graph
            return Err(Error::coordinator(
                "hot swap raced a graph update; re-issue the swap",
            ));
        }
        st.prepared = fresh;
        st.acts = None;
        // assign_tables stay frozen over the ORIGINAL learned params:
        // delta NNS assignment is a property of the session, not of
        // whichever weights currently serve it
        self.logits.bump();
        let epoch = self.logits.epoch();
        let mut snapshot_installed = false;
        if st.node.is_some() {
            let mut pguard = self.persist_lock();
            if let Some(p) = pguard.as_mut() {
                match snapshot_resident(st, epoch) {
                    Ok(snap) => match p.install_snapshot(&snap) {
                        Ok(()) => snapshot_installed = true,
                        Err(e) => p.set_note(format!(
                            "post-swap snapshot failed — the swap is live but \
                             NOT durable; fix the state dir before restarting: \
                             {e}"
                        )),
                    },
                    Err(e) => p.set_note(format!(
                        "post-swap snapshot capture failed — the swap is live \
                         but NOT durable: {e}"
                    )),
                }
            }
        }
        drop(guard);
        Ok(SwapReport {
            epoch,
            model_name,
            prepared_bytes,
            snapshot_installed,
        })
    }

    /// Durability diagnostics: `(generation, wal_records, wal_bytes)` of
    /// the attached sink; `None` for volatile sessions.
    pub fn wal_stats(&self) -> Option<(u64, usize, u64)> {
        self.persist_lock()
            .as_ref()
            .map(|p| (p.generation(), p.wal_records(), p.wal_bytes()))
    }

    /// Last persistence warning (failed snapshot or rollback), if any.
    pub fn persistence_note(&self) -> Option<String> {
        self.persist_lock()
            .as_ref()
            .and_then(|p| p.note().map(str::to_string))
    }

    /// Serve node rows of a sharded session from the per-shard logits
    /// blocks, recomputing with one shard-parallel forward when the
    /// blocks are stale for the current epoch.  The recompute runs outside
    /// the write lock and installs epoch-checked, mirroring
    /// [`LogitsCache::get_or_compute`]: a concurrent delta keeps a stale
    /// result out of the blocks while this call still serves what it
    /// computed.
    fn sharded_node_rows(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        let epoch = self.logits.epoch();
        {
            let st = self.resident();
            // a2q-lint: allow(panic-path) routed here only when the caller
            // saw sharded state installed, and with_shards never unsets it
            let sh = st.sharded.as_ref().expect("sharded session");
            if sh
                .logits
                .iter()
                .all(|b| matches!(b, Some((e, _)) if *e == epoch))
            {
                return node_ids
                    .iter()
                    .map(|&v| {
                        if v as usize >= sh.graph.num_nodes {
                            return Err(Error::coordinator(format!(
                                "node {v} out of range"
                            )));
                        }
                        let (s, pos) = sh.graph.locate(v);
                        // a2q-lint: allow(panic-path) the freshness scan
                        // above proved every slot holds this epoch's block
                        let block = sh.logits[s].as_ref().expect("checked fresh above");
                        Ok(block.1.row(pos).to_vec())
                    })
                    .collect();
            }
        }
        let record = self.dynamic.load(Ordering::Acquire);
        let (out, acts) = {
            let st = self.resident();
            let side = st
                .node
                .as_ref()
                .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
            // a2q-lint: allow(panic-path) routed here only when the caller
            // saw sharded state installed, and with_shards never unsets it
            let shg = &st.sharded.as_ref().expect("sharded session").graph;
            let mut acts = Vec::new();
            let out = match (self.use_int_path, record) {
                (true, true) => forward_int_sharded_recording(
                    &st.prepared,
                    &side.features,
                    shg,
                    &self.parallel,
                    &mut acts,
                ),
                (false, true) => forward_fp_sharded_recording(
                    &st.prepared,
                    &side.features,
                    shg,
                    &self.parallel,
                    &mut acts,
                ),
                (true, false) => {
                    forward_int_sharded(&st.prepared, &side.features, shg, &self.parallel)
                }
                (false, false) => {
                    forward_fp_sharded(&st.prepared, &side.features, shg, &self.parallel)
                }
            };
            (out, record.then_some(acts))
        };
        {
            let mut st = self.resident_mut();
            if self.logits.epoch() == epoch {
                if let Some(acts) = acts {
                    st.acts = Some((epoch, acts));
                }
                // a2q-lint: allow(panic-path) routed here only when the
                // caller saw sharded state, and with_shards never unsets it
                let sh = st.sharded.as_mut().expect("sharded session");
                refresh_shard_logits(sh, &out, epoch);
            }
        }
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if v >= out.rows {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(out.row(v).to_vec())
            })
            .collect()
    }

    fn full_graph_logits(&self) -> Result<Arc<Matrix<f32>>> {
        // Static sessions (no delta ever applied) take the plain forward;
        // once the session turns dynamic, epoch recomputes also record the
        // per-layer activations so the next delta patches instead of
        // recomputing.  A cold first delta warms its own cache either way.
        let record = self.dynamic.load(Ordering::Acquire);
        self.logits.get_or_compute(|epoch| {
            let st = self.resident();
            let side = st
                .node
                .as_ref()
                .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
            let input =
                GraphInput::node_level(&side.features, st.prepared.model.in_dim, &side.edges);
            let mut acts = Vec::new();
            let out = match (self.use_int_path, record) {
                (true, true) => forward_int_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut acts,
                ),
                (false, true) => forward_fp_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut acts,
                ),
                (true, false) => forward_int_prepared_with_plan(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                ),
                (false, false) => forward_fp_prepared_with_plan(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                ),
            };
            drop(st);
            if record {
                // stash the per-layer activations so a later delta patches
                // instead of recomputing; skip if an update raced us
                let mut st = self.resident_mut();
                if self.logits.epoch() == epoch {
                    st.acts = Some((epoch, acts));
                }
            }
            Ok(out)
        })
    }

    /// Apply a [`GraphDelta`] to the resident graph (node-level gcn/gin
    /// sessions).  The epoch bumps exactly once; only the delta's L-hop
    /// reverse frontier of logits rows is recomputed, and the patched
    /// logits are installed for the new epoch so the next classify batch
    /// is a slice-copy.  Appended nodes receive `(step, bits)` via the
    /// paper's NNS against the learned per-node parameters.  All repairs
    /// are staged and committed atomically — a rejected delta (shape
    /// mismatch, non-finite features/activations) leaves the resident
    /// state untouched.
    pub fn apply_delta(&self, delta: &GraphDelta) -> Result<DeltaReport> {
        self.apply_delta_impl(delta, true)
    }

    /// [`Self::apply_delta`] body.  `log == false` is the crash-recovery
    /// replay path ([`Self::with_persistence`]): the delta is already in
    /// the WAL, so it is neither re-logged nor snapshot-triggering.
    fn apply_delta_impl(&self, delta: &GraphDelta, log: bool) -> Result<DeltaReport> {
        let mut guard = self.resident_mut();
        let st = &mut *guard;
        if st.prepared.model.arch == "gat" {
            return Err(Error::coordinator(
                "resident-graph updates are not supported for gat sessions",
            ));
        }
        if st.prepared.model.head.is_some() {
            // graph-level readout models have no resident graph to mutate,
            // and their logits are a pooled head output, not acts.last()
            return Err(Error::coordinator(
                "resident-graph updates need a node-level session",
            ));
        }
        let side = st.node.as_mut().ok_or_else(|| {
            Error::coordinator("resident-graph updates need a node-level session")
        })?;
        let in_dim = st.prepared.model.in_dim;
        let n_layers = st.prepared.model.layers.len();
        let int_path = st.prepared.int_path_semantics(self.use_int_path);
        delta.validate(side.num_nodes, in_dim)?;
        // log-before-commit: the record hits the WAL (under the resident
        // write lock, so WAL order == commit order) before any state
        // mutates; the rejected-delta paths below unwrite it again so the
        // log never replays a delta the resident refused
        let logged = if log { self.wal_append(delta)? } else { None };
        // this session is dynamic from here on: epoch recomputes keep the
        // per-layer activation cache warm for future deltas
        self.dynamic.store(true, Ordering::Release);

        // Empty delta: nothing to repair — honour the one-bump-per-delta
        // contract and carry the current state forward untouched.
        if delta.is_empty() {
            let epoch = self.logits.epoch();
            self.logits.bump();
            let new_epoch = self.logits.epoch();
            if let Some((e, acts)) = st.acts.as_mut() {
                if *e == epoch {
                    *e = new_epoch;
                    // a2q-lint: allow(panic-path) recording forwards always
                    // return the input plus one matrix per layer
                    let logits_mat = acts.last().expect("at least the input features");
                    self.logits.set(new_epoch, Arc::new(logits_mat.clone()));
                }
            }
            // sharded blocks carry over bit-for-bit under the new epoch
            let halo_nodes = match st.sharded.as_mut() {
                Some(sh) => {
                    for slot in sh.logits.iter_mut() {
                        if let Some((e, _)) = slot {
                            if *e == epoch {
                                *e = new_epoch;
                            }
                        }
                    }
                    sh.graph.halo_stats().halo_nodes
                }
                None => 0,
            };
            let report = DeltaReport {
                epoch: new_epoch,
                num_nodes: side.num_nodes,
                recomputed_rows: 0,
                new_nodes: 0,
                shards_touched: 0,
                halo_nodes,
            };
            if log {
                self.maybe_snapshot(st, new_epoch);
            }
            return Ok(report);
        }

        // 1. incremental structural repair (all staged)
        let applied = match delta.apply_to_csr(&side.csr) {
            Ok(a) => a,
            Err(e) => {
                self.wal_rollback(logged);
                return Err(e);
            }
        };
        let new_edges = side.edges.apply_delta(&side.csr, &applied);
        let new_plan = AggregationPlan::for_csr_edge_form(&applied.csr);
        let n_new = applied.csr.num_nodes();
        let mut new_features = side.features.clone();
        new_features.extend_from_slice(&delta.new_features);
        let dirty = dirty_frontier(&applied.csr, &applied, n_layers);
        let frontier_rows = dirty.last().map(|d| d.len()).unwrap_or(0);

        // Near-full frontier without appended nodes: the serial row patch
        // would touch most of the graph, so the row-parallel recording
        // forward over the post-delta structure is cheaper and produces the
        // identical (bitwise) result.  With appended nodes the patch is
        // required — NNS assignment interleaves with layer computation.
        if delta.add_nodes == 0 && frontier_rows.saturating_mul(2) > n_new {
            let input = GraphInput::node_level(&new_features, in_dim, &new_edges);
            let mut rec = Vec::new();
            if self.use_int_path {
                forward_int_prepared_recording(
                    &st.prepared,
                    &input,
                    Some(&new_plan),
                    &self.parallel,
                    &mut rec,
                );
            } else {
                forward_fp_prepared_recording(
                    &st.prepared,
                    &input,
                    Some(&new_plan),
                    &self.parallel,
                    &mut rec,
                );
            }
            // sharded resident: rebuild only the affected shards' local
            // views against the post-delta structure (before it moves)
            let (shards_touched, halo_nodes) = match st.sharded.as_mut() {
                Some(sh) => {
                    let touched = sh
                        .graph
                        .apply_delta(
                            &applied.csr,
                            &new_edges,
                            0,
                            &applied.row_changed,
                            &applied.deg_changed,
                        )
                        .len();
                    (touched, sh.graph.halo_stats().halo_nodes)
                }
                None => (0, 0),
            };
            side.csr = applied.csr;
            side.features = new_features;
            side.edges = new_edges;
            side.num_nodes = n_new;
            st.plan = Some(new_plan);
            self.logits.bump();
            let new_epoch = self.logits.epoch();
            // a2q-lint: allow(panic-path) recording forwards always return
            // the input plus one matrix per layer
            let logits_mat = rec.last().expect("at least the input features").clone();
            st.acts = Some((new_epoch, rec));
            if let Some(sh) = st.sharded.as_mut() {
                refresh_shard_logits(sh, &logits_mat, new_epoch);
            }
            self.logits.set(new_epoch, Arc::new(logits_mat));
            let report = DeltaReport {
                epoch: new_epoch,
                num_nodes: n_new,
                recomputed_rows: frontier_rows,
                new_nodes: 0,
                shards_touched,
                halo_nodes,
            };
            if log {
                self.maybe_snapshot(st, new_epoch);
            }
            return Ok(report);
        }

        // 2. make sure the per-layer activation cache matches this epoch
        //    (cold sessions pay one full forward on the pre-delta graph —
        //    the same warm-up the first classify batch would have done)
        let epoch = self.logits.epoch();
        if st.acts.as_ref().map(|(e, _)| *e) != Some(epoch) {
            let input = GraphInput::node_level(&side.features, in_dim, &side.edges);
            let mut rec = Vec::new();
            if self.use_int_path {
                forward_int_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut rec,
                );
            } else {
                forward_fp_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut rec,
                );
            }
            st.acts = Some((epoch, rec));
        }

        // 3. freeze the NNS assignment tables over the learned params
        if st.assign_tables.is_none() {
            match build_assign_tables(&st.prepared) {
                Ok(t) => st.assign_tables = Some(t),
                Err(e) => {
                    self.wal_rollback(logged);
                    return Err(e);
                }
            }
        }

        // 4. staged activations (pre-delta rows carried over, appended
        //    rows zeroed until patched)
        // a2q-lint: allow(panic-path) step 2 just warmed the activation
        // cache for exactly this epoch
        let (_, old_acts) = st.acts.as_ref().expect("warmed above");
        let mut acts: Vec<Matrix<f32>> = Vec::with_capacity(n_layers + 1);
        match Matrix::from_vec(n_new, in_dim, new_features.clone()) {
            Ok(m) => acts.push(m),
            Err(e) => {
                self.wal_rollback(logged);
                return Err(e);
            }
        }
        for m in &old_acts[1..] {
            let mut grown = Matrix::zeros(n_new, m.cols);
            grown.data[..m.data.len()].copy_from_slice(&m.data);
            acts.push(grown);
        }

        // 5. staged per-node quant params (cloned; appended entries are
        //    NNS-assigned inside the patch as their rows materialize)
        // a2q-lint: allow(panic-path) step 3 just froze the assignment
        // tables for this session
        let tables = st.assign_tables.as_ref().expect("frozen above");
        let mut staged: Vec<(Option<NodeQuantParams>, Option<NodeQuantParams>)> = st
            .prepared
            .model
            .layers
            .iter()
            .zip(tables.iter())
            .map(|(lay, t)| {
                (
                    t.feat.as_ref().and(lay.feat.clone()),
                    t.feat2.as_ref().and(lay.feat2.clone()),
                )
            })
            .collect();

        // 6. row repair over the frontier (bitwise == full recompute)
        let recomputed = match patch_activations(
            &st.prepared,
            &mut staged,
            tables,
            &new_edges,
            &new_plan,
            &mut acts,
            &dirty,
            int_path,
            self.parallel.simd,
        ) {
            Ok(r) => r,
            Err(e) => {
                self.wal_rollback(logged);
                return Err(e);
            }
        };

        // 7. commit + single epoch bump.  Sharded residents first repair
        //    their partition (appended nodes go to the least-loaded
        //    shards) and rebuild only the affected shards' local views.
        let (shards_touched, halo_nodes) = match st.sharded.as_mut() {
            Some(sh) => {
                let touched = sh
                    .graph
                    .apply_delta(
                        &applied.csr,
                        &new_edges,
                        delta.add_nodes,
                        &applied.row_changed,
                        &applied.deg_changed,
                    )
                    .len();
                (touched, sh.graph.halo_stats().halo_nodes)
            }
            None => (0, 0),
        };
        side.csr = applied.csr;
        side.features = new_features;
        side.edges = new_edges;
        side.num_nodes = n_new;
        st.plan = Some(new_plan);
        for (lay, (f, f2)) in st.prepared.model.layers.iter_mut().zip(staged) {
            if let Some(p) = f {
                lay.feat = Some(p);
            }
            if let Some(p) = f2 {
                lay.feat2 = Some(p);
            }
        }
        st.prepared.model.num_nodes = n_new;
        st.caps.0 = n_new;
        self.logits.bump();
        let new_epoch = self.logits.epoch();
        // a2q-lint: allow(panic-path) acts was built above as the input
        // plus one matrix per layer
        let logits_mat = acts.last().expect("at least input + one layer").clone();
        st.acts = Some((new_epoch, acts));
        if let Some(sh) = st.sharded.as_mut() {
            let frontier: &[u32] = dirty.last().map(|d| d.as_slice()).unwrap_or(&[]);
            if !patch_shard_logits(sh, &logits_mat, epoch, new_epoch, frontier) {
                refresh_shard_logits(sh, &logits_mat, new_epoch);
            }
        }
        self.logits.set(new_epoch, Arc::new(logits_mat));
        let report = DeltaReport {
            epoch: new_epoch,
            num_nodes: n_new,
            recomputed_rows: recomputed,
            new_nodes: delta.add_nodes,
            shards_touched,
            halo_nodes,
        };
        if log {
            self.maybe_snapshot(st, new_epoch);
        }
        Ok(report)
    }
}

impl BatchExecutor for NativeExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        // sharded sessions serve from per-shard logits blocks, recomputing
        // with the shard-parallel forward when the epoch moved
        if self.resident().sharded.is_some() {
            return self.sharded_node_rows(node_ids);
        }
        // full forward once per epoch; every batch after that is a
        // row slice-copy off the cached logits
        let logits = self.full_graph_logits()?;
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if v >= logits.rows {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits.row(v).to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let st = self.resident();
        let (cap_n, cap_e, cap_g) = st.caps;
        let batch = GraphBatch::pack(graphs, st.prepared.model.in_dim, cap_n, cap_e, cap_g)?;
        let input = GraphInput::batch(&batch);
        // client-supplied edges differ per batch, so no resident plan here
        let out = if self.use_int_path {
            forward_int_prepared_with_plan(&st.prepared, &input, None, &self.parallel)
        } else {
            forward_fp_prepared_with_plan(&st.prepared, &input, None, &self.parallel)
        };
        Ok((0..graphs.len()).map(|g| out.row(g).to_vec()).collect())
    }

    fn apply_delta(&self, delta: &GraphDelta) -> Result<DeltaReport> {
        NativeExecutor::apply_delta(self, delta)
    }

    fn capacity(&self) -> (usize, usize) {
        let st = self.resident();
        if st.prepared.model.node_level {
            (
                st.node.as_ref().map(|s| s.num_nodes).unwrap_or(st.caps.0),
                0,
            )
        } else {
            (st.caps.0, st.caps.2)
        }
    }

    fn out_dim(&self) -> usize {
        self.resident().prepared.model.out_dim
    }
}

// ---------------------------------------------------------------------------
// Mock
// ---------------------------------------------------------------------------

/// Deterministic test double: returns node id / node count as "logits",
/// optionally sleeping to emulate execution latency.
pub struct MockExecutor {
    pub out_dim: usize,
    pub latency: std::time::Duration,
}

impl Default for MockExecutor {
    fn default() -> Self {
        MockExecutor {
            out_dim: 2,
            latency: std::time::Duration::ZERO,
        }
    }
}

impl BatchExecutor for MockExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(node_ids
            .iter()
            .map(|&v| {
                let mut out = vec![0.0; self.out_dim];
                out[v as usize % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(graphs
            .iter()
            .map(|g| {
                let mut out = vec![0.0; self.out_dim];
                out[g.num_nodes() % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn capacity(&self) -> (usize, usize) {
        (1024, 16)
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::{forward_fp_with, LayerParams, QuantMethod};
    use crate::quant::mixed::NodeQuantParams;
    use crate::util::json::Json;

    #[test]
    fn mock_is_deterministic() {
        let m = MockExecutor::default();
        let out = m.run_node_batch(&[0, 1, 2]).unwrap();
        assert_eq!(out[0], vec![1.0, 0.0]);
        assert_eq!(out[1], vec![0.0, 1.0]);
        assert_eq!(out[2], vec![1.0, 0.0]);
    }

    #[test]
    fn mock_rejects_deltas() {
        let err = BatchExecutor::apply_delta(
            &MockExecutor::default(),
            &GraphDelta::default(),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("does not support"));
    }

    fn tiny_session() -> (GnnModel, Dataset) {
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.5, 1.0]).unwrap();
        let model = GnnModel {
            name: "tiny".into(),
            arch: "gcn".into(),
            dataset: "unit".into(),
            method: QuantMethod::A2q,
            layers: vec![LayerParams {
                w: Some(w),
                b: vec![0.1, -0.1],
                w_steps: vec![0.05, 0.05],
                feat: Some(NodeQuantParams::new(vec![0.1; 3], vec![4; 3], true).unwrap()),
                ..Default::default()
            }],
            head: None,
            dq_steps: vec![],
            skip_input_quant: false,
            node_level: true,
            num_nodes: 3,
            in_dim: 2,
            out_dim: 2,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        };
        let csr = Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap();
        let ds = Dataset::Node(NodeData {
            name: "unit".into(),
            csr,
            num_features: 2,
            num_classes: 2,
            features: vec![0.3, -0.2, 0.15, 0.4, -0.35, 0.05],
            labels: vec![0, 1, 0],
            train_mask: vec![false; 3],
            val_mask: vec![false; 3],
            test_mask: vec![false; 3],
        });
        (model, ds)
    }

    /// 6-node path graph session (1-layer GCN) — long enough that a delta
    /// at one end leaves a genuinely untouched far end.
    fn path_session() -> (GnnModel, Dataset) {
        let n = 6;
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.5, 1.0]).unwrap();
        let model = GnnModel {
            name: "path".into(),
            arch: "gcn".into(),
            dataset: "unit".into(),
            method: QuantMethod::A2q,
            layers: vec![LayerParams {
                w: Some(w),
                b: vec![0.1, -0.1],
                w_steps: vec![0.05, 0.05],
                feat: Some(NodeQuantParams::new(vec![0.1; 6], vec![4; 6], true).unwrap()),
                ..Default::default()
            }],
            head: None,
            dq_steps: vec![],
            skip_input_quant: false,
            node_level: true,
            num_nodes: n,
            in_dim: 2,
            out_dim: 2,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        };
        let mut edges = Vec::new();
        for i in 0..n as u32 - 1 {
            edges.push((i, i + 1));
            edges.push((i + 1, i));
        }
        let csr = Csr::from_edges(n, &edges).unwrap();
        let features: Vec<f32> = (0..n * 2).map(|i| 0.05 * (i as f32 + 1.0) - 0.3).collect();
        let ds = Dataset::Node(NodeData {
            name: "unit".into(),
            csr,
            num_features: 2,
            num_classes: 2,
            features,
            labels: vec![0; n],
            train_mask: vec![false; n],
            val_mask: vec![false; n],
            test_mask: vec![false; n],
        });
        (model, ds)
    }

    #[test]
    fn native_cached_batches_match_unprepared_forward() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let Dataset::Node(nd) = &ds else { unreachable!() };
        let ef = EdgeForm::from_csr(&nd.csr);
        let input = GraphInput::node_level(&nd.features, 2, &ef);
        let want = forward_fp_with(&model, &input, &ParallelConfig::serial());

        // first batch computes + caches, second serves from the cache —
        // both bitwise identical to the per-call shim
        for _ in 0..2 {
            let out = exec.run_node_batch(&[0, 1, 2]).unwrap();
            for (v, row) in out.iter().enumerate() {
                assert_eq!(row.as_slice(), want.row(v));
            }
        }
        assert_eq!(exec.epoch(), 0);
    }

    #[test]
    fn native_epoch_bump_invalidates_but_stays_consistent() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let before = exec.run_node_batch(&[0, 2]).unwrap();
        exec.bump_epoch();
        assert_eq!(exec.epoch(), 1);
        // immutable state ⇒ recompute under the new epoch is identical
        let after = exec.run_node_batch(&[0, 2]).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn native_out_of_range_node_is_an_error_not_a_panic() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let err = exec.run_node_batch(&[99]).unwrap_err();
        assert!(format!("{err}").contains("out of range"));
    }

    #[test]
    fn native_rejects_malformed_model_at_construction() {
        let (mut model, ds) = tiny_session();
        model.layers[0].w = None;
        let err = NativeExecutor::new(model, Some(&ds)).unwrap_err();
        assert!(format!("{err}").contains("missing w"));
    }

    #[test]
    fn delta_recomputes_frontier_and_preserves_untouched_rows_bitwise() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let all: Vec<u32> = (0..6).collect();
        let before = exec.run_node_batch(&all).unwrap();
        assert_eq!(exec.epoch(), 0);

        // add a directed edge 5→0: node 0's row + degree change; the
        // 1-layer frontier is {0} ∪ out-neighbours of {0} = {0, 1}
        let report = exec
            .apply_delta(&GraphDelta {
                add_edges: vec![(5, 0)],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(exec.epoch(), 1, "epoch bumps exactly once per delta");
        assert_eq!(report.recomputed_rows, 2, "only the frontier recomputes");
        assert_eq!(report.num_nodes, 6);

        let after = exec.run_node_batch(&all).unwrap();
        // untouched rows survive the epoch change bit-for-bit
        for v in 2..6 {
            assert_eq!(before[v], after[v], "row {v} should be untouched");
        }
        // the mutated destination genuinely moved
        assert_ne!(before[0], after[0], "row 0 must reflect the new edge");

        // a second (empty) delta still bumps exactly once and touches no rows
        let report = exec.apply_delta(&GraphDelta::default()).unwrap();
        assert_eq!(report.epoch, 2);
        assert_eq!(report.recomputed_rows, 0);
        let again = exec.run_node_batch(&all).unwrap();
        assert_eq!(after, again);

        // a manual epoch bump on a now-dynamic session recomputes AND
        // re-records the activation cache on the next batch; a further
        // delta then patches off that recorded recompute
        exec.bump_epoch();
        assert_eq!(exec.epoch(), 3);
        let recomputed = exec.run_node_batch(&all).unwrap();
        assert_eq!(after, recomputed, "recompute must reproduce the patched state");
        let report = exec
            .apply_delta(&GraphDelta {
                add_edges: vec![(0, 5)],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(report.epoch, 4);
        let last = exec.run_node_batch(&all).unwrap();
        // frontier of (0,5): {5} ∪ out-neighbours of deg-changed {5} =
        // {0, 4, 5} (0 gained 5 as in-neighbour in the first delta); the
        // middle of the path stays bit-identical
        for v in 1..4 {
            assert_eq!(recomputed[v], last[v], "row {v} should be untouched");
        }
        assert_ne!(recomputed[5], last[5], "row 5 must reflect the new edge");
    }

    #[test]
    fn delta_appends_node_with_nns_assigned_params() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        // node 6 arrives with features and links to node 0
        let report = exec
            .apply_delta(&GraphDelta {
                add_nodes: 1,
                new_features: vec![0.2, -0.1],
                add_edges: vec![(6, 0), (0, 6)],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(report.num_nodes, 7);
        assert_eq!(report.new_nodes, 1);
        assert_eq!(exec.resident_nodes(), 7);
        assert_eq!(exec.capacity().0, 7);
        // the unseen node serves logits like any resident node
        let out = exec.run_node_batch(&[6]).unwrap();
        assert_eq!(out[0].len(), 2);
        assert!(out[0].iter().all(|v| v.is_finite()));
        // and its quantization params were assigned from the learned table
        let params = exec.resident_quant_params();
        let feat = params[0].0.as_ref().unwrap();
        assert_eq!(feat.len(), 7);
        assert!(feat.steps[6].is_finite() && feat.steps[6] > 0.0);
        assert!(feat.bits[6] >= 1);
    }

    #[test]
    fn sharded_session_serves_and_patches_like_unsharded() {
        let (model, ds) = path_session();
        let plain = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let sharded = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_shards(3)
            .unwrap();
        let all: Vec<u32> = (0..6).collect();
        // per-shard block serving == single-shard cache serving, bitwise
        assert_eq!(
            plain.run_node_batch(&all).unwrap(),
            sharded.run_node_batch(&all).unwrap()
        );
        let (s, _stats) = sharded.shard_stats().unwrap();
        assert_eq!(s, 3);
        assert!(plain.shard_stats().is_none());

        // a delta patches both sessions to the same bits; shard accounting
        // only reports on the sharded one, and the epoch bump is
        // exactly-once across shards
        let delta = GraphDelta {
            add_nodes: 1,
            new_features: vec![0.2, -0.1],
            add_edges: vec![(6, 0), (0, 6)],
            ..Default::default()
        };
        let rp = plain.apply_delta(&delta).unwrap();
        let rs = sharded.apply_delta(&delta).unwrap();
        assert_eq!(rp.epoch, rs.epoch);
        assert_eq!(rs.num_nodes, 7);
        assert_eq!(rp.shards_touched, 0);
        assert!(rs.shards_touched >= 1, "the owning shard must rebuild");
        assert_eq!(sharded.epoch(), 1, "one bump per delta across shards");
        let all7: Vec<u32> = (0..7).collect();
        let want = plain.run_node_batch(&all7).unwrap();
        let got = sharded.run_node_batch(&all7).unwrap();
        assert_eq!(want, got, "post-delta sharded rows diverged");

        // empty delta: blocks retag under the new epoch, rows bit-identical
        let re = sharded.apply_delta(&GraphDelta::default()).unwrap();
        assert_eq!(re.shards_touched, 0);
        assert_eq!(sharded.epoch(), 2);
        assert_eq!(got, sharded.run_node_batch(&all7).unwrap());

        // manual epoch bump: the shard-parallel recompute reproduces the
        // patched state bit-for-bit
        sharded.bump_epoch();
        assert_eq!(got, sharded.run_node_batch(&all7).unwrap());
    }

    #[test]
    fn with_shards_rejects_non_node_level_sessions() {
        let (mut model, _ds) = tiny_session();
        model.node_level = false;
        model.num_nodes = 0;
        let exec = NativeExecutor::new(model, None).unwrap();
        let err = exec.with_shards(2).unwrap_err();
        assert!(format!("{err}").contains("node-level"), "got: {err}");
    }

    #[test]
    fn delta_rejects_malformed_input_without_mutating() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let all: Vec<u32> = (0..6).collect();
        let before = exec.run_node_batch(&all).unwrap();
        // wrong feature arity
        assert!(exec
            .apply_delta(&GraphDelta {
                add_nodes: 1,
                new_features: vec![0.0; 3],
                ..Default::default()
            })
            .is_err());
        // non-finite features
        assert!(exec
            .apply_delta(&GraphDelta {
                add_nodes: 1,
                new_features: vec![0.0, f32::NAN],
                ..Default::default()
            })
            .is_err());
        // out-of-range edge
        assert!(exec
            .apply_delta(&GraphDelta {
                add_edges: vec![(0, 42)],
                ..Default::default()
            })
            .is_err());
        // nothing changed: same epoch, same logits
        assert_eq!(exec.epoch(), 0);
        assert_eq!(exec.run_node_batch(&all).unwrap(), before);
    }

    #[test]
    fn cold_session_delta_then_first_batch_is_consistent() {
        // apply a delta before any classify batch: the executor warms its
        // own activation cache, and the first served batch must equal a
        // freshly-built session over the post-delta graph
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let delta = GraphDelta {
            add_edges: vec![(5, 0), (0, 5)],
            ..Default::default()
        };
        exec.apply_delta(&delta).unwrap();
        let got = exec.run_node_batch(&(0..6).collect::<Vec<u32>>()).unwrap();

        let Dataset::Node(nd) = &ds else { unreachable!() };
        let mut edges = nd.csr.edge_list();
        edges.push((5, 0));
        edges.push((0, 5));
        let csr = Csr::from_edges(6, &edges).unwrap();
        let ef = EdgeForm::from_csr(&csr);
        let input = GraphInput::node_level(&nd.features, 2, &ef);
        let want = forward_fp_with(&model, &input, &ParallelConfig::serial());
        for (v, row) in got.iter().enumerate() {
            assert_eq!(row.as_slice(), want.row(v), "row {v}");
        }
    }

    fn tmp_state_dir(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("a2q_exec_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn assert_params_equal(
        want: &[(Option<NodeQuantParams>, Option<NodeQuantParams>)],
        got: &[(Option<NodeQuantParams>, Option<NodeQuantParams>)],
    ) {
        assert_eq!(want.len(), got.len());
        for (l, ((wf, wf2), (gf, gf2))) in want.iter().zip(got).enumerate() {
            for (tag, w, g) in [("feat", wf, gf), ("feat2", wf2, gf2)] {
                match (w, g) {
                    (None, None) => {}
                    (Some(w), Some(g)) => {
                        assert_eq!(w.steps, g.steps, "layer {l} {tag} steps");
                        assert_eq!(w.bits, g.bits, "layer {l} {tag} bits");
                        assert_eq!(w.signed, g.signed, "layer {l} {tag} signed");
                    }
                    _ => panic!("layer {l} {tag} presence diverged"),
                }
            }
        }
    }

    #[test]
    fn persistence_restart_reproduces_logits_bitwise() {
        let dir = tmp_state_dir("restart");
        let (model, ds) = path_session();
        let mut cfg = PersistConfig::new(&dir);
        cfg.snapshot_every = 2; // force a mid-stream snapshot rotation
        let (exec, restore) = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_persistence(cfg.clone())
            .unwrap();
        assert!(!restore.restored_snapshot);
        assert_eq!(restore.replayed_deltas, 0);
        let deltas = [
            GraphDelta {
                add_edges: vec![(5, 0), (0, 5)],
                ..Default::default()
            },
            GraphDelta {
                add_nodes: 1,
                new_features: vec![0.2, -0.1],
                add_edges: vec![(6, 0), (0, 6)],
                ..Default::default()
            },
            GraphDelta::default(),
            GraphDelta {
                remove_edges: vec![(5, 0)],
                ..Default::default()
            },
        ];
        for d in &deltas {
            exec.apply_delta(d).unwrap();
        }
        let all: Vec<u32> = (0..7).collect();
        let want = exec.run_node_batch(&all).unwrap();
        let want_params = exec.resident_quant_params();
        let epoch = exec.epoch();
        drop(exec);

        let (back, restore) = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_persistence(cfg)
            .unwrap();
        assert!(restore.restored_snapshot, "snapshot_every=2 must have rotated");
        assert!(
            restore.replayed_deltas < deltas.len(),
            "recovery replays the tail, not the whole log"
        );
        assert_eq!(restore.epoch, epoch, "epoch counter survives the restart");
        assert_eq!(restore.num_nodes, 7);
        assert_eq!(back.run_node_batch(&all).unwrap(), want, "restart parity");
        assert_params_equal(&want_params, &back.resident_quant_params());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn persistence_never_logs_a_rejected_delta() {
        let dir = tmp_state_dir("reject");
        let (model, ds) = path_session();
        let (exec, _) = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_persistence(PersistConfig::new(&dir))
            .unwrap();
        exec.apply_delta(&GraphDelta {
            add_edges: vec![(5, 0), (0, 5)],
            ..Default::default()
        })
        .unwrap();
        assert!(exec
            .apply_delta(&GraphDelta {
                add_edges: vec![(0, 42)],
                ..Default::default()
            })
            .is_err());
        let (_, records, _) = exec.wal_stats().unwrap();
        assert_eq!(records, 1, "the rejected delta must not be in the log");
        drop(exec);
        let (back, restore) = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_persistence(PersistConfig::new(&dir))
            .unwrap();
        assert_eq!(restore.replayed_deltas, 1);
        assert_eq!(back.resident_nodes(), 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hot_swap_installs_new_weights_with_one_epoch_bump() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        // evolve the resident graph first: the swap must preserve the
        // NNS-extended per-node state
        exec.apply_delta(&GraphDelta {
            add_nodes: 1,
            new_features: vec![0.2, -0.1],
            add_edges: vec![(6, 0), (0, 6)],
            ..Default::default()
        })
        .unwrap();
        let all: Vec<u32> = (0..7).collect();
        let before = exec.run_node_batch(&all).unwrap();
        let params_before = exec.resident_quant_params();

        let mut v2 = model.clone();
        v2.name = "path-v2".into();
        v2.layers[0].w =
            Some(Matrix::from_vec(2, 2, vec![0.8, -0.25, 0.6, 1.1]).unwrap());
        let report = exec.hot_swap(v2.clone()).unwrap();
        assert_eq!(report.epoch, 2, "delta bump + exactly one swap bump");
        assert_eq!(report.model_name, "path-v2");
        assert!(!report.snapshot_installed, "volatile session");

        let after = exec.run_node_batch(&all).unwrap();
        assert_ne!(before, after, "new weights must actually serve");
        assert_eq!(exec.resident_nodes(), 7, "evolved graph survives the swap");
        assert_params_equal(&params_before, &exec.resident_quant_params());

        // reference: a from-scratch session over the evolved graph with the
        // grafted params serves the same bits
        let Dataset::Node(nd) = &ds else { unreachable!() };
        let mut edges = nd.csr.edge_list();
        edges.push((6, 0));
        edges.push((0, 6));
        let csr = Csr::from_edges(7, &edges).unwrap();
        let mut features = nd.features.clone();
        features.extend_from_slice(&[0.2, -0.1]);
        let mut fresh_model = v2;
        fresh_model.num_nodes = 7;
        let (feat, feat2) = params_before[0].clone();
        fresh_model.layers[0].feat = feat;
        fresh_model.layers[0].feat2 = feat2;
        let fresh_ds = Dataset::Node(NodeData {
            name: "unit".into(),
            csr,
            num_features: 2,
            num_classes: 2,
            features,
            labels: vec![0; 7],
            train_mask: vec![false; 7],
            val_mask: vec![false; 7],
            test_mask: vec![false; 7],
        });
        let fresh = NativeExecutor::new(fresh_model, Some(&fresh_ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        assert_eq!(
            fresh.run_node_batch(&all).unwrap(),
            after,
            "swapped session must match a from-scratch rebuild bitwise"
        );
    }

    #[test]
    fn hot_swap_rejects_incompatible_shapes() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds)).unwrap();
        let mut bad = model;
        bad.out_dim = 3;
        let err = exec.hot_swap(bad).unwrap_err();
        assert!(format!("{err}").contains("shape-compatible"), "got: {err}");
        assert_eq!(exec.epoch(), 0, "a rejected swap must not bump the epoch");
    }

    #[test]
    fn hot_swap_forces_a_durable_snapshot() {
        let dir = tmp_state_dir("swapsnap");
        let (model, ds) = path_session();
        let (exec, _) = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_persistence(PersistConfig::new(&dir))
            .unwrap();
        exec.apply_delta(&GraphDelta {
            add_edges: vec![(5, 0), (0, 5)],
            ..Default::default()
        })
        .unwrap();
        let mut v2 = model.clone();
        v2.name = "path-v2".into();
        let report = exec.hot_swap(v2.clone()).unwrap();
        assert!(report.snapshot_installed, "durable swaps must snapshot");
        let (_, records, _) = exec.wal_stats().unwrap();
        assert_eq!(records, 0, "the snapshot rotation empties the WAL");
        let all: Vec<u32> = (0..6).collect();
        let want = exec.run_node_batch(&all).unwrap();
        drop(exec);
        // restart against the OLD artifact: the snapshot names the swapped
        // model, so recovery refuses instead of silently diverging
        let err = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_persistence(PersistConfig::new(&dir))
            .map(|_| ())
            .unwrap_err();
        assert!(format!("{err}").contains("path-v2"), "got: {err}");
        // restart against the swapped artifact restores bit-for-bit
        let (back, restore) = NativeExecutor::new(v2, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_persistence(PersistConfig::new(&dir))
            .unwrap();
        assert!(restore.restored_snapshot);
        assert_eq!(back.run_node_batch(&all).unwrap(), want);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hot_swap_under_concurrent_classify_traffic_never_tears() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let all: Vec<u32> = (0..6).collect();
        let before = exec.run_node_batch(&all).unwrap();
        let mut v2 = model.clone();
        v2.name = "path-v2".into();
        v2.layers[0].w =
            Some(Matrix::from_vec(2, 2, vec![0.8, -0.25, 0.6, 1.1]).unwrap());
        let after_want = {
            let reference = NativeExecutor::new(
                {
                    let mut m = v2.clone();
                    m.layers[0].feat = model.layers[0].feat.clone();
                    m
                },
                Some(&ds),
            )
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
            reference.run_node_batch(&all).unwrap()
        };
        std::thread::scope(|scope| {
            let stop = std::sync::atomic::AtomicBool::new(false);
            let exec_ref = &exec;
            let all_ref = &all;
            let before_ref = &before;
            let after_ref = &after_want;
            let stop_ref = &stop;
            let readers: Vec<_> = (0..3)
                .map(|_| {
                    scope.spawn(move || {
                        let mut served = 0usize;
                        while !stop_ref.load(Ordering::Acquire) {
                            let out = exec_ref.run_node_batch(all_ref).unwrap();
                            // every batch is served whole from one epoch's
                            // logits: it is the old bits or the new bits,
                            // never a mixture
                            assert!(
                                &out == before_ref || &out == after_ref,
                                "torn or stale batch under hot swap"
                            );
                            served += 1;
                        }
                        served
                    })
                })
                .collect();
            let report = exec.hot_swap(v2.clone()).unwrap();
            assert_eq!(report.epoch, 1, "exactly one bump under traffic");
            // let the readers observe the swapped weights for a while
            for _ in 0..50 {
                let out = exec.run_node_batch(&all).unwrap();
                assert_eq!(&out, &after_want);
            }
            stop.store(true, Ordering::Release);
            let total: usize = readers.into_iter().map(|h| h.join().unwrap()).sum();
            assert!(total > 0, "readers must have served during the swap");
        });
        assert_eq!(exec.epoch(), 1);
    }
}
