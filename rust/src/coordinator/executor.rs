//! Execution backends behind the coordinator.
//!
//! * [`PjrtExecutor`] — runs the AOT HLO artifact through `runtime::Engine`
//!   (the production path: python never touched).
//! * [`NativeExecutor`] — pure-rust integer/fp path (`gnn::infer`), used as
//!   a cross-check backend and for environments without the PJRT library.
//! * [`MockExecutor`] — deterministic fake for coordinator unit tests.

use crate::error::{Error, Result};
use crate::gnn::{forward_fp_with, forward_int_with, GnnModel, GraphInput};
use crate::graph::batch::GraphBatch;
use crate::graph::io::{Dataset, NodeData, SmallGraph};
use crate::graph::norm::EdgeForm;
use crate::runtime::engine::EngineHandle;
use crate::runtime::{ExecInput, ModelArtifact};
use crate::util::threadpool::ParallelConfig;

/// A backend able to run the two batch kinds.
pub trait BatchExecutor: Send + Sync {
    /// Full-graph node classification; returns per-queried-node logits.
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;
    /// Batched graph-level prediction; returns per-graph outputs.
    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>>;
    /// Executable batch capacity (nodes, graph slots); node-level models
    /// report (N, 0).
    fn capacity(&self) -> (usize, usize);
    fn out_dim(&self) -> usize;
}

// ---------------------------------------------------------------------------
// PJRT
// ---------------------------------------------------------------------------

/// Runs the compiled HLO artifact (via the engine service thread).
pub struct PjrtExecutor {
    engine: EngineHandle,
    key: String,
    node: Option<NodeSide>,
    graph_caps: Option<(usize, usize, usize)>, // (nodes, edges, graphs)
    feat_dim: usize,
    out_dim: usize,
    /// surviving logical parameter indices (XLA drops unused entry params)
    param_map: Vec<usize>,
    /// weight tensors appended after the data inputs (manifest order)
    weight_inputs: Vec<ExecInput>,
}

struct NodeSide {
    features: Vec<f32>,
    edges: EdgeForm,
    num_nodes: usize,
}

impl PjrtExecutor {
    /// Build from an artifact + its dataset (node-level needs the resident
    /// graph; graph-level needs only capacities).
    pub fn new(
        engine: EngineHandle,
        artifact: &ModelArtifact,
        dataset: Option<&Dataset>,
    ) -> Result<PjrtExecutor> {
        engine.load_artifact(artifact)?;
        let param_map = artifact.param_map()?;
        let weight_inputs = artifact.weight_inputs()?;
        let mut node = None;
        let mut graph_caps = None;
        if artifact.node_level {
            let ds = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(NodeSide {
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        } else {
            graph_caps = Some((
                artifact.num_nodes,
                artifact.num_edges,
                artifact.graph_capacity,
            ));
        }
        Ok(PjrtExecutor {
            engine,
            key: artifact.name.clone(),
            node,
            graph_caps,
            feat_dim: artifact.in_dim,
            out_dim: artifact.out_dim,
            param_map,
            weight_inputs,
        })
    }

    /// Append the weight parameters, then keep only the logical inputs the
    /// compiled program still expects (XLA drops unused entry params).
    fn select_params(&self, data: Vec<ExecInput>) -> Vec<ExecInput> {
        let mut logical: Vec<Option<ExecInput>> = data
            .into_iter()
            .chain(self.weight_inputs.iter().cloned())
            .map(Some)
            .collect();
        self.param_map
            .iter()
            .filter_map(|&l| logical.get_mut(l).and_then(|slot| slot.take()))
            .collect()
    }

    fn logits_full_graph(&self) -> Result<Vec<f32>> {
        let side = self
            .node
            .as_ref()
            .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(side.features.clone(), side.num_nodes, self.feat_dim),
            ExecInput::i32_1d(side.edges.src.clone()),
            ExecInput::i32_1d(side.edges.dst.clone()),
            ExecInput::f32_1d(side.edges.gcn_w.clone()),
            ExecInput::f32_1d(side.edges.sum_w.clone()),
        ]);
        self.engine.execute(&self.key, inputs)
    }
}

impl BatchExecutor for PjrtExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        let logits = self.logits_full_graph()?;
        let c = self.out_dim;
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if (v + 1) * c > logits.len() {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits[v * c..(v + 1) * c].to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let (cap_n, cap_e, cap_g) = self
            .graph_caps
            .ok_or_else(|| Error::coordinator("not a graph-level executor"))?;
        let batch = GraphBatch::pack(graphs, self.feat_dim, cap_n, cap_e, cap_g)?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(batch.features, cap_n, self.feat_dim),
            ExecInput::i32_1d(batch.src),
            ExecInput::i32_1d(batch.dst),
            ExecInput::f32_1d(batch.gcn_w),
            ExecInput::f32_1d(batch.sum_w),
            ExecInput::i32_1d(batch.node2graph),
            ExecInput::f32_1d(batch.node_mask),
        ]);
        let out = self.engine.execute(&self.key, inputs)?;
        let c = self.out_dim;
        Ok((0..graphs.len()).map(|g| out[g * c..(g + 1) * c].to_vec()).collect())
    }

    fn capacity(&self) -> (usize, usize) {
        match (&self.node, self.graph_caps) {
            (Some(n), _) => (n.num_nodes, 0),
            (None, Some((n, _e, g))) => (n, g),
            _ => (0, 0),
        }
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

// ---------------------------------------------------------------------------
// Native
// ---------------------------------------------------------------------------

/// Pure-rust backend over `gnn::infer` (fp emulation by default, true
/// integer path opt-in).  Carries its own [`ParallelConfig`] so the
/// serving stack controls the intra-op parallelism budget per executor.
pub struct NativeExecutor {
    model: GnnModel,
    node: Option<NodeSide>,
    caps: (usize, usize, usize),
    parallel: ParallelConfig,
    use_int_path: bool,
}

impl NativeExecutor {
    pub fn new(model: GnnModel, dataset: Option<&Dataset>) -> Result<NativeExecutor> {
        let mut node = None;
        if model.node_level {
            let ds: &NodeData = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(NodeSide {
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        }
        let caps = (
            model.num_nodes,
            model
                .manifest
                .get("num_edges")
                .and_then(|v| v.as_usize())
                .unwrap_or(model.num_nodes * 8),
            model.graph_capacity.max(1),
        );
        Ok(NativeExecutor {
            model,
            node,
            caps,
            parallel: ParallelConfig::from_env(),
            use_int_path: false,
        })
    }

    /// Set the intra-op parallelism budget (builder style).
    pub fn with_parallelism(mut self, cfg: ParallelConfig) -> NativeExecutor {
        self.parallel = cfg;
        self
    }

    /// Route through `forward_int` (true integer arithmetic over packed
    /// codes) instead of the fp emulation.
    pub fn with_int_path(mut self, on: bool) -> NativeExecutor {
        self.use_int_path = on;
        self
    }

    pub fn parallelism(&self) -> ParallelConfig {
        self.parallel
    }

    fn forward(&self, input: &GraphInput) -> crate::tensor::Matrix<f32> {
        if self.use_int_path {
            forward_int_with(&self.model, input, &self.parallel)
        } else {
            forward_fp_with(&self.model, input, &self.parallel)
        }
    }
}

impl BatchExecutor for NativeExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        let side = self
            .node
            .as_ref()
            .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
        let input = GraphInput::node_level(&side.features, self.model.in_dim, &side.edges);
        let logits = self.forward(&input);
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if v >= logits.rows {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits.row(v).to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let (cap_n, cap_e, cap_g) = self.caps;
        let batch = GraphBatch::pack(graphs, self.model.in_dim, cap_n, cap_e, cap_g)?;
        let input = GraphInput::batch(&batch);
        let out = self.forward(&input);
        Ok((0..graphs.len()).map(|g| out.row(g).to_vec()).collect())
    }

    fn capacity(&self) -> (usize, usize) {
        if self.model.node_level {
            (self.caps.0, 0)
        } else {
            (self.caps.0, self.caps.2)
        }
    }

    fn out_dim(&self) -> usize {
        self.model.out_dim
    }
}

// ---------------------------------------------------------------------------
// Mock
// ---------------------------------------------------------------------------

/// Deterministic test double: returns node id / node count as "logits",
/// optionally sleeping to emulate execution latency.
pub struct MockExecutor {
    pub out_dim: usize,
    pub latency: std::time::Duration,
}

impl Default for MockExecutor {
    fn default() -> Self {
        MockExecutor {
            out_dim: 2,
            latency: std::time::Duration::ZERO,
        }
    }
}

impl BatchExecutor for MockExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(node_ids
            .iter()
            .map(|&v| {
                let mut out = vec![0.0; self.out_dim];
                out[v as usize % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(graphs
            .iter()
            .map(|g| {
                let mut out = vec![0.0; self.out_dim];
                out[g.num_nodes() % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn capacity(&self) -> (usize, usize) {
        (1024, 16)
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let m = MockExecutor::default();
        let out = m.run_node_batch(&[0, 1, 2]).unwrap();
        assert_eq!(out[0], vec![1.0, 0.0]);
        assert_eq!(out[1], vec![0.0, 1.0]);
        assert_eq!(out[2], vec![1.0, 0.0]);
    }
}
