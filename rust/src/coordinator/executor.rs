//! Execution backends behind the coordinator.
//!
//! * [`PjrtExecutor`] — runs the AOT HLO artifact through `runtime::Engine`
//!   (the production path: python never touched).
//! * [`NativeExecutor`] — pure-rust integer/fp path (`gnn::infer`), used as
//!   a cross-check backend and for environments without the PJRT library.
//! * [`MockExecutor`] — deterministic fake for coordinator unit tests.
//!
//! Both real executors are **prepared sessions**: everything derivable
//! from the loaded model alone is computed at construction
//! ([`gnn::PreparedModel`], the resident graph's
//! [`AggregationPlan`]), and full-graph node-level logits are cached under
//! an explicit **epoch** version — `run_node_batch` is a slice-copy after
//! the first batch of an epoch, and [`NativeExecutor::bump_epoch`] /
//! [`PjrtExecutor::bump_epoch`] invalidate the cache when a weight or
//! feature swap mutates the resident state.
//!
//! [`NativeExecutor::apply_delta`] is the **dynamic-graph serving path**:
//! a [`GraphDelta`] is applied incrementally (CSR row repair, GCN-weight
//! splice, sort-free plan reconstruction — all bitwise-identical to a
//! from-scratch rebuild), unseen nodes get their quantization parameters
//! assigned online through the paper's NNS, the epoch bumps exactly once,
//! and only the delta's L-hop reverse frontier of logits rows is
//! recomputed against the resident per-layer activation cache — untouched
//! rows survive the epoch change bit-for-bit.
//!
//! [`NativeExecutor::with_shards`] turns a node-level session into a
//! **sharded resident**: the graph is partitioned degree-aware
//! (`graph::shard`), epoch recomputes run shard-parallel with a
//! halo-exchange step between layers (`gnn::forward_{fp,int}_sharded`,
//! bitwise identical to the single-shard path), node batches are served
//! from per-shard logits blocks, and `apply_delta` rebuilds only the
//! owning shards' local views — the epoch bump stays exactly-once and
//! atomic *across* shards.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::error::{Error, Result};
use crate::gnn::incremental::{build_assign_tables, patch_activations, NnsAssignTables};
use crate::gnn::{
    forward_fp_prepared_recording, forward_fp_prepared_with_plan, forward_fp_sharded,
    forward_fp_sharded_recording, forward_int_prepared_recording,
    forward_int_prepared_with_plan, forward_int_sharded, forward_int_sharded_recording,
    GnnModel, GraphInput, PreparedModel,
};
use crate::graph::batch::GraphBatch;
use crate::graph::csr::Csr;
use crate::graph::delta::{dirty_frontier, GraphDelta};
use crate::graph::io::{Dataset, NodeData, SmallGraph};
use crate::graph::norm::{AggregationPlan, EdgeForm};
use crate::graph::shard::{HaloStats, ShardedGraph};
use crate::quant::mixed::NodeQuantParams;
use crate::runtime::engine::EngineHandle;
use crate::runtime::{ExecInput, ModelArtifact};
use crate::tensor::Matrix;
use crate::util::threadpool::ParallelConfig;

/// Outcome of one applied [`GraphDelta`].
#[derive(Debug, Clone)]
pub struct DeltaReport {
    /// logits-cache epoch after the update (bumps exactly once per delta)
    pub epoch: u64,
    /// resident node count after the update
    pub num_nodes: usize,
    /// final-layer logits rows recomputed (the L-hop reverse frontier)
    pub recomputed_rows: usize,
    /// nodes appended (each got NNS-assigned quantization parameters)
    pub new_nodes: usize,
    /// sharded residents: shards whose local view was rebuilt (owners of
    /// dirty rows + shards mirroring a degree-changed node); 0 unsharded
    pub shards_touched: usize,
    /// sharded residents: Σ mirrored halo nodes after the update; 0
    /// unsharded
    pub halo_nodes: usize,
}

/// A backend able to run the two batch kinds.
pub trait BatchExecutor: Send + Sync {
    /// Full-graph node classification; returns per-queried-node logits.
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>>;
    /// Batched graph-level prediction; returns per-graph outputs.
    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>>;
    /// Mutate the resident graph.  Backends without a mutable resident
    /// graph keep this default rejection.
    fn apply_delta(&self, _delta: &GraphDelta) -> Result<DeltaReport> {
        Err(Error::coordinator(
            "this executor does not support resident-graph updates",
        ))
    }
    /// Executable batch capacity (nodes, graph slots); node-level models
    /// report (N, 0).
    fn capacity(&self) -> (usize, usize);
    fn out_dim(&self) -> usize;
}

/// Versioned full-graph logits cache: the resident graph and model are
/// immutable within an epoch, so the full forward runs once per epoch and
/// every subsequent node batch is a row slice-copy.
struct LogitsCache<T> {
    epoch: AtomicU64,
    slot: Mutex<Option<(u64, Arc<T>)>>,
}

impl<T> LogitsCache<T> {
    fn new() -> Self {
        LogitsCache {
            epoch: AtomicU64::new(0),
            slot: Mutex::new(None),
        }
    }

    fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    fn bump(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Lock the cache slot — the one audited lock acquisition.
    fn locked(&self) -> MutexGuard<'_, Option<(u64, Arc<T>)>> {
        // a2q-lint: allow(panic-path) poisoning requires a prior panic while
        // holding this short-lived lock; there is no state to salvage
        self.slot.lock().unwrap()
    }

    /// Fetch the cached value for the current epoch, computing (outside the
    /// lock) and installing it on miss.  The closure receives the epoch
    /// the computation is for.  A concurrent [`Self::bump`] during compute
    /// keeps the stale result out of the cache — the caller still gets the
    /// value it computed.
    fn get_or_compute(&self, compute: impl FnOnce(u64) -> Result<T>) -> Result<Arc<T>> {
        let epoch = self.epoch();
        if let Some((e, cached)) = self.locked().as_ref() {
            if *e == epoch {
                return Ok(Arc::clone(cached));
            }
        }
        let value = Arc::new(compute(epoch)?);
        let mut guard = self.locked();
        if self.epoch() == epoch {
            *guard = Some((epoch, Arc::clone(&value)));
        }
        Ok(value)
    }

    /// Install a value for `epoch` (no-op if the epoch already moved on) —
    /// the partial-invalidation path primes the new epoch with its patched
    /// logits so the next batch is a slice-copy, not a recompute.
    fn set(&self, epoch: u64, value: Arc<T>) {
        let mut guard = self.locked();
        if self.epoch() == epoch {
            *guard = Some((epoch, value));
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT
// ---------------------------------------------------------------------------

/// Runs the compiled HLO artifact (via the engine service thread).
pub struct PjrtExecutor {
    engine: EngineHandle,
    key: String,
    node: Option<PjrtNodeSide>,
    graph_caps: Option<(usize, usize, usize)>, // (nodes, edges, graphs)
    feat_dim: usize,
    out_dim: usize,
    /// surviving logical parameter indices (XLA drops unused entry params)
    param_map: Vec<usize>,
    /// weight tensors appended after the data inputs (manifest order)
    weight_inputs: Vec<ExecInput>,
    /// versioned full-graph logits (node-level serving hot path)
    logits: LogitsCache<Vec<f32>>,
}

struct PjrtNodeSide {
    features: Vec<f32>,
    edges: EdgeForm,
    num_nodes: usize,
}

impl PjrtExecutor {
    /// Build from an artifact + its dataset (node-level needs the resident
    /// graph; graph-level needs only capacities).
    pub fn new(
        engine: EngineHandle,
        artifact: &ModelArtifact,
        dataset: Option<&Dataset>,
    ) -> Result<PjrtExecutor> {
        engine.load_artifact(artifact)?;
        let param_map = artifact.param_map()?;
        let weight_inputs = artifact.weight_inputs()?;
        let mut node = None;
        let mut graph_caps = None;
        if artifact.node_level {
            let ds = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(PjrtNodeSide {
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        } else {
            graph_caps = Some((
                artifact.num_nodes,
                artifact.num_edges,
                artifact.graph_capacity,
            ));
        }
        Ok(PjrtExecutor {
            engine,
            key: artifact.name.clone(),
            node,
            graph_caps,
            feat_dim: artifact.in_dim,
            out_dim: artifact.out_dim,
            param_map,
            weight_inputs,
            logits: LogitsCache::new(),
        })
    }

    /// Append the weight parameters, then keep only the logical inputs the
    /// compiled program still expects (XLA drops unused entry params).
    fn select_params(&self, data: Vec<ExecInput>) -> Vec<ExecInput> {
        let mut logical: Vec<Option<ExecInput>> = data
            .into_iter()
            .chain(self.weight_inputs.iter().cloned())
            .map(Some)
            .collect();
        self.param_map
            .iter()
            .filter_map(|&l| logical.get_mut(l).and_then(|slot| slot.take()))
            .collect()
    }

    fn logits_full_graph(&self) -> Result<Vec<f32>> {
        let side = self
            .node
            .as_ref()
            .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(side.features.clone(), side.num_nodes, self.feat_dim),
            ExecInput::i32_1d(side.edges.src.clone()),
            ExecInput::i32_1d(side.edges.dst.clone()),
            ExecInput::f32_1d(side.edges.gcn_w.clone()),
            ExecInput::f32_1d(side.edges.sum_w.clone()),
        ]);
        self.engine.execute(&self.key, inputs)
    }

    /// Invalidate the full-graph logits cache (call after swapping the
    /// resident weights or features on the engine side).
    pub fn bump_epoch(&self) {
        self.logits.bump();
    }

    /// Current logits-cache epoch (diagnostics).
    pub fn epoch(&self) -> u64 {
        self.logits.epoch()
    }
}

impl BatchExecutor for PjrtExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        // PJRT execution of the full graph is identical for every node
        // batch of an epoch — serve subsequent batches from the cache.
        let logits = self
            .logits
            .get_or_compute(|_epoch| self.logits_full_graph())?;
        let c = self.out_dim;
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if (v + 1) * c > logits.len() {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits[v * c..(v + 1) * c].to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let (cap_n, cap_e, cap_g) = self
            .graph_caps
            .ok_or_else(|| Error::coordinator("not a graph-level executor"))?;
        let batch = GraphBatch::pack(graphs, self.feat_dim, cap_n, cap_e, cap_g)?;
        let inputs = self.select_params(vec![
            ExecInput::f32_2d(batch.features, cap_n, self.feat_dim),
            ExecInput::i32_1d(batch.src),
            ExecInput::i32_1d(batch.dst),
            ExecInput::f32_1d(batch.gcn_w),
            ExecInput::f32_1d(batch.sum_w),
            ExecInput::i32_1d(batch.node2graph),
            ExecInput::f32_1d(batch.node_mask),
        ]);
        let out = self.engine.execute(&self.key, inputs)?;
        let c = self.out_dim;
        Ok((0..graphs.len()).map(|g| out[g * c..(g + 1) * c].to_vec()).collect())
    }

    fn capacity(&self) -> (usize, usize) {
        match (&self.node, self.graph_caps) {
            (Some(n), _) => (n.num_nodes, 0),
            (None, Some((n, _e, g))) => (n, g),
            _ => (0, 0),
        }
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

// ---------------------------------------------------------------------------
// Native
// ---------------------------------------------------------------------------

/// Resident graph state of a node-level session.
struct NodeSide {
    csr: Csr,
    features: Vec<f32>,
    edges: EdgeForm,
    num_nodes: usize,
}

/// Sharded resident state: the partitioned graph plus one epoch-tagged
/// logits block per shard (rows in the shard's `owned` order).  Blocks
/// are installed atomically under the state lock with the session's
/// single epoch counter — the epoch bump of a delta is exactly-once
/// *across* shards, never per shard.
struct ShardedState {
    graph: ShardedGraph,
    /// per-shard `LogitsCache` slot: `(epoch, owned-row logits block)`
    logits: Vec<Option<(u64, Arc<Matrix<f32>>)>>,
}

/// Everything [`NativeExecutor::apply_delta`] mutates, behind one lock:
/// prepared model state (per-node quantization parameters grow with the
/// graph), the resident graph, its plan, the per-layer activation cache,
/// the frozen NNS assignment tables, and (sharded sessions) the per-shard
/// local views + logits blocks.
struct Resident {
    prepared: PreparedModel,
    node: Option<NodeSide>,
    /// destination-grouped plan of the resident graph (node-level gcn/gin)
    plan: Option<AggregationPlan>,
    caps: (usize, usize, usize),
    /// per-layer activations of the resident graph, tagged with the
    /// logits-cache epoch they belong to (`acts[0]` input features,
    /// `acts[L]` logits) — what incremental deltas patch
    acts: Option<(u64, Vec<Matrix<f32>>)>,
    /// NNS lookup tables over the originally-learned per-node params,
    /// frozen at the first delta (later deltas must not search previously
    /// assigned copies)
    assign_tables: Option<Vec<NnsAssignTables>>,
    /// sharded resident mode ([`NativeExecutor::with_shards`])
    sharded: Option<ShardedState>,
}

/// Scatter a full `[N, C]` logits matrix into per-shard owned-row blocks
/// tagged with `epoch`.  Untouched rows land bit-identically (the block is
/// a row copy), so a delta's unaffected shards keep serving the same bits.
fn refresh_shard_logits(sh: &mut ShardedState, logits: &Matrix<f32>, epoch: u64) {
    debug_assert_eq!(sh.logits.len(), sh.graph.num_shards());
    for (s, local) in sh.graph.shards.iter().enumerate() {
        let mut block = Matrix::zeros(local.owned.len(), logits.cols);
        for (li, &gid) in local.owned.iter().enumerate() {
            block.row_mut(li).copy_from_slice(logits.row(gid as usize));
        }
        sh.logits[s] = Some((epoch, Arc::new(block)));
    }
}

/// Frontier-proportional alternative to [`refresh_shard_logits`] for the
/// delta patch path: rows outside the recomputed `frontier` are
/// bit-identical across the epoch (the partial-invalidation invariant),
/// so only frontier rows are rewritten in place and blocks whose shard
/// gained appended nodes grow at the tail (owned lists grow append-only
/// with maximal ids, so existing row positions are stable; the frontier
/// contains every appended node by construction).  Returns `false` —
/// leaving the blocks untouched — when any block is missing or stale for
/// `old_epoch`, in which case the caller falls back to the full scatter.
fn patch_shard_logits(
    sh: &mut ShardedState,
    logits: &Matrix<f32>,
    old_epoch: u64,
    new_epoch: u64,
    frontier: &[u32],
) -> bool {
    debug_assert_eq!(sh.logits.len(), sh.graph.num_shards());
    let patchable = sh.logits.iter().zip(&sh.graph.shards).all(|(b, local)| {
        matches!(b, Some((e, blk))
            if *e == old_epoch
                && blk.cols == logits.cols
                && blk.rows <= local.owned.len())
    });
    if !patchable {
        return false;
    }
    for (slot, local) in sh.logits.iter_mut().zip(&sh.graph.shards) {
        // a2q-lint: allow(panic-path) the patchable scan above proved
        // every slot is Some at old_epoch
        let (e, blk) = slot.as_mut().expect("checked patchable above");
        if blk.rows < local.owned.len() {
            let old = Arc::make_mut(blk);
            let mut grown = Matrix::zeros(local.owned.len(), logits.cols);
            grown.data[..old.data.len()].copy_from_slice(&old.data);
            for (li, &gid) in local.owned.iter().enumerate().skip(old.rows) {
                grown.row_mut(li).copy_from_slice(logits.row(gid as usize));
            }
            *old = grown;
        }
        *e = new_epoch;
    }
    for &v in frontier {
        let (s, pos) = sh.graph.locate(v);
        // a2q-lint: allow(panic-path) the patchable scan above proved
        // every slot is Some at old_epoch
        let (_, blk) = sh.logits[s].as_mut().expect("checked patchable above");
        Arc::make_mut(blk)
            .row_mut(pos)
            .copy_from_slice(logits.row(v as usize));
    }
    true
}

/// Pure-rust backend over `gnn::infer` (fp emulation by default, true
/// integer path opt-in), holding a prepared session: quantized weights,
/// integer codes, and NNS tables are computed once in [`Self::new`], the
/// resident graph's [`AggregationPlan`] is built once, and full-graph
/// node-level logits are cached per epoch.  Carries its own
/// [`ParallelConfig`] so the serving stack controls the intra-op
/// parallelism budget per executor.  [`Self::apply_delta`] mutates the
/// resident graph in place (reads block only for the duration of the
/// incremental repair).
pub struct NativeExecutor {
    state: RwLock<Resident>,
    parallel: ParallelConfig,
    use_int_path: bool,
    /// set by the first [`Self::apply_delta`]: only dynamic sessions pay
    /// the per-layer activation recording (L+1 matrix clones + a write
    /// lock) on the epoch's first classify batch — static sessions keep
    /// the plain forward
    dynamic: std::sync::atomic::AtomicBool,
    /// versioned full-graph logits (node-level serving hot path)
    logits: LogitsCache<Matrix<f32>>,
}

impl NativeExecutor {
    /// Prepare a serving session from a loaded model.  This is the
    /// model-load validation boundary: malformed static state (missing
    /// layer tensors, non-finite or mismatched quant steps, empty NNS
    /// tables) is rejected here instead of panicking on the first request.
    pub fn new(model: GnnModel, dataset: Option<&Dataset>) -> Result<NativeExecutor> {
        let mut node = None;
        if model.node_level {
            let ds: &NodeData = match dataset {
                Some(Dataset::Node(d)) => d,
                _ => {
                    return Err(Error::coordinator(
                        "node-level executor needs its node dataset",
                    ))
                }
            };
            node = Some(NodeSide {
                csr: ds.csr.clone(),
                features: ds.features.clone(),
                edges: EdgeForm::from_csr(&ds.csr),
                num_nodes: ds.num_nodes(),
            });
        }
        let prepared = PreparedModel::prepare(model)?;
        let model = &prepared.model;
        let caps = (
            model.num_nodes,
            model
                .manifest
                .get("num_edges")
                .and_then(|v| v.as_usize())
                .unwrap_or(model.num_nodes * 8),
            model.graph_capacity.max(1),
        );
        let plan = node.as_ref().and_then(|side: &NodeSide| {
            (model.arch != "gat")
                .then(|| AggregationPlan::build(&side.edges.dst, side.edges.num_nodes))
        });
        Ok(NativeExecutor {
            state: RwLock::new(Resident {
                prepared,
                node,
                plan,
                caps,
                acts: None,
                assign_tables: None,
                sharded: None,
            }),
            parallel: ParallelConfig::from_env(),
            use_int_path: false,
            dynamic: std::sync::atomic::AtomicBool::new(false),
            logits: LogitsCache::new(),
        })
    }

    /// Set the intra-op parallelism budget (builder style).
    pub fn with_parallelism(mut self, cfg: ParallelConfig) -> NativeExecutor {
        self.parallel = cfg;
        self
    }

    /// Route through `forward_int` (true integer arithmetic over packed
    /// codes) instead of the fp emulation.
    pub fn with_int_path(mut self, on: bool) -> NativeExecutor {
        self.use_int_path = on;
        self
    }

    /// Read-lock the resident state — the one audited read acquisition.
    fn resident(&self) -> RwLockReadGuard<'_, Resident> {
        // a2q-lint: allow(panic-path) poisoning requires a prior panic while
        // holding the lock; the resident state is unrecoverable past that
        self.state.read().unwrap()
    }

    /// Write-lock the resident state — the one audited write acquisition.
    fn resident_mut(&self) -> RwLockWriteGuard<'_, Resident> {
        // a2q-lint: allow(panic-path) poisoning requires a prior panic while
        // holding the lock; the resident state is unrecoverable past that
        self.state.write().unwrap()
    }

    /// Switch this session into **sharded resident mode**: the resident
    /// graph is partitioned into `num_shards` shards by the degree-aware
    /// partitioner, full-graph recomputes run shard-parallel
    /// (`forward_{fp,int}_sharded`, bitwise identical to the single-shard
    /// path), node batches are served from per-shard logits blocks, and
    /// [`Self::apply_delta`] rebuilds only the owning shards' local views.
    /// Node-level gcn/gin sessions only.
    pub fn with_shards(self, num_shards: usize) -> Result<NativeExecutor> {
        {
            let mut st = self.resident_mut();
            let model = &st.prepared.model;
            if model.arch == "gat" || model.head.is_some() || !model.node_level {
                return Err(Error::coordinator(
                    "sharded residents need a node-level gcn/gin session",
                ));
            }
            let side = st.node.as_ref().ok_or_else(|| {
                Error::coordinator("sharded residents need a resident node dataset")
            })?;
            let graph = ShardedGraph::build(&side.csr, &side.edges, num_shards)?;
            let s = graph.num_shards();
            st.sharded = Some(ShardedState {
                graph,
                logits: vec![None; s],
            });
        }
        Ok(self)
    }

    /// Shard layout of a sharded session: `(num_shards, halo stats)`.
    pub fn shard_stats(&self) -> Option<(usize, HaloStats)> {
        let st = self.resident();
        st.sharded
            .as_ref()
            .map(|s| (s.graph.num_shards(), s.graph.halo_stats()))
    }

    pub fn parallelism(&self) -> ParallelConfig {
        self.parallel
    }

    /// Resident-size accounting of the prepared session in bytes.
    pub fn prepared_bytes(&self) -> usize {
        self.resident().prepared.prepared_bytes()
    }

    /// Current resident node count (grows with applied deltas).
    pub fn resident_nodes(&self) -> usize {
        let st = self.resident();
        st.node
            .as_ref()
            .map(|s| s.num_nodes)
            .unwrap_or(st.caps.0)
    }

    /// Clone of the resident graph's aggregation plan (tests/diagnostics).
    pub fn resident_plan(&self) -> Option<AggregationPlan> {
        self.resident().plan.clone()
    }

    /// Per-layer clones of the resident feature-quantization parameters
    /// (`(feat, feat2)` per layer) — after deltas these include the
    /// NNS-assigned entries for appended nodes, which is exactly what a
    /// from-scratch rebuild needs to reproduce the served logits
    /// (`rust/tests/delta_parity.rs`).
    pub fn resident_quant_params(
        &self,
    ) -> Vec<(Option<NodeQuantParams>, Option<NodeQuantParams>)> {
        let st = self.resident();
        st.prepared
            .model
            .layers
            .iter()
            .map(|l| (l.feat.clone(), l.feat2.clone()))
            .collect()
    }

    /// Invalidate the full-graph logits cache.  Call after a weight or
    /// resident-feature swap; the next node batch recomputes under the new
    /// epoch while in-flight batches keep serving the old one.
    pub fn bump_epoch(&self) {
        self.logits.bump();
    }

    /// Current logits-cache epoch (diagnostics).
    pub fn epoch(&self) -> u64 {
        self.logits.epoch()
    }

    /// Serve node rows of a sharded session from the per-shard logits
    /// blocks, recomputing with one shard-parallel forward when the
    /// blocks are stale for the current epoch.  The recompute runs outside
    /// the write lock and installs epoch-checked, mirroring
    /// [`LogitsCache::get_or_compute`]: a concurrent delta keeps a stale
    /// result out of the blocks while this call still serves what it
    /// computed.
    fn sharded_node_rows(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        let epoch = self.logits.epoch();
        {
            let st = self.resident();
            // a2q-lint: allow(panic-path) routed here only when the caller
            // saw sharded state installed, and with_shards never unsets it
            let sh = st.sharded.as_ref().expect("sharded session");
            if sh
                .logits
                .iter()
                .all(|b| matches!(b, Some((e, _)) if *e == epoch))
            {
                return node_ids
                    .iter()
                    .map(|&v| {
                        if v as usize >= sh.graph.num_nodes {
                            return Err(Error::coordinator(format!(
                                "node {v} out of range"
                            )));
                        }
                        let (s, pos) = sh.graph.locate(v);
                        // a2q-lint: allow(panic-path) the freshness scan
                        // above proved every slot holds this epoch's block
                        let block = sh.logits[s].as_ref().expect("checked fresh above");
                        Ok(block.1.row(pos).to_vec())
                    })
                    .collect();
            }
        }
        let record = self.dynamic.load(Ordering::Acquire);
        let (out, acts) = {
            let st = self.resident();
            let side = st
                .node
                .as_ref()
                .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
            // a2q-lint: allow(panic-path) routed here only when the caller
            // saw sharded state installed, and with_shards never unsets it
            let shg = &st.sharded.as_ref().expect("sharded session").graph;
            let mut acts = Vec::new();
            let out = match (self.use_int_path, record) {
                (true, true) => forward_int_sharded_recording(
                    &st.prepared,
                    &side.features,
                    shg,
                    &self.parallel,
                    &mut acts,
                ),
                (false, true) => forward_fp_sharded_recording(
                    &st.prepared,
                    &side.features,
                    shg,
                    &self.parallel,
                    &mut acts,
                ),
                (true, false) => {
                    forward_int_sharded(&st.prepared, &side.features, shg, &self.parallel)
                }
                (false, false) => {
                    forward_fp_sharded(&st.prepared, &side.features, shg, &self.parallel)
                }
            };
            (out, record.then_some(acts))
        };
        {
            let mut st = self.resident_mut();
            if self.logits.epoch() == epoch {
                if let Some(acts) = acts {
                    st.acts = Some((epoch, acts));
                }
                // a2q-lint: allow(panic-path) routed here only when the
                // caller saw sharded state, and with_shards never unsets it
                let sh = st.sharded.as_mut().expect("sharded session");
                refresh_shard_logits(sh, &out, epoch);
            }
        }
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if v >= out.rows {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(out.row(v).to_vec())
            })
            .collect()
    }

    fn full_graph_logits(&self) -> Result<Arc<Matrix<f32>>> {
        // Static sessions (no delta ever applied) take the plain forward;
        // once the session turns dynamic, epoch recomputes also record the
        // per-layer activations so the next delta patches instead of
        // recomputing.  A cold first delta warms its own cache either way.
        let record = self.dynamic.load(Ordering::Acquire);
        self.logits.get_or_compute(|epoch| {
            let st = self.resident();
            let side = st
                .node
                .as_ref()
                .ok_or_else(|| Error::coordinator("not a node-level executor"))?;
            let input =
                GraphInput::node_level(&side.features, st.prepared.model.in_dim, &side.edges);
            let mut acts = Vec::new();
            let out = match (self.use_int_path, record) {
                (true, true) => forward_int_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut acts,
                ),
                (false, true) => forward_fp_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut acts,
                ),
                (true, false) => forward_int_prepared_with_plan(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                ),
                (false, false) => forward_fp_prepared_with_plan(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                ),
            };
            drop(st);
            if record {
                // stash the per-layer activations so a later delta patches
                // instead of recomputing; skip if an update raced us
                let mut st = self.resident_mut();
                if self.logits.epoch() == epoch {
                    st.acts = Some((epoch, acts));
                }
            }
            Ok(out)
        })
    }

    /// Apply a [`GraphDelta`] to the resident graph (node-level gcn/gin
    /// sessions).  The epoch bumps exactly once; only the delta's L-hop
    /// reverse frontier of logits rows is recomputed, and the patched
    /// logits are installed for the new epoch so the next classify batch
    /// is a slice-copy.  Appended nodes receive `(step, bits)` via the
    /// paper's NNS against the learned per-node parameters.  All repairs
    /// are staged and committed atomically — a rejected delta (shape
    /// mismatch, non-finite features/activations) leaves the resident
    /// state untouched.
    pub fn apply_delta(&self, delta: &GraphDelta) -> Result<DeltaReport> {
        let mut guard = self.resident_mut();
        let st = &mut *guard;
        if st.prepared.model.arch == "gat" {
            return Err(Error::coordinator(
                "resident-graph updates are not supported for gat sessions",
            ));
        }
        if st.prepared.model.head.is_some() {
            // graph-level readout models have no resident graph to mutate,
            // and their logits are a pooled head output, not acts.last()
            return Err(Error::coordinator(
                "resident-graph updates need a node-level session",
            ));
        }
        let side = st.node.as_mut().ok_or_else(|| {
            Error::coordinator("resident-graph updates need a node-level session")
        })?;
        let in_dim = st.prepared.model.in_dim;
        let n_layers = st.prepared.model.layers.len();
        let int_path = st.prepared.int_path_semantics(self.use_int_path);
        delta.validate(side.num_nodes, in_dim)?;
        // this session is dynamic from here on: epoch recomputes keep the
        // per-layer activation cache warm for future deltas
        self.dynamic.store(true, Ordering::Release);

        // Empty delta: nothing to repair — honour the one-bump-per-delta
        // contract and carry the current state forward untouched.
        if delta.is_empty() {
            let epoch = self.logits.epoch();
            self.logits.bump();
            let new_epoch = self.logits.epoch();
            if let Some((e, acts)) = st.acts.as_mut() {
                if *e == epoch {
                    *e = new_epoch;
                    // a2q-lint: allow(panic-path) recording forwards always
                    // return the input plus one matrix per layer
                    let logits_mat = acts.last().expect("at least the input features");
                    self.logits.set(new_epoch, Arc::new(logits_mat.clone()));
                }
            }
            // sharded blocks carry over bit-for-bit under the new epoch
            let halo_nodes = match st.sharded.as_mut() {
                Some(sh) => {
                    for slot in sh.logits.iter_mut() {
                        if let Some((e, _)) = slot {
                            if *e == epoch {
                                *e = new_epoch;
                            }
                        }
                    }
                    sh.graph.halo_stats().halo_nodes
                }
                None => 0,
            };
            return Ok(DeltaReport {
                epoch: new_epoch,
                num_nodes: side.num_nodes,
                recomputed_rows: 0,
                new_nodes: 0,
                shards_touched: 0,
                halo_nodes,
            });
        }

        // 1. incremental structural repair (all staged)
        let applied = delta.apply_to_csr(&side.csr)?;
        let new_edges = side.edges.apply_delta(&side.csr, &applied);
        let new_plan = AggregationPlan::for_csr_edge_form(&applied.csr);
        let n_new = applied.csr.num_nodes();
        let mut new_features = side.features.clone();
        new_features.extend_from_slice(&delta.new_features);
        let dirty = dirty_frontier(&applied.csr, &applied, n_layers);
        let frontier_rows = dirty.last().map(|d| d.len()).unwrap_or(0);

        // Near-full frontier without appended nodes: the serial row patch
        // would touch most of the graph, so the row-parallel recording
        // forward over the post-delta structure is cheaper and produces the
        // identical (bitwise) result.  With appended nodes the patch is
        // required — NNS assignment interleaves with layer computation.
        if delta.add_nodes == 0 && frontier_rows.saturating_mul(2) > n_new {
            let input = GraphInput::node_level(&new_features, in_dim, &new_edges);
            let mut rec = Vec::new();
            if self.use_int_path {
                forward_int_prepared_recording(
                    &st.prepared,
                    &input,
                    Some(&new_plan),
                    &self.parallel,
                    &mut rec,
                );
            } else {
                forward_fp_prepared_recording(
                    &st.prepared,
                    &input,
                    Some(&new_plan),
                    &self.parallel,
                    &mut rec,
                );
            }
            // sharded resident: rebuild only the affected shards' local
            // views against the post-delta structure (before it moves)
            let (shards_touched, halo_nodes) = match st.sharded.as_mut() {
                Some(sh) => {
                    let touched = sh
                        .graph
                        .apply_delta(
                            &applied.csr,
                            &new_edges,
                            0,
                            &applied.row_changed,
                            &applied.deg_changed,
                        )
                        .len();
                    (touched, sh.graph.halo_stats().halo_nodes)
                }
                None => (0, 0),
            };
            side.csr = applied.csr;
            side.features = new_features;
            side.edges = new_edges;
            side.num_nodes = n_new;
            st.plan = Some(new_plan);
            self.logits.bump();
            let new_epoch = self.logits.epoch();
            // a2q-lint: allow(panic-path) recording forwards always return
            // the input plus one matrix per layer
            let logits_mat = rec.last().expect("at least the input features").clone();
            st.acts = Some((new_epoch, rec));
            if let Some(sh) = st.sharded.as_mut() {
                refresh_shard_logits(sh, &logits_mat, new_epoch);
            }
            self.logits.set(new_epoch, Arc::new(logits_mat));
            return Ok(DeltaReport {
                epoch: new_epoch,
                num_nodes: n_new,
                recomputed_rows: frontier_rows,
                new_nodes: 0,
                shards_touched,
                halo_nodes,
            });
        }

        // 2. make sure the per-layer activation cache matches this epoch
        //    (cold sessions pay one full forward on the pre-delta graph —
        //    the same warm-up the first classify batch would have done)
        let epoch = self.logits.epoch();
        if st.acts.as_ref().map(|(e, _)| *e) != Some(epoch) {
            let input = GraphInput::node_level(&side.features, in_dim, &side.edges);
            let mut rec = Vec::new();
            if self.use_int_path {
                forward_int_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut rec,
                );
            } else {
                forward_fp_prepared_recording(
                    &st.prepared,
                    &input,
                    st.plan.as_ref(),
                    &self.parallel,
                    &mut rec,
                );
            }
            st.acts = Some((epoch, rec));
        }

        // 3. freeze the NNS assignment tables over the learned params
        if st.assign_tables.is_none() {
            st.assign_tables = Some(build_assign_tables(&st.prepared)?);
        }

        // 4. staged activations (pre-delta rows carried over, appended
        //    rows zeroed until patched)
        // a2q-lint: allow(panic-path) step 2 just warmed the activation
        // cache for exactly this epoch
        let (_, old_acts) = st.acts.as_ref().expect("warmed above");
        let mut acts: Vec<Matrix<f32>> = Vec::with_capacity(n_layers + 1);
        acts.push(Matrix::from_vec(n_new, in_dim, new_features.clone())?);
        for m in &old_acts[1..] {
            let mut grown = Matrix::zeros(n_new, m.cols);
            grown.data[..m.data.len()].copy_from_slice(&m.data);
            acts.push(grown);
        }

        // 5. staged per-node quant params (cloned; appended entries are
        //    NNS-assigned inside the patch as their rows materialize)
        // a2q-lint: allow(panic-path) step 3 just froze the assignment
        // tables for this session
        let tables = st.assign_tables.as_ref().expect("frozen above");
        let mut staged: Vec<(Option<NodeQuantParams>, Option<NodeQuantParams>)> = st
            .prepared
            .model
            .layers
            .iter()
            .zip(tables.iter())
            .map(|(lay, t)| {
                (
                    t.feat.as_ref().and(lay.feat.clone()),
                    t.feat2.as_ref().and(lay.feat2.clone()),
                )
            })
            .collect();

        // 6. row repair over the frontier (bitwise == full recompute)
        let recomputed = patch_activations(
            &st.prepared,
            &mut staged,
            tables,
            &new_edges,
            &new_plan,
            &mut acts,
            &dirty,
            int_path,
            self.parallel.simd,
        )?;

        // 7. commit + single epoch bump.  Sharded residents first repair
        //    their partition (appended nodes go to the least-loaded
        //    shards) and rebuild only the affected shards' local views.
        let (shards_touched, halo_nodes) = match st.sharded.as_mut() {
            Some(sh) => {
                let touched = sh
                    .graph
                    .apply_delta(
                        &applied.csr,
                        &new_edges,
                        delta.add_nodes,
                        &applied.row_changed,
                        &applied.deg_changed,
                    )
                    .len();
                (touched, sh.graph.halo_stats().halo_nodes)
            }
            None => (0, 0),
        };
        side.csr = applied.csr;
        side.features = new_features;
        side.edges = new_edges;
        side.num_nodes = n_new;
        st.plan = Some(new_plan);
        for (lay, (f, f2)) in st.prepared.model.layers.iter_mut().zip(staged) {
            if let Some(p) = f {
                lay.feat = Some(p);
            }
            if let Some(p) = f2 {
                lay.feat2 = Some(p);
            }
        }
        st.prepared.model.num_nodes = n_new;
        st.caps.0 = n_new;
        self.logits.bump();
        let new_epoch = self.logits.epoch();
        // a2q-lint: allow(panic-path) acts was built above as the input
        // plus one matrix per layer
        let logits_mat = acts.last().expect("at least input + one layer").clone();
        st.acts = Some((new_epoch, acts));
        if let Some(sh) = st.sharded.as_mut() {
            let frontier: &[u32] = dirty.last().map(|d| d.as_slice()).unwrap_or(&[]);
            if !patch_shard_logits(sh, &logits_mat, epoch, new_epoch, frontier) {
                refresh_shard_logits(sh, &logits_mat, new_epoch);
            }
        }
        self.logits.set(new_epoch, Arc::new(logits_mat));
        Ok(DeltaReport {
            epoch: new_epoch,
            num_nodes: n_new,
            recomputed_rows: recomputed,
            new_nodes: delta.add_nodes,
            shards_touched,
            halo_nodes,
        })
    }
}

impl BatchExecutor for NativeExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        // sharded sessions serve from per-shard logits blocks, recomputing
        // with the shard-parallel forward when the epoch moved
        if self.resident().sharded.is_some() {
            return self.sharded_node_rows(node_ids);
        }
        // full forward once per epoch; every batch after that is a
        // row slice-copy off the cached logits
        let logits = self.full_graph_logits()?;
        node_ids
            .iter()
            .map(|&v| {
                let v = v as usize;
                if v >= logits.rows {
                    return Err(Error::coordinator(format!("node {v} out of range")));
                }
                Ok(logits.row(v).to_vec())
            })
            .collect()
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        let st = self.resident();
        let (cap_n, cap_e, cap_g) = st.caps;
        let batch = GraphBatch::pack(graphs, st.prepared.model.in_dim, cap_n, cap_e, cap_g)?;
        let input = GraphInput::batch(&batch);
        // client-supplied edges differ per batch, so no resident plan here
        let out = if self.use_int_path {
            forward_int_prepared_with_plan(&st.prepared, &input, None, &self.parallel)
        } else {
            forward_fp_prepared_with_plan(&st.prepared, &input, None, &self.parallel)
        };
        Ok((0..graphs.len()).map(|g| out.row(g).to_vec()).collect())
    }

    fn apply_delta(&self, delta: &GraphDelta) -> Result<DeltaReport> {
        NativeExecutor::apply_delta(self, delta)
    }

    fn capacity(&self) -> (usize, usize) {
        let st = self.resident();
        if st.prepared.model.node_level {
            (
                st.node.as_ref().map(|s| s.num_nodes).unwrap_or(st.caps.0),
                0,
            )
        } else {
            (st.caps.0, st.caps.2)
        }
    }

    fn out_dim(&self) -> usize {
        self.resident().prepared.model.out_dim
    }
}

// ---------------------------------------------------------------------------
// Mock
// ---------------------------------------------------------------------------

/// Deterministic test double: returns node id / node count as "logits",
/// optionally sleeping to emulate execution latency.
pub struct MockExecutor {
    pub out_dim: usize,
    pub latency: std::time::Duration,
}

impl Default for MockExecutor {
    fn default() -> Self {
        MockExecutor {
            out_dim: 2,
            latency: std::time::Duration::ZERO,
        }
    }
}

impl BatchExecutor for MockExecutor {
    fn run_node_batch(&self, node_ids: &[u32]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(node_ids
            .iter()
            .map(|&v| {
                let mut out = vec![0.0; self.out_dim];
                out[v as usize % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn run_graph_batch(&self, graphs: &[&SmallGraph]) -> Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.latency);
        Ok(graphs
            .iter()
            .map(|g| {
                let mut out = vec![0.0; self.out_dim];
                out[g.num_nodes() % self.out_dim] = 1.0;
                out
            })
            .collect())
    }

    fn capacity(&self) -> (usize, usize) {
        (1024, 16)
    }

    fn out_dim(&self) -> usize {
        self.out_dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::{forward_fp_with, LayerParams, QuantMethod};
    use crate::quant::mixed::NodeQuantParams;
    use crate::util::json::Json;

    #[test]
    fn mock_is_deterministic() {
        let m = MockExecutor::default();
        let out = m.run_node_batch(&[0, 1, 2]).unwrap();
        assert_eq!(out[0], vec![1.0, 0.0]);
        assert_eq!(out[1], vec![0.0, 1.0]);
        assert_eq!(out[2], vec![1.0, 0.0]);
    }

    #[test]
    fn mock_rejects_deltas() {
        let err = BatchExecutor::apply_delta(
            &MockExecutor::default(),
            &GraphDelta::default(),
        )
        .unwrap_err();
        assert!(format!("{err}").contains("does not support"));
    }

    fn tiny_session() -> (GnnModel, Dataset) {
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.5, 1.0]).unwrap();
        let model = GnnModel {
            name: "tiny".into(),
            arch: "gcn".into(),
            dataset: "unit".into(),
            method: QuantMethod::A2q,
            layers: vec![LayerParams {
                w: Some(w),
                b: vec![0.1, -0.1],
                w_steps: vec![0.05, 0.05],
                feat: Some(NodeQuantParams::new(vec![0.1; 3], vec![4; 3], true).unwrap()),
                ..Default::default()
            }],
            head: None,
            dq_steps: vec![],
            skip_input_quant: false,
            node_level: true,
            num_nodes: 3,
            in_dim: 2,
            out_dim: 2,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        };
        let csr = Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap();
        let ds = Dataset::Node(NodeData {
            name: "unit".into(),
            csr,
            num_features: 2,
            num_classes: 2,
            features: vec![0.3, -0.2, 0.15, 0.4, -0.35, 0.05],
            labels: vec![0, 1, 0],
            train_mask: vec![false; 3],
            val_mask: vec![false; 3],
            test_mask: vec![false; 3],
        });
        (model, ds)
    }

    /// 6-node path graph session (1-layer GCN) — long enough that a delta
    /// at one end leaves a genuinely untouched far end.
    fn path_session() -> (GnnModel, Dataset) {
        let n = 6;
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.5, 1.0]).unwrap();
        let model = GnnModel {
            name: "path".into(),
            arch: "gcn".into(),
            dataset: "unit".into(),
            method: QuantMethod::A2q,
            layers: vec![LayerParams {
                w: Some(w),
                b: vec![0.1, -0.1],
                w_steps: vec![0.05, 0.05],
                feat: Some(NodeQuantParams::new(vec![0.1; 6], vec![4; 6], true).unwrap()),
                ..Default::default()
            }],
            head: None,
            dq_steps: vec![],
            skip_input_quant: false,
            node_level: true,
            num_nodes: n,
            in_dim: 2,
            out_dim: 2,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        };
        let mut edges = Vec::new();
        for i in 0..n as u32 - 1 {
            edges.push((i, i + 1));
            edges.push((i + 1, i));
        }
        let csr = Csr::from_edges(n, &edges).unwrap();
        let features: Vec<f32> = (0..n * 2).map(|i| 0.05 * (i as f32 + 1.0) - 0.3).collect();
        let ds = Dataset::Node(NodeData {
            name: "unit".into(),
            csr,
            num_features: 2,
            num_classes: 2,
            features,
            labels: vec![0; n],
            train_mask: vec![false; n],
            val_mask: vec![false; n],
            test_mask: vec![false; n],
        });
        (model, ds)
    }

    #[test]
    fn native_cached_batches_match_unprepared_forward() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let Dataset::Node(nd) = &ds else { unreachable!() };
        let ef = EdgeForm::from_csr(&nd.csr);
        let input = GraphInput::node_level(&nd.features, 2, &ef);
        let want = forward_fp_with(&model, &input, &ParallelConfig::serial());

        // first batch computes + caches, second serves from the cache —
        // both bitwise identical to the per-call shim
        for _ in 0..2 {
            let out = exec.run_node_batch(&[0, 1, 2]).unwrap();
            for (v, row) in out.iter().enumerate() {
                assert_eq!(row.as_slice(), want.row(v));
            }
        }
        assert_eq!(exec.epoch(), 0);
    }

    #[test]
    fn native_epoch_bump_invalidates_but_stays_consistent() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let before = exec.run_node_batch(&[0, 2]).unwrap();
        exec.bump_epoch();
        assert_eq!(exec.epoch(), 1);
        // immutable state ⇒ recompute under the new epoch is identical
        let after = exec.run_node_batch(&[0, 2]).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn native_out_of_range_node_is_an_error_not_a_panic() {
        let (model, ds) = tiny_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let err = exec.run_node_batch(&[99]).unwrap_err();
        assert!(format!("{err}").contains("out of range"));
    }

    #[test]
    fn native_rejects_malformed_model_at_construction() {
        let (mut model, ds) = tiny_session();
        model.layers[0].w = None;
        let err = NativeExecutor::new(model, Some(&ds)).unwrap_err();
        assert!(format!("{err}").contains("missing w"));
    }

    #[test]
    fn delta_recomputes_frontier_and_preserves_untouched_rows_bitwise() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let all: Vec<u32> = (0..6).collect();
        let before = exec.run_node_batch(&all).unwrap();
        assert_eq!(exec.epoch(), 0);

        // add a directed edge 5→0: node 0's row + degree change; the
        // 1-layer frontier is {0} ∪ out-neighbours of {0} = {0, 1}
        let report = exec
            .apply_delta(&GraphDelta {
                add_edges: vec![(5, 0)],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(report.epoch, 1);
        assert_eq!(exec.epoch(), 1, "epoch bumps exactly once per delta");
        assert_eq!(report.recomputed_rows, 2, "only the frontier recomputes");
        assert_eq!(report.num_nodes, 6);

        let after = exec.run_node_batch(&all).unwrap();
        // untouched rows survive the epoch change bit-for-bit
        for v in 2..6 {
            assert_eq!(before[v], after[v], "row {v} should be untouched");
        }
        // the mutated destination genuinely moved
        assert_ne!(before[0], after[0], "row 0 must reflect the new edge");

        // a second (empty) delta still bumps exactly once and touches no rows
        let report = exec.apply_delta(&GraphDelta::default()).unwrap();
        assert_eq!(report.epoch, 2);
        assert_eq!(report.recomputed_rows, 0);
        let again = exec.run_node_batch(&all).unwrap();
        assert_eq!(after, again);

        // a manual epoch bump on a now-dynamic session recomputes AND
        // re-records the activation cache on the next batch; a further
        // delta then patches off that recorded recompute
        exec.bump_epoch();
        assert_eq!(exec.epoch(), 3);
        let recomputed = exec.run_node_batch(&all).unwrap();
        assert_eq!(after, recomputed, "recompute must reproduce the patched state");
        let report = exec
            .apply_delta(&GraphDelta {
                add_edges: vec![(0, 5)],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(report.epoch, 4);
        let last = exec.run_node_batch(&all).unwrap();
        // frontier of (0,5): {5} ∪ out-neighbours of deg-changed {5} =
        // {0, 4, 5} (0 gained 5 as in-neighbour in the first delta); the
        // middle of the path stays bit-identical
        for v in 1..4 {
            assert_eq!(recomputed[v], last[v], "row {v} should be untouched");
        }
        assert_ne!(recomputed[5], last[5], "row 5 must reflect the new edge");
    }

    #[test]
    fn delta_appends_node_with_nns_assigned_params() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        // node 6 arrives with features and links to node 0
        let report = exec
            .apply_delta(&GraphDelta {
                add_nodes: 1,
                new_features: vec![0.2, -0.1],
                add_edges: vec![(6, 0), (0, 6)],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(report.num_nodes, 7);
        assert_eq!(report.new_nodes, 1);
        assert_eq!(exec.resident_nodes(), 7);
        assert_eq!(exec.capacity().0, 7);
        // the unseen node serves logits like any resident node
        let out = exec.run_node_batch(&[6]).unwrap();
        assert_eq!(out[0].len(), 2);
        assert!(out[0].iter().all(|v| v.is_finite()));
        // and its quantization params were assigned from the learned table
        let params = exec.resident_quant_params();
        let feat = params[0].0.as_ref().unwrap();
        assert_eq!(feat.len(), 7);
        assert!(feat.steps[6].is_finite() && feat.steps[6] > 0.0);
        assert!(feat.bits[6] >= 1);
    }

    #[test]
    fn sharded_session_serves_and_patches_like_unsharded() {
        let (model, ds) = path_session();
        let plain = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let sharded = NativeExecutor::new(model, Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial())
            .with_shards(3)
            .unwrap();
        let all: Vec<u32> = (0..6).collect();
        // per-shard block serving == single-shard cache serving, bitwise
        assert_eq!(
            plain.run_node_batch(&all).unwrap(),
            sharded.run_node_batch(&all).unwrap()
        );
        let (s, _stats) = sharded.shard_stats().unwrap();
        assert_eq!(s, 3);
        assert!(plain.shard_stats().is_none());

        // a delta patches both sessions to the same bits; shard accounting
        // only reports on the sharded one, and the epoch bump is
        // exactly-once across shards
        let delta = GraphDelta {
            add_nodes: 1,
            new_features: vec![0.2, -0.1],
            add_edges: vec![(6, 0), (0, 6)],
            ..Default::default()
        };
        let rp = plain.apply_delta(&delta).unwrap();
        let rs = sharded.apply_delta(&delta).unwrap();
        assert_eq!(rp.epoch, rs.epoch);
        assert_eq!(rs.num_nodes, 7);
        assert_eq!(rp.shards_touched, 0);
        assert!(rs.shards_touched >= 1, "the owning shard must rebuild");
        assert_eq!(sharded.epoch(), 1, "one bump per delta across shards");
        let all7: Vec<u32> = (0..7).collect();
        let want = plain.run_node_batch(&all7).unwrap();
        let got = sharded.run_node_batch(&all7).unwrap();
        assert_eq!(want, got, "post-delta sharded rows diverged");

        // empty delta: blocks retag under the new epoch, rows bit-identical
        let re = sharded.apply_delta(&GraphDelta::default()).unwrap();
        assert_eq!(re.shards_touched, 0);
        assert_eq!(sharded.epoch(), 2);
        assert_eq!(got, sharded.run_node_batch(&all7).unwrap());

        // manual epoch bump: the shard-parallel recompute reproduces the
        // patched state bit-for-bit
        sharded.bump_epoch();
        assert_eq!(got, sharded.run_node_batch(&all7).unwrap());
    }

    #[test]
    fn with_shards_rejects_non_node_level_sessions() {
        let (mut model, _ds) = tiny_session();
        model.node_level = false;
        model.num_nodes = 0;
        let exec = NativeExecutor::new(model, None).unwrap();
        let err = exec.with_shards(2).unwrap_err();
        assert!(format!("{err}").contains("node-level"), "got: {err}");
    }

    #[test]
    fn delta_rejects_malformed_input_without_mutating() {
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model, Some(&ds)).unwrap();
        let all: Vec<u32> = (0..6).collect();
        let before = exec.run_node_batch(&all).unwrap();
        // wrong feature arity
        assert!(exec
            .apply_delta(&GraphDelta {
                add_nodes: 1,
                new_features: vec![0.0; 3],
                ..Default::default()
            })
            .is_err());
        // non-finite features
        assert!(exec
            .apply_delta(&GraphDelta {
                add_nodes: 1,
                new_features: vec![0.0, f32::NAN],
                ..Default::default()
            })
            .is_err());
        // out-of-range edge
        assert!(exec
            .apply_delta(&GraphDelta {
                add_edges: vec![(0, 42)],
                ..Default::default()
            })
            .is_err());
        // nothing changed: same epoch, same logits
        assert_eq!(exec.epoch(), 0);
        assert_eq!(exec.run_node_batch(&all).unwrap(), before);
    }

    #[test]
    fn cold_session_delta_then_first_batch_is_consistent() {
        // apply a delta before any classify batch: the executor warms its
        // own activation cache, and the first served batch must equal a
        // freshly-built session over the post-delta graph
        let (model, ds) = path_session();
        let exec = NativeExecutor::new(model.clone(), Some(&ds))
            .unwrap()
            .with_parallelism(ParallelConfig::serial());
        let delta = GraphDelta {
            add_edges: vec![(5, 0), (0, 5)],
            ..Default::default()
        };
        exec.apply_delta(&delta).unwrap();
        let got = exec.run_node_batch(&(0..6).collect::<Vec<u32>>()).unwrap();

        let Dataset::Node(nd) = &ds else { unreachable!() };
        let mut edges = nd.csr.edge_list();
        edges.push((5, 0));
        edges.push((0, 5));
        let csr = Csr::from_edges(6, &edges).unwrap();
        let ef = EdgeForm::from_csr(&csr);
        let input = GraphInput::node_level(&nd.features, 2, &ef);
        let want = forward_fp_with(&model, &input, &ParallelConfig::serial());
        for (v, row) in got.iter().enumerate() {
            assert_eq!(row.as_slice(), want.row(v), "row {v}");
        }
    }
}
