//! Serving coordinator (L3): router → dynamic batcher → worker pipeline.
//!
//! The deployable inference service in front of the AOT artifacts:
//!
//! * [`request`] — typed requests/responses (node classification over the
//!   resident graph; graph-level prediction for client-supplied graphs;
//!   resident-graph mutation via `Payload::UpdateGraph`).
//! * [`batcher`] — dynamic batching: graph-level requests accumulate until
//!   a node-count budget fills or a deadline expires (static-shape batches
//!   for the PJRT executable); node-level queries coalesce onto one
//!   full-graph forward; graph updates are ordering barriers that execute
//!   alone so inference and mutation interleave without stale reads.
//! * [`router`] — dispatches to per-model runners, bounded queues give
//!   admission-control backpressure.
//! * [`executor`] — pluggable execution backends: PJRT artifact, native
//!   integer path, or mock (tests).
//! * [`metrics`] — latency histograms + throughput counters.
//! * [`server`] — the `Coordinator` facade tying it together.
//! * [`supervise`] — self-healing: supervised runner respawn with backoff
//!   and a restart budget, plus per-model circuit breakers that reject
//!   fast (on-protocol, with `retry_after_ms`) while an executor is
//!   failing every batch.
//! * [`net`] — the TCP front end: versioned length-prefixed wire protocol
//!   over `Coordinator::submit`, per-client token-bucket rate limiting,
//!   explicit on-protocol rejections, p99-driven adaptive batching, and
//!   graceful drain.

pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod net;
pub mod request;
pub mod router;
pub mod server;
pub mod supervise;

pub use batcher::{AdaptiveWait, BatcherConfig, DynamicBatcher};
pub use executor::{
    synthetic_node_session, BatchExecutor, DeltaReport, MockExecutor, NativeExecutor,
    PjrtExecutor, RestoreReport, SwapReport,
};
pub use metrics::Metrics;
pub use net::{DrainReport, NetClient, NetConfig, NetServer};
pub use request::{Payload, Prediction, Request, Response};
pub use router::{RejectReason, Rejected};
pub use server::{Coordinator, CoordinatorConfig};
pub use supervise::{CircuitBreaker, SuperviseConfig};
