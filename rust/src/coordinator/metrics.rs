//! Serving metrics: latency histograms + throughput counters.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

/// Sliding-window throughput gauge: `RATE_BUCKETS` ring buckets of
/// `RATE_BUCKET_MS` each (a 10 s window).  The old gauge divided lifetime
/// responses by wall time since the *first* admission, so a polled
/// `/metrics` endpoint watched the number decay toward zero while the
/// server idled — and it could never recover to the true current rate.
/// This one reports responses inside the window only: steady traffic reads
/// its steady rate regardless of uptime, and an idle server reads 0.
const RATE_BUCKET_MS: u64 = 500;
const RATE_BUCKETS: usize = 20;

#[derive(Debug)]
struct RateWindow {
    origin: Instant,
    counts: [u64; RATE_BUCKETS],
    /// absolute bucket index of the newest bucket accounted for
    cursor: u64,
}

impl Default for RateWindow {
    fn default() -> Self {
        RateWindow::new(Instant::now())
    }
}

impl RateWindow {
    fn new(origin: Instant) -> RateWindow {
        RateWindow {
            origin,
            counts: [0; RATE_BUCKETS],
            cursor: 0,
        }
    }

    fn bucket_of(&self, now: Instant) -> u64 {
        (now.saturating_duration_since(self.origin).as_millis() as u64) / RATE_BUCKET_MS
    }

    /// Move the cursor to `now`'s bucket, zeroing every bucket the window
    /// slid past (bounded by the ring size, so a long idle gap is O(ring)).
    fn advance(&mut self, now: Instant) {
        let b = self.bucket_of(now);
        if b <= self.cursor {
            return;
        }
        let steps = (b - self.cursor).min(RATE_BUCKETS as u64);
        for i in 1..=steps {
            self.counts[((self.cursor + i) % RATE_BUCKETS as u64) as usize] = 0;
        }
        self.cursor = b;
    }

    fn record(&mut self, now: Instant) {
        self.advance(now);
        self.counts[(self.cursor % RATE_BUCKETS as u64) as usize] += 1;
    }

    /// Events inside the live window divided by the span the window
    /// actually covers (shorter than the full ring right after start-up).
    fn rate(&mut self, now: Instant) -> f64 {
        self.advance(now);
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let oldest_live = self.cursor.saturating_sub(RATE_BUCKETS as u64 - 1);
        let now_ms = now.saturating_duration_since(self.origin).as_millis() as u64;
        let span_ms = now_ms.saturating_sub(oldest_live * RATE_BUCKET_MS).max(1);
        total as f64 / (span_ms as f64 / 1e3)
    }
}

#[derive(Debug, Default)]
struct Inner {
    latency: LatencyHistogram,
    queue_wait: LatencyHistogram,
    exec: LatencyHistogram,
    rate: RateWindow,
    requests: u64,
    responses: u64,
    rejected: u64,
    errors: u64,
    batches: u64,
    batched_requests: u64,
    updates: u64,
    /// cumulative shard local-view rebuilds across applied deltas
    /// (sharded residents; 0 for unsharded sessions)
    shard_rebuilds: u64,
    /// last observed Σ halo mirror nodes of the sharded resident (gauge)
    halo_nodes: u64,
    /// supervised runner respawns after a panic escaped the batch boundary
    runner_restarts: u64,
    /// circuit-breaker closed/half-open → open transitions
    breaker_opens: u64,
    /// submissions rejected fast because a model's breaker was open
    breaker_rejected: u64,
    /// per-model breaker state ("closed" / "open" / "half_open"); BTreeMap
    /// so snapshots list models in a stable order
    breaker_states: BTreeMap<String, &'static str>,
}

/// Thread-safe metrics sink shared across the pipeline.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub rejected: u64,
    pub errors: u64,
    pub batches: u64,
    /// successfully applied resident-graph updates
    pub updates: u64,
    /// cumulative shard local-view rebuilds (sharded residents)
    pub shard_rebuilds: u64,
    /// last observed Σ halo mirror nodes of the sharded resident (gauge)
    pub halo_nodes: u64,
    /// supervised runner respawns (panic escaped the batch boundary)
    pub runner_restarts: u64,
    /// circuit-breaker open transitions
    pub breaker_opens: u64,
    /// submissions rejected fast by an open circuit breaker
    pub breaker_rejected: u64,
    /// per-model breaker state, sorted by model name
    pub breaker_states: Vec<(String, String)>,
    pub mean_batch_size: f64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub mean_queue_us: f64,
    pub p50_queue_us: f64,
    pub p99_queue_us: f64,
    pub mean_exec_us: f64,
    pub p50_exec_us: f64,
    pub p99_exec_us: f64,
    /// responses per second over the sliding window (~10 s), not lifetime:
    /// reads 0 when idle and the current rate under steady traffic
    pub throughput_rps: f64,
}

impl Metrics {
    /// Lock the counters — the one audited lock acquisition.
    fn locked(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a2q-lint: allow(panic-path) counter updates cannot panic while
        // holding the lock, so poisoning would itself be a prior bug
        self.inner.lock().unwrap()
    }

    pub fn record_admitted(&self) {
        self.locked().requests += 1;
    }

    pub fn record_rejected(&self) {
        self.locked().rejected += 1;
    }

    pub fn record_error(&self) {
        self.locked().errors += 1;
    }

    /// Count one successfully applied resident-graph update.  Sharded
    /// executors report how many shard local views the delta rebuilt and
    /// the post-delta halo size (unsharded sessions pass 0, 0).
    pub fn record_update(&self, shards_touched: u64, halo_nodes: u64) {
        let mut m = self.locked();
        m.updates += 1;
        m.shard_rebuilds += shards_touched;
        m.halo_nodes = halo_nodes;
    }

    /// Count one supervised runner respawn.
    pub fn record_runner_restart(&self) {
        self.locked().runner_restarts += 1;
    }

    /// Count one circuit-breaker open transition.
    pub fn record_breaker_open(&self) {
        self.locked().breaker_opens += 1;
    }

    /// Count one fast rejection by an open circuit breaker.
    pub fn record_breaker_rejected(&self) {
        self.locked().breaker_rejected += 1;
    }

    /// Record a model's current breaker state (gauge, per model).
    pub fn set_breaker_state(&self, model: &str, state: &'static str) {
        self.locked().breaker_states.insert(model.to_string(), state);
    }

    pub fn record_batch(&self, batch_size: usize) {
        let mut m = self.locked();
        m.batches += 1;
        m.batched_requests += batch_size as u64;
    }

    /// `queue_us` is admission → batch-execution start; `exec_us` the
    /// request's own sub-batch execution time.
    pub fn record_response(&self, latency_us: u64, queue_us: u64, exec_us: u64) {
        let mut m = self.locked();
        m.responses += 1;
        m.latency.record_us(latency_us as f64);
        m.queue_wait.record_us(queue_us as f64);
        m.exec.record_us(exec_us as f64);
        m.rate.record(Instant::now());
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut m = self.locked();
        MetricsSnapshot {
            requests: m.requests,
            responses: m.responses,
            rejected: m.rejected,
            errors: m.errors,
            batches: m.batches,
            updates: m.updates,
            shard_rebuilds: m.shard_rebuilds,
            halo_nodes: m.halo_nodes,
            runner_restarts: m.runner_restarts,
            breaker_opens: m.breaker_opens,
            breaker_rejected: m.breaker_rejected,
            breaker_states: m
                .breaker_states
                .iter()
                .map(|(k, v)| (k.clone(), (*v).to_string()))
                .collect(),
            mean_batch_size: if m.batches == 0 {
                0.0
            } else {
                m.batched_requests as f64 / m.batches as f64
            },
            mean_latency_us: m.latency.mean_us(),
            p50_latency_us: m.latency.percentile_us(50.0),
            p99_latency_us: m.latency.percentile_us(99.0),
            mean_queue_us: m.queue_wait.mean_us(),
            p50_queue_us: m.queue_wait.percentile_us(50.0),
            p99_queue_us: m.queue_wait.percentile_us(99.0),
            mean_exec_us: m.exec.mean_us(),
            p50_exec_us: m.exec.percentile_us(50.0),
            p99_exec_us: m.exec.percentile_us(99.0),
            throughput_rps: m.rate.rate(Instant::now()),
        }
    }
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        let breakers = if self.breaker_states.is_empty() {
            String::new()
        } else {
            let states: Vec<String> = self
                .breaker_states
                .iter()
                .map(|(m, s)| format!("{m}:{s}"))
                .collect();
            format!(" breakers=[{}]", states.join(","))
        };
        format!(
            "requests={} responses={} rejected={} errors={} batches={} updates={} \
             shard_rebuilds={} halo_nodes={} \
             restarts={} breaker_opens={} breaker_rejected={}{} \
             mean_batch={:.2} latency(mean/p50/p99)={:.0}/{:.0}/{:.0}µs \
             queue(mean/p50/p99)={:.0}/{:.0}/{:.0}µs \
             exec(mean/p50/p99)={:.0}/{:.0}/{:.0}µs throughput={:.1} rps (10s window)",
            self.requests,
            self.responses,
            self.rejected,
            self.errors,
            self.batches,
            self.updates,
            self.shard_rebuilds,
            self.halo_nodes,
            self.runner_restarts,
            self.breaker_opens,
            self.breaker_rejected,
            breakers,
            self.mean_batch_size,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.mean_queue_us,
            self.p50_queue_us,
            self.p99_queue_us,
            self.mean_exec_us,
            self.p50_exec_us,
            self.p99_exec_us,
            self.throughput_rps,
        )
    }

    /// Machine-readable snapshot (served by the wire protocol's metrics
    /// request).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("responses", Json::Num(self.responses as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("updates", Json::Num(self.updates as f64)),
            ("shard_rebuilds", Json::Num(self.shard_rebuilds as f64)),
            ("halo_nodes", Json::Num(self.halo_nodes as f64)),
            ("runner_restarts", Json::Num(self.runner_restarts as f64)),
            ("breaker_opens", Json::Num(self.breaker_opens as f64)),
            ("breaker_rejected", Json::Num(self.breaker_rejected as f64)),
            (
                "breaker_states",
                Json::obj(
                    self.breaker_states
                        .iter()
                        .map(|(m, s)| (m.as_str(), Json::Str(s.clone())))
                        .collect(),
                ),
            ),
            ("mean_batch_size", Json::Num(self.mean_batch_size)),
            ("mean_latency_us", Json::Num(self.mean_latency_us)),
            ("p50_latency_us", Json::Num(self.p50_latency_us)),
            ("p99_latency_us", Json::Num(self.p99_latency_us)),
            ("mean_queue_us", Json::Num(self.mean_queue_us)),
            ("p50_queue_us", Json::Num(self.p50_queue_us)),
            ("p99_queue_us", Json::Num(self.p99_queue_us)),
            ("mean_exec_us", Json::Num(self.mean_exec_us)),
            ("p50_exec_us", Json::Num(self.p50_exec_us)),
            ("p99_exec_us", Json::Num(self.p99_exec_us)),
            ("throughput_rps", Json::Num(self.throughput_rps)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.record_admitted();
        m.record_admitted();
        m.record_rejected();
        m.record_batch(2);
        m.record_response(100, 10, 90);
        m.record_response(300, 30, 270);
        m.record_update(3, 17);
        m.record_update(2, 21);
        m.record_runner_restart();
        m.record_breaker_open();
        m.record_breaker_rejected();
        m.record_breaker_rejected();
        m.set_breaker_state("mock", "closed");
        m.set_breaker_state("mock", "open");
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.updates, 2);
        assert_eq!(s.shard_rebuilds, 5, "shard rebuilds accumulate");
        assert_eq!(s.halo_nodes, 21, "halo gauge tracks the last report");
        assert_eq!(s.mean_batch_size, 2.0);
        assert!((s.mean_latency_us - 200.0).abs() < 1.0);
        assert!((s.mean_exec_us - 180.0).abs() < 1.0);
        assert!(s.p99_exec_us >= s.p50_exec_us);
        assert!(s.p99_queue_us >= s.p50_queue_us);
        assert_eq!(s.runner_restarts, 1);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.breaker_rejected, 2);
        assert_eq!(
            s.breaker_states,
            vec![("mock".to_string(), "open".to_string())],
            "breaker state gauge tracks the last report per model"
        );
        assert!(s.render().contains("requests=2"));
        assert!(s.render().contains("shard_rebuilds=5"));
        assert!(s.render().contains("breakers=[mock:open]"));
        // fresh traffic: the windowed rate is live, not zero
        assert!(s.throughput_rps > 0.0);
    }

    /// Regression for the decaying-RPS bug: the gauge must read the
    /// *current* rate — zero across an idle gap, and after new traffic a
    /// value reflecting only the window, not the lifetime average (the old
    /// responses-since-first-admission gauge could neither reach zero nor
    /// recover).  Synthetic clocks, no sleeping.
    #[test]
    fn rate_window_is_stable_across_idle_gaps() {
        let t0 = Instant::now();
        let mut w = RateWindow::new(t0);
        // 100 responses spread over the first second → ~100 rps
        for i in 0..100u64 {
            w.record(t0 + Duration::from_millis(i * 10));
        }
        let live = w.rate(t0 + Duration::from_secs(1));
        assert!(
            (live - 100.0).abs() < 15.0,
            "live rate should be ~100 rps, got {live}"
        );
        // a minute of idle: the window has slid past all traffic → exactly 0
        assert_eq!(w.rate(t0 + Duration::from_secs(61)), 0.0);
        // new burst after the gap counts only itself, not the lifetime
        for i in 0..50u64 {
            w.record(t0 + Duration::from_millis(61_000 + i * 10));
        }
        let after = w.rate(t0 + Duration::from_millis(61_500));
        assert!(after > 0.0, "fresh traffic must register");
        // 50 events over at most the full 10 s window: bounded well below
        // the stale lifetime numerator (150 events)
        assert!(after <= 50.0 / 0.5 + 1.0, "rate overshoots: {after}");
    }

    #[test]
    fn rate_window_survives_cursor_wraparound() {
        let t0 = Instant::now();
        let mut w = RateWindow::new(t0);
        // touch buckets far apart repeatedly — ring indices must stay sane
        for k in 0..10u64 {
            for i in 0..5u64 {
                w.record(t0 + Duration::from_secs(k * 30) + Duration::from_millis(i));
            }
        }
        let r = w.rate(t0 + Duration::from_secs(271));
        assert!(r >= 0.0 && r.is_finite());
        // only the final burst is inside the window
        assert!(r <= 5.0 / 0.5 + 1.0, "stale buckets leaked into rate: {r}");
    }

    #[test]
    fn snapshot_serializes_to_json() {
        let m = Metrics::default();
        m.record_admitted();
        m.record_batch(1);
        m.record_response(500, 50, 450);
        let j = m.snapshot().to_json();
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.req_f64("responses").unwrap(), 1.0);
        assert!(back.req_f64("p99_latency_us").unwrap() > 0.0);
        assert!(back.req_f64("p50_exec_us").unwrap() > 0.0);
    }
}
