//! Serving metrics: latency histograms + throughput counters.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LatencyHistogram;

#[derive(Debug, Default)]
struct Inner {
    latency: LatencyHistogram,
    queue_wait: LatencyHistogram,
    requests: u64,
    responses: u64,
    rejected: u64,
    errors: u64,
    batches: u64,
    batched_requests: u64,
    updates: u64,
    /// cumulative shard local-view rebuilds across applied deltas
    /// (sharded residents; 0 for unsharded sessions)
    shard_rebuilds: u64,
    /// last observed Σ halo mirror nodes of the sharded resident (gauge)
    halo_nodes: u64,
    started: Option<Instant>,
}

/// Thread-safe metrics sink shared across the pipeline.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub rejected: u64,
    pub errors: u64,
    pub batches: u64,
    /// successfully applied resident-graph updates
    pub updates: u64,
    /// cumulative shard local-view rebuilds (sharded residents)
    pub shard_rebuilds: u64,
    /// last observed Σ halo mirror nodes of the sharded resident (gauge)
    pub halo_nodes: u64,
    pub mean_batch_size: f64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub mean_queue_us: f64,
    pub throughput_rps: f64,
}

impl Metrics {
    /// Lock the counters — the one audited lock acquisition.
    fn locked(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a2q-lint: allow(panic-path) counter updates cannot panic while
        // holding the lock, so poisoning would itself be a prior bug
        self.inner.lock().unwrap()
    }

    pub fn record_admitted(&self) {
        let mut m = self.locked();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
        m.requests += 1;
    }

    pub fn record_rejected(&self) {
        self.locked().rejected += 1;
    }

    pub fn record_error(&self) {
        self.locked().errors += 1;
    }

    /// Count one successfully applied resident-graph update.  Sharded
    /// executors report how many shard local views the delta rebuilt and
    /// the post-delta halo size (unsharded sessions pass 0, 0).
    pub fn record_update(&self, shards_touched: u64, halo_nodes: u64) {
        let mut m = self.locked();
        m.updates += 1;
        m.shard_rebuilds += shards_touched;
        m.halo_nodes = halo_nodes;
    }

    pub fn record_batch(&self, batch_size: usize) {
        let mut m = self.locked();
        m.batches += 1;
        m.batched_requests += batch_size as u64;
    }

    pub fn record_response(&self, latency_us: u64, queue_us: u64) {
        let mut m = self.locked();
        m.responses += 1;
        m.latency.record_us(latency_us as f64);
        m.queue_wait.record_us(queue_us as f64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.locked();
        let elapsed = m
            .started
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
            .max(1e-9);
        MetricsSnapshot {
            requests: m.requests,
            responses: m.responses,
            rejected: m.rejected,
            errors: m.errors,
            batches: m.batches,
            updates: m.updates,
            shard_rebuilds: m.shard_rebuilds,
            halo_nodes: m.halo_nodes,
            mean_batch_size: if m.batches == 0 {
                0.0
            } else {
                m.batched_requests as f64 / m.batches as f64
            },
            mean_latency_us: m.latency.mean_us(),
            p50_latency_us: m.latency.percentile_us(50.0),
            p99_latency_us: m.latency.percentile_us(99.0),
            mean_queue_us: m.queue_wait.mean_us(),
            throughput_rps: m.responses as f64 / elapsed,
        }
    }
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} responses={} rejected={} errors={} batches={} updates={} \
             shard_rebuilds={} halo_nodes={} \
             mean_batch={:.2} latency(mean/p50/p99)={:.0}/{:.0}/{:.0}µs \
             queue_mean={:.0}µs throughput={:.1} rps",
            self.requests,
            self.responses,
            self.rejected,
            self.errors,
            self.batches,
            self.updates,
            self.shard_rebuilds,
            self.halo_nodes,
            self.mean_batch_size,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p99_latency_us,
            self.mean_queue_us,
            self.throughput_rps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.record_admitted();
        m.record_admitted();
        m.record_rejected();
        m.record_batch(2);
        m.record_response(100, 10);
        m.record_response(300, 30);
        m.record_update(3, 17);
        m.record_update(2, 21);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.updates, 2);
        assert_eq!(s.shard_rebuilds, 5, "shard rebuilds accumulate");
        assert_eq!(s.halo_nodes, 21, "halo gauge tracks the last report");
        assert_eq!(s.mean_batch_size, 2.0);
        assert!((s.mean_latency_us - 200.0).abs() < 1.0);
        assert!(s.render().contains("requests=2"));
        assert!(s.render().contains("shard_rebuilds=5"));
    }
}
