//! Request router: model name → per-model runner queue.

use std::collections::HashMap;
use std::sync::mpsc;

use crate::error::{Error, Result};

use super::request::Request;

/// Routes requests to per-model bounded queues.
pub struct Router {
    queues: HashMap<String, mpsc::SyncSender<Request>>,
}

impl Router {
    pub fn new() -> Router {
        Router {
            queues: HashMap::new(),
        }
    }

    /// Register a model runner queue; returns the receiving end.
    pub fn register(&mut self, model: &str, depth: usize) -> mpsc::Receiver<Request> {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        self.queues.insert(model.to_string(), tx);
        rx
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.queues.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route a request.  `Err` carries the request back on unknown model or
    /// full queue (the caller decides how to reply).
    pub fn route(&self, req: Request) -> Result<()> {
        let q = self.queues.get(&req.model).ok_or_else(|| {
            Error::coordinator(format!("unknown model '{}'", req.model))
        })?;
        q.try_send(req)
            .map_err(|e| match e {
                mpsc::TrySendError::Full(_) => Error::coordinator("queue full"),
                mpsc::TrySendError::Disconnected(_) => {
                    Error::coordinator("runner stopped")
                }
            })
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Payload;
    use std::time::Instant;

    fn req(model: &str) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            model: model.into(),
            payload: Payload::ClassifyNodes(vec![0]),
            enqueued: Instant::now(),
            reply: tx,
        }
    }

    #[test]
    fn routes_to_registered_queue() {
        let mut r = Router::new();
        let rx = r.register("gcn", 4);
        r.route(req("gcn")).unwrap();
        assert!(rx.try_recv().is_ok());
    }

    #[test]
    fn unknown_model_rejected() {
        let r = Router::new();
        assert!(r.route(req("nope")).is_err());
    }

    #[test]
    fn full_queue_backpressure() {
        let mut r = Router::new();
        let _rx = r.register("gcn", 1);
        r.route(req("gcn")).unwrap();
        let err = r.route(req("gcn")).unwrap_err();
        assert!(format!("{err}").contains("queue full"));
    }

    #[test]
    fn lists_models_sorted() {
        let mut r = Router::new();
        let _a = r.register("zeta", 1);
        let _b = r.register("alpha", 1);
        assert_eq!(r.models(), vec!["alpha", "zeta"]);
    }
}
