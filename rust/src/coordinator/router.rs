//! Request router: model name → per-model runner queue.

use std::collections::HashMap;
use std::sync::mpsc;

use crate::error::Error;

use super::request::Request;

/// Why the router refused a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// no runner registered under that model name
    UnknownModel,
    /// the model's bounded queue is full (overload backpressure)
    QueueFull,
    /// the runner's receiving end is gone (shutdown/drain in progress)
    Stopped,
    /// the model's circuit breaker is open (its executor has been
    /// failing every batch); retry after the hinted cooldown
    BreakerOpen { retry_after_ms: u64 },
}

impl RejectReason {
    /// Stable lowercase tag (used by the wire protocol's rejection replies).
    pub fn as_str(&self) -> &'static str {
        match self {
            RejectReason::UnknownModel => "unknown_model",
            RejectReason::QueueFull => "overloaded",
            RejectReason::Stopped => "stopped",
            RejectReason::BreakerOpen { .. } => "breaker_open",
        }
    }
}

/// A refused admission.  Carries the whole [`Request`] back — including its
/// reply channel — so the caller can answer the client explicitly (an
/// on-channel error, or an on-protocol rejection frame at the net layer)
/// instead of silently dropping the reply sender.
#[derive(Debug)]
pub struct Rejected {
    pub request: Request,
    pub reason: RejectReason,
}

impl Rejected {
    /// The legacy error shape (`submit` returns this when the caller does
    /// not want the request back).
    pub fn into_error(self) -> Error {
        match self.reason {
            RejectReason::UnknownModel => {
                Error::coordinator(format!("unknown model '{}'", self.request.model))
            }
            RejectReason::QueueFull => Error::coordinator("queue full"),
            RejectReason::Stopped => Error::coordinator("runner stopped"),
            RejectReason::BreakerOpen { retry_after_ms } => Error::coordinator(format!(
                "circuit breaker open for model '{}', retry in {retry_after_ms} ms",
                self.request.model
            )),
        }
    }
}

/// Routes requests to per-model bounded queues.
pub struct Router {
    queues: HashMap<String, mpsc::SyncSender<Request>>,
}

impl Router {
    pub fn new() -> Router {
        Router {
            queues: HashMap::new(),
        }
    }

    /// Register a model runner queue; returns the receiving end.
    pub fn register(&mut self, model: &str, depth: usize) -> mpsc::Receiver<Request> {
        let (tx, rx) = mpsc::sync_channel(depth.max(1));
        self.queues.insert(model.to_string(), tx);
        rx
    }

    pub fn models(&self) -> Vec<String> {
        let mut v: Vec<String> = self.queues.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route a request.  The `Err` variant carries the request back —
    /// reply channel included — on unknown model, full queue, or stopped
    /// runner, so the caller decides how to reply (it is never silently
    /// dropped here).
    pub fn route(&self, req: Request) -> std::result::Result<(), Rejected> {
        let Some(q) = self.queues.get(&req.model) else {
            return Err(Rejected {
                request: req,
                reason: RejectReason::UnknownModel,
            });
        };
        q.try_send(req).map_err(|e| match e {
            mpsc::TrySendError::Full(request) => Rejected {
                request,
                reason: RejectReason::QueueFull,
            },
            mpsc::TrySendError::Disconnected(request) => Rejected {
                request,
                reason: RejectReason::Stopped,
            },
        })
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Payload;
    use crate::error::Result;
    use std::time::Instant;

    fn req(model: &str) -> Request {
        req_with_rx(model).0
    }

    fn req_with_rx(
        model: &str,
    ) -> (Request, mpsc::Receiver<Result<super::super::request::Response>>) {
        let (tx, rx) = mpsc::channel();
        (
            Request {
                model: model.into(),
                payload: Payload::ClassifyNodes(vec![0]),
                enqueued: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn routes_to_registered_queue() {
        let mut r = Router::new();
        let rx = r.register("gcn", 4);
        r.route(req("gcn")).unwrap();
        assert!(rx.try_recv().is_ok());
    }

    #[test]
    fn unknown_model_rejected_with_request_back() {
        let r = Router::new();
        let rej = r.route(req("nope")).unwrap_err();
        assert_eq!(rej.reason, RejectReason::UnknownModel);
        assert_eq!(rej.request.model, "nope");
        assert!(format!("{}", rej.into_error()).contains("unknown model 'nope'"));
    }

    #[test]
    fn full_queue_backpressure() {
        let mut r = Router::new();
        let _rx = r.register("gcn", 1);
        r.route(req("gcn")).unwrap();
        let rej = r.route(req("gcn")).unwrap_err();
        assert_eq!(rej.reason, RejectReason::QueueFull);
        assert!(format!("{}", rej.into_error()).contains("queue full"));
    }

    /// Regression: the rejection must carry the reply channel back so the
    /// caller can answer the client on-channel (the old signature dropped
    /// the request, so an overloaded client's receiver just disconnected).
    #[test]
    fn rejection_carries_reply_channel_for_on_channel_reply() {
        let mut r = Router::new();
        let _queue_rx = r.register("gcn", 1);
        r.route(req("gcn")).unwrap();
        let (second, client_rx) = req_with_rx("gcn");
        let rej = r.route(second).unwrap_err();
        assert_eq!(rej.reason, RejectReason::QueueFull);
        // the caller replies explicitly instead of dropping the sender
        rej.request
            .reply
            .send(Err(Error::coordinator("overloaded, retry later")))
            .unwrap();
        let got = client_rx.try_recv().unwrap().unwrap_err();
        assert!(format!("{got}").contains("overloaded, retry later"));
    }

    #[test]
    fn stopped_runner_reported_as_stopped() {
        let mut r = Router::new();
        let rx = r.register("gcn", 1);
        drop(rx);
        let rej = r.route(req("gcn")).unwrap_err();
        assert_eq!(rej.reason, RejectReason::Stopped);
        assert_eq!(rej.reason.as_str(), "stopped");
    }

    #[test]
    fn lists_models_sorted() {
        let mut r = Router::new();
        let _a = r.register("zeta", 1);
        let _b = r.register("alpha", 1);
        assert_eq!(r.models(), vec!["alpha", "zeta"]);
    }
}
