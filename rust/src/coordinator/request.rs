//! Request / response types for the serving API.

use std::sync::mpsc;
use std::time::Instant;

use crate::graph::delta::GraphDelta;
use crate::graph::io::SmallGraph;

/// A prediction for one request.
#[derive(Debug, Clone)]
pub struct Prediction {
    /// raw output vector (logits or regression value)
    pub output: Vec<f32>,
    /// argmax class for classification outputs
    pub class: usize,
}

impl Prediction {
    pub fn from_logits(output: Vec<f32>) -> Prediction {
        // NaN logits (a degenerate model, not a protocol error) must
        // neither panic the runner's response path (the old
        // partial_cmp().unwrap()) nor hijack the argmax (total_cmp alone
        // would rank NaN above every real): skip them, fall back to class
        // 0 only when every logit is NaN.
        let class = output
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        Prediction { output, class }
    }
}

/// Server response.
#[derive(Debug, Clone)]
pub struct Response {
    pub predictions: Vec<Prediction>,
    pub model: String,
    /// microseconds spent queued + executing
    pub latency_us: u64,
    /// how many requests shared the executed batch
    pub batch_size: usize,
}

/// Client request payload.
#[derive(Debug)]
pub enum Payload {
    /// classify these nodes of the model's resident graph
    ClassifyNodes(Vec<u32>),
    /// predict for a client-supplied small graph
    PredictGraph(SmallGraph),
    /// mutate the model's resident graph (dynamic-graph serving).  The
    /// batcher never batches an update with other requests: it executes
    /// alone, in arrival order, so a classify admitted after an update's
    /// reply always observes the post-update epoch.  The reply carries no
    /// predictions.
    UpdateGraph(GraphDelta),
}

/// Internal envelope: payload + reply channel + admission timestamp.
#[derive(Debug)]
pub struct Request {
    pub model: String,
    pub payload: Payload,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<crate::error::Result<Response>>,
}

impl Request {
    pub fn num_nodes(&self) -> usize {
        match &self.payload {
            Payload::ClassifyNodes(ids) => ids.len(),
            Payload::PredictGraph(g) => g.num_nodes(),
            Payload::UpdateGraph(d) => d.add_nodes,
        }
    }

    /// Whether this request mutates the resident graph (executes alone).
    pub fn is_update(&self) -> bool {
        matches!(self.payload, Payload::UpdateGraph(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_argmax() {
        let p = Prediction::from_logits(vec![0.1, 2.0, -1.0]);
        assert_eq!(p.class, 1);
        let empty = Prediction::from_logits(vec![]);
        assert_eq!(empty.class, 0);
    }

    #[test]
    fn prediction_argmax_ignores_nan_without_panicking() {
        let p = Prediction::from_logits(vec![0.9, f32::NAN, 0.3]);
        assert_eq!(p.class, 0);
        let all_nan = Prediction::from_logits(vec![f32::NAN, f32::NAN]);
        assert_eq!(all_nan.class, 0);
    }
}
