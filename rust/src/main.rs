//! `a2q` — the L3 command-line entry point.
//!
//! Commands:
//!   models    list the AOT model artifacts
//!   infer     run one inference through the PJRT runtime
//!   serve     run the serving coordinator under a synthetic load
//!   simulate  run the cycle-accurate accelerator simulator
//!   tables    regenerate the paper's tables from recorded results
//!   figures   regenerate the paper's figure series (CSV)

use std::sync::Arc;
use std::time::{Duration, Instant};

use a2q::coordinator::request::Payload;
use a2q::coordinator::{BatcherConfig, Coordinator, PjrtExecutor};
use a2q::error::{Error, Result};
use a2q::harness::tables::{render_table, TableSpec};
use a2q::harness::{figures, ResultsStore};
use a2q::quant::mixed::BitsFile;
use a2q::runtime::{ArtifactIndex, EngineHandle};
use a2q::util::cli::{App, CommandSpec};
use a2q::util::rng::Rng;

fn app() -> App {
    App::new("a2q", "Aggregation-Aware Quantization for GNNs — serving & evaluation")
        .command(CommandSpec::new("models", "list AOT model artifacts"))
        .command(
            CommandSpec::new("infer", "run one inference via PJRT")
                .opt("model", "gcn-synth-cora-a2q", "artifact name")
                .opt("nodes", "8", "how many nodes to classify (node-level)"),
        )
        .command(
            CommandSpec::new("serve", "run the coordinator under synthetic load")
                .opt("model", "gcn-synth-cora-a2q", "artifact name")
                .opt("requests", "200", "number of requests")
                .opt("clients", "4", "concurrent client threads")
                .opt("max-wait-ms", "5", "batcher deadline (ms)"),
        )
        .command(
            CommandSpec::new("simulate", "cycle-accurate accelerator simulation")
                .opt("model", "gcn-synth-cora-a2q", "artifact name (needs bits.bin)")
                .flag("unsorted", "disable the degree/bit-sorted schedules"),
        )
        .command(
            CommandSpec::new("tables", "regenerate paper tables")
                .opt("id", "all", "table1|table2|table3|table6|table11|table13|table16|fig5|all"),
        )
        .command(
            CommandSpec::new("figures", "regenerate paper figure series (CSV)")
                .opt("id", "all", "fig1|fig3|fig4|fig8|fig22|all")
                .opt("dataset", "synth-cora", "dataset for fig1/fig4/fig8")
                .opt("arch", "gcn", "architecture for fig4"),
        )
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let app = app();
    let matches = match app.parse(&args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let cmd = matches.command.clone();
    if let Err(e) = run(&cmd, matches) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(cmd: &str, m: a2q::util::cli::Matches) -> Result<()> {
    let artifacts = a2q::artifacts_dir();
    match cmd {
        "models" => {
            let index = ArtifactIndex::load(&artifacts)?;
            println!(
                "{:<34} {:>8} {:>9} {:>11} {:>9}",
                "name", "method", "avg_bits", "compression", "accuracy"
            );
            for a in index.all()? {
                println!(
                    "{:<34} {:>8} {:>9.2} {:>10.1}x {:>8.4}",
                    a.name,
                    a.method,
                    a.avg_bits,
                    32.0 / a.avg_bits.max(0.01),
                    a.accuracy
                );
            }
            Ok(())
        }
        "infer" => {
            let index = ArtifactIndex::load(&artifacts)?;
            let artifact = index.artifact(m.req("model")?)?;
            let dataset = a2q::graph::io::load_named(&artifacts, &artifact.dataset)?;
            let engine = EngineHandle::spawn()?;
            println!("platform: {}", engine.platform()?);
            let t0 = Instant::now();
            let exec = PjrtExecutor::new(engine, &artifact, Some(&dataset))?;
            println!("compiled {} in {:?}", artifact.name, t0.elapsed());
            let n = m.get_usize("nodes")?;
            let ids: Vec<u32> = (0..n as u32).collect();
            let t1 = Instant::now();
            let outputs = {
                use a2q::coordinator::BatchExecutor;
                exec.run_node_batch(&ids)?
            };
            println!("executed in {:?}", t1.elapsed());
            for (v, out) in ids.iter().zip(&outputs) {
                let class = out
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                println!(
                    "node {v}: class {class} logits {:?}",
                    &out[..out.len().min(4)]
                );
            }
            Ok(())
        }
        "serve" => {
            let index = ArtifactIndex::load(&artifacts)?;
            let artifact = index.artifact(m.req("model")?)?;
            let dataset = a2q::graph::io::load_named(&artifacts, &artifact.dataset)?;
            let engine = EngineHandle::spawn()?;
            let exec = Arc::new(PjrtExecutor::new(engine, &artifact, Some(&dataset))?);
            let mut coord = Coordinator::new();
            let cfg = BatcherConfig {
                max_wait: Duration::from_millis(m.get_usize("max-wait-ms")? as u64),
                ..BatcherConfig::default()
            };
            coord.add_model(&artifact.name, exec, cfg);
            let coord = Arc::new(coord);
            let total = m.get_usize("requests")?;
            let clients = m.get_usize("clients")?;
            let num_nodes = artifact.num_nodes;
            let t0 = Instant::now();
            let mut joins = Vec::new();
            for c in 0..clients {
                let coord = Arc::clone(&coord);
                let name = artifact.name.clone();
                joins.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(c as u64);
                    let mut ok = 0usize;
                    for _ in 0..total / clients {
                        let ids = vec![rng.below(num_nodes) as u32];
                        if coord
                            .submit_blocking(&name, Payload::ClassifyNodes(ids))
                            .is_ok()
                        {
                            ok += 1;
                        }
                    }
                    ok
                }));
            }
            let ok: usize = joins.into_iter().map(|j| j.join().unwrap()).sum();
            let wall = t0.elapsed();
            println!("served {ok} requests in {wall:?}");
            println!("{}", coord.metrics().render());
            Ok(())
        }
        "simulate" => {
            let index = ArtifactIndex::load(&artifacts)?;
            let artifact = index.artifact(m.req("model")?)?;
            let bits_path = artifact
                .bits_path()
                .ok_or_else(|| Error::artifact("model has no bits.bin (fp32?)"))?;
            let bf = BitsFile::load(&bits_path)?;
            let csr =
                a2q::harness::tables::representative_csr(&artifacts, &artifact.dataset)?;
            let cfg = if m.has_flag("unsorted") {
                a2q::accel::AccelConfig::unsorted()
            } else {
                a2q::accel::AccelConfig::default()
            };
            let sim = a2q::accel::Simulator::new(cfg);
            let n_maps = bf.maps.len();
            let matmuls: Vec<(usize, usize)> = bf
                .maps
                .iter()
                .enumerate()
                .map(|(i, (_b, dim))| {
                    (*dim, if i + 1 == n_maps { artifact.out_dim } else { 64 })
                })
                .collect();
            let workload = a2q::accel::ModelWorkload::from_bits_file(&bf, matmuls, 0);
            let stats = a2q::accel::simulate_model_cycles(&sim, &csr, &workload);
            let speedup = a2q::accel::speedup_vs_dq(&sim, &csr, &workload);
            let energy = a2q::accel::EnergyModel::default();
            let rep = energy.accelerator(&stats);
            println!("model {}  avg_bits {:.2}", artifact.name, bf.avg_bits());
            println!(
                "cycles: update {} + aggregate {} = {}",
                stats.update_cycles,
                stats.aggregate_cycles,
                stats.total_cycles()
            );
            println!(
                "ops: int_mults {}M  int_adds {}M  float {}M",
                stats.int_mults / 1_000_000,
                stats.int_adds / 1_000_000,
                stats.float_ops / 1_000_000
            );
            println!("speedup vs DQ-INT4: {speedup:.2}x");
            println!(
                "energy: compute {:.1} µJ, sram {:.1} µJ, off-chip {:.1} µJ  (vs GPU model: {:.1}x better)",
                rep.compute_nj / 1e3,
                rep.sram_nj / 1e3,
                rep.offchip_nj / 1e3,
                energy.efficiency_vs_gpu(&stats)
            );
            Ok(())
        }
        "tables" => {
            let store = ResultsStore::load(&artifacts)?;
            let id = m.req("id")?;
            let specs: Vec<TableSpec> = if id == "all" {
                TableSpec::all().to_vec()
            } else {
                vec![TableSpec::parse(id)
                    .ok_or_else(|| Error::config(format!("unknown table '{id}'")))?]
            };
            for spec in specs {
                println!("{}", render_table(spec, &store, &artifacts));
            }
            Ok(())
        }
        "figures" => {
            let store = ResultsStore::load(&artifacts)?;
            let id = m.req("id")?;
            let dataset = m.req("dataset")?;
            let arch = m.req("arch")?;
            let all = id == "all";
            if all || id == "fig1" {
                print!("{}", figures::fig1(&artifacts, dataset)?);
            }
            if all || id == "fig3" {
                print!("{}", figures::fig3(&store));
            }
            if all || id == "fig4" {
                print!("{}", figures::fig4(&store, &artifacts, dataset, arch)?);
            }
            if all || id == "fig8" {
                print!("{}", figures::fig8(&artifacts, dataset)?);
            }
            if all || id == "fig22" {
                print!("{}", figures::fig22(&store, &artifacts));
            }
            Ok(())
        }
        other => Err(Error::config(format!("unhandled command {other}"))),
    }
}
