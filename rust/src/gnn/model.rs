//! Model parameter loading from AOT manifests.
//!
//! `python/compile/aot.py` exports a `manifest.json` (tensor table) plus a
//! flat `weights.bin` (little-endian f32 in table order).  Tensor names are
//! jax key paths like `['model']['layers'][0]['w']`; this module parses
//! them back into typed layer structs.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};
use crate::quant::mixed::NodeQuantParams;
use crate::tensor::Matrix;
use crate::util::json::{self, Json};

/// Quantization method baked into an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMethod {
    Fp32,
    A2q,
    Dq,
    Binary,
}

impl QuantMethod {
    pub fn parse(s: &str) -> QuantMethod {
        match s {
            "a2q" | "a2q_global" | "manual" => QuantMethod::A2q,
            "dq" => QuantMethod::Dq,
            "binary" => QuantMethod::Binary,
            _ => QuantMethod::Fp32,
        }
    }
}

/// One GNN layer's parameters (union across architectures).
#[derive(Debug, Clone, Default)]
pub struct LayerParams {
    pub w: Option<Matrix<f32>>,
    pub b: Vec<f32>,
    // GIN MLP second matmul
    pub w2: Option<Matrix<f32>>,
    pub b2: Vec<f32>,
    pub eps: f32,
    // GAT attention
    pub a_src: Option<Matrix<f32>>, // [heads, fh]
    pub a_dst: Option<Matrix<f32>>,
    pub attn_step: f32,
    // per-output-column weight quant steps
    pub w_steps: Vec<f32>,
    pub w2_steps: Vec<f32>,
    // per-node feature quant params (layer input), and the GIN hidden map
    pub feat: Option<NodeQuantParams>,
    pub feat2: Option<NodeQuantParams>,
}

/// Readout head (graph-level models).
#[derive(Debug, Clone)]
pub struct HeadParams {
    pub w1: Matrix<f32>,
    pub b1: Vec<f32>,
    pub w2: Matrix<f32>,
    pub b2: Vec<f32>,
    pub w1_steps: Vec<f32>,
    pub w2_steps: Vec<f32>,
    pub feat: Option<NodeQuantParams>,
}

/// A fully-loaded model artifact (weights + quantization parameters +
/// metadata).  The HLO side of the same artifact is handled by
/// `runtime::Engine`.
#[derive(Debug, Clone)]
pub struct GnnModel {
    pub name: String,
    pub arch: String,
    pub dataset: String,
    pub method: QuantMethod,
    pub layers: Vec<LayerParams>,
    pub head: Option<HeadParams>,
    pub dq_steps: Vec<f32>,
    pub skip_input_quant: bool,
    pub node_level: bool,
    pub num_nodes: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    pub heads: usize,
    pub graph_capacity: usize,
    pub accuracy: f64,
    pub avg_bits: f64,
    pub expected_head: Vec<f32>,
    pub manifest: Json,
}

struct TensorTable {
    tensors: BTreeMap<String, (Vec<usize>, usize)>, // name -> (shape, offset)
    data: Vec<f32>,
}

impl TensorTable {
    fn get(&self, name: &str) -> Option<(Vec<usize>, &[f32])> {
        let (shape, off) = self.tensors.get(name)?;
        let len: usize = shape.iter().product::<usize>().max(1);
        Some((shape.clone(), &self.data[*off..*off + len]))
    }

    fn vec(&self, name: &str) -> Option<Vec<f32>> {
        self.get(name).map(|(_, s)| s.to_vec())
    }

    fn matrix(&self, name: &str) -> Result<Option<Matrix<f32>>> {
        match self.get(name) {
            None => Ok(None),
            Some((shape, data)) => {
                if shape.len() != 2 {
                    return Err(Error::artifact(format!(
                        "tensor {name} is not 2-D: {shape:?}"
                    )));
                }
                Ok(Some(Matrix::from_vec(shape[0], shape[1], data.to_vec())?))
            }
        }
    }

    fn scalar(&self, name: &str) -> Option<f32> {
        self.get(name).and_then(|(_, s)| s.first().copied())
    }
}

impl GnnModel {
    /// Load `<dir>/<name>.manifest.json` + its weights.
    pub fn load(dir: &Path, name: &str) -> Result<GnnModel> {
        let man_path = dir.join(format!("{name}.manifest.json"));
        let man = json::parse_file(&man_path)?;
        let weights_path = dir.join(man.req_str("weights_bin")?);
        let mut raw = Vec::new();
        std::fs::File::open(&weights_path)?.read_to_end(&mut raw)?;
        if raw.len() % 4 != 0 {
            return Err(Error::artifact("weights.bin not a multiple of 4 bytes"));
        }
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();

        let mut tensors = BTreeMap::new();
        for t in man
            .req("tensors")?
            .as_arr()
            .ok_or_else(|| Error::artifact("tensors not an array"))?
        {
            let tname = t.req_str("name")?.to_string();
            let shape: Vec<usize> = t
                .req("shape")?
                .as_arr()
                .ok_or_else(|| Error::artifact("bad shape"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            let offset = t.req_usize("offset")?;
            tensors.insert(tname, (shape, offset));
        }
        let table = TensorTable { tensors, data };

        let arch = man.req_str("arch")?.to_string();
        let method = QuantMethod::parse(man.req_str("method")?);
        let n_layers = man.req_usize("layers")?;
        let node_level = man.req("node_level")?.as_bool().unwrap_or(true);
        let num_nodes = man.req_usize("num_nodes")?;
        let signed_in = true;

        let mut layers = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let p = |suffix: &str| format!("['model']['layers'][{l}]{suffix}");
            let q = |suffix: &str| format!("['qp']{suffix}");
            let mut lay = LayerParams {
                w: table.matrix(&p("['w']"))?.or(table.matrix(&p("['w1']"))?),
                b: table
                    .vec(&p("['b']"))
                    .or_else(|| table.vec(&p("['b1']")))
                    .unwrap_or_default(),
                w2: table.matrix(&p("['w2']"))?,
                b2: table.vec(&p("['b2']")).unwrap_or_default(),
                eps: table.scalar(&p("['eps']")).unwrap_or(0.0),
                a_src: table.matrix(&p("['a_src']"))?,
                a_dst: table.matrix(&p("['a_dst']"))?,
                attn_step: table
                    .scalar(&q(&format!("['attn'][{l}]")))
                    .unwrap_or(0.05),
                w_steps: table
                    .vec(&q(&format!("['w'][{l}][0]")))
                    .unwrap_or_default(),
                w2_steps: table
                    .vec(&q(&format!("['w'][{l}][1]")))
                    .unwrap_or_default(),
                feat: None,
                feat2: None,
            };
            // per-node (or NNS-group) feature quant params
            let fs = table.vec(&q(&format!("['feat'][{l}]['s']")));
            let fb = table.vec(&q(&format!("['feat'][{l}]['b']")));
            if let (Some(s), Some(b)) = (fs, fb) {
                let bits: Vec<u8> = b.iter().map(|&x| x.round().clamp(1.0, 8.0) as u8).collect();
                // input layer is signed; deeper layers unsigned (post-ReLU)
                // for gcn/gin, signed for gat (ELU) — matching models.py
                let signed = if l == 0 { signed_in } else { arch == "gat" };
                lay.feat = Some(NodeQuantParams::new(s, bits, signed)?);
            }
            let fs2 = table.vec(&q(&format!("['feat2'][{l}]['s']")));
            let fb2 = table.vec(&q(&format!("['feat2'][{l}]['b']")));
            if let (Some(s), Some(b)) = (fs2, fb2) {
                let bits: Vec<u8> = b.iter().map(|&x| x.round().clamp(1.0, 8.0) as u8).collect();
                lay.feat2 = Some(NodeQuantParams::new(s, bits, false)?);
            }
            layers.push(lay);
        }

        let head = match table.matrix("['model']['head']['w1']")? {
            Some(w1) => {
                let hf_s = table.vec("['qp']['head_feat']['s']");
                let hf_b = table.vec("['qp']['head_feat']['b']");
                let feat = match (hf_s, hf_b) {
                    (Some(s), Some(b)) => {
                        let bits: Vec<u8> =
                            b.iter().map(|&x| x.round().clamp(1.0, 8.0) as u8).collect();
                        Some(NodeQuantParams::new(s, bits, true)?)
                    }
                    _ => None,
                };
                Some(HeadParams {
                    w1,
                    b1: table.vec("['model']['head']['b1']").unwrap_or_default(),
                    w2: table
                        .matrix("['model']['head']['w2']")?
                        .ok_or_else(|| Error::artifact("head.w2 missing"))?,
                    b2: table.vec("['model']['head']['b2']").unwrap_or_default(),
                    w1_steps: table.vec("['qp']['head_w'][0]").unwrap_or_default(),
                    w2_steps: table.vec("['qp']['head_w'][1]").unwrap_or_default(),
                    feat,
                })
            }
            None => None,
        };

        let mut dq_steps = Vec::new();
        for l in 0..=n_layers {
            if let Some(s) = table.scalar(&format!("['qp']['dq_s'][{l}]")) {
                dq_steps.push(s);
            }
        }

        Ok(GnnModel {
            name: name.to_string(),
            arch,
            dataset: man.req_str("dataset")?.to_string(),
            method,
            layers,
            head,
            dq_steps,
            skip_input_quant: man
                .get("skip_input_quant")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            node_level,
            num_nodes,
            in_dim: man.req_usize("in_dim")?,
            out_dim: man.req_usize("out_dim")?,
            heads: man.req_usize("heads")?,
            graph_capacity: man.req_usize("graph_capacity")?,
            accuracy: man.req_f64("accuracy")?,
            avg_bits: man.req_f64("avg_bits")?,
            expected_head: man
                .req("expected_head")?
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
                .unwrap_or_default(),
            manifest: man,
        })
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_method_parsing() {
        assert_eq!(QuantMethod::parse("a2q"), QuantMethod::A2q);
        assert_eq!(QuantMethod::parse("a2q_global"), QuantMethod::A2q);
        assert_eq!(QuantMethod::parse("dq"), QuantMethod::Dq);
        assert_eq!(QuantMethod::parse("fp32"), QuantMethod::Fp32);
        assert_eq!(QuantMethod::parse("binary"), QuantMethod::Binary);
        assert_eq!(QuantMethod::parse("other"), QuantMethod::Fp32);
    }

    // Full loading is covered by the integration test rust/tests/
    // artifact_roundtrip.rs (requires `make artifacts`).
}
