//! Native GNN inference (no python, no PJRT).
//!
//! Two execution paths over the same loaded parameters:
//!
//! * [`infer::forward_fp`] — f32 emulation of the quantized forward
//!   (fake-quant), numerically identical to the exported HLO artifact;
//!   integration tests pin it against the PJRT path and against the logits
//!   recorded by python at export time.
//! * [`infer::forward_int`] — the true integer path: per-node codes,
//!   i32-accumulate matmuls, Eq. 2 outer-product rescale, Â never quantized
//!   (Proof 2).  This is the arithmetic the paper's accelerator executes;
//!   the simulator derives its cycle counts from exactly these shapes.
//!
//! Serving paths build a [`prepared::PreparedModel`] once per loaded model
//! — quantized weights, integer weight codes, clamped steps, and NNS
//! tables are all request-invariant — and run the `*_prepared` forward
//! entry points against it; the `*_with` signatures remain as per-call
//! shims.

pub mod incremental;
pub mod infer;
pub mod model;
pub mod prepared;
pub mod sharded;

pub use incremental::{build_assign_tables, patch_activations, NnsAssignTables};
pub use infer::{
    forward_fp, forward_fp_prepared, forward_fp_prepared_recording,
    forward_fp_prepared_with_plan, forward_fp_with, forward_int, forward_int_prepared,
    forward_int_prepared_recording, forward_int_prepared_with_plan, forward_int_with,
    GraphInput,
};
pub use model::{GnnModel, LayerParams, QuantMethod};
pub use prepared::{PreparedHead, PreparedLayer, PreparedModel};
pub use sharded::{
    forward_fp_sharded, forward_fp_sharded_recording, forward_int_sharded,
    forward_int_sharded_recording,
};
