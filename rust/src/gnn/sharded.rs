//! Shard-parallel forward passes over a partitioned resident graph.
//!
//! `forward_{fp,int}_sharded` run the same network as
//! [`super::infer::forward_fp_prepared`] / `forward_int_prepared`, but
//! layer-by-layer across the shards of a [`ShardedGraph`]: each layer,
//! every shard **gathers** its mirror block (owned rows + halo rows — the
//! halo exchange) out of the global activation matrix, computes its owned
//! output rows against its local [`AggregationPlan`], and the owned blocks
//! are scattered back into the next global matrix before the next layer.
//!
//! **Bitwise identity** with the single-shard prepared path holds by
//! construction and is property-tested in `rust/tests/shard_parity.rs`:
//!
//! * every output row has exactly one owning shard, and the per-row f32
//!   kernels (`ops::matmul_with` row blocks, `AggregationPlan` gathers,
//!   bias/skip/ReLU, the Eq. 2 rescale) accumulate per row in an order
//!   independent of which rows share the call;
//! * the shard builder preserves the global per-destination edge order
//!   (real CSR edges, then the self-loop) and bit-copies the edge weights;
//! * mirror rows are bit-copies of the global activations, quantized with
//!   the row's *global* per-node `(step, bits)` (the same
//!   `incremental::quantize_row` expressions the frontier patcher uses).
//!
//! The integer path additionally stores each shard's quantized hidden map
//! as a **per-shard packed slab** (`quant::pack::pack_rows_subset`) — the
//! at-rest layout a distributed deployment would ship between machines —
//! and streams the i32 matmul straight off it, exactly like the
//! single-shard path does off its full-graph slab.

use std::borrow::Cow;

use crate::graph::shard::{ShardLocal, ShardedGraph};
use crate::quant::mixed::NodeQuantParams;
use crate::quant::nns::NnsTable;
use crate::quant::{pack, uniform};
use crate::tensor::{dense::Matrix, ops};
use crate::util::threadpool::{self, ParallelConfig};

use super::incremental::quantize_row;
use super::infer::{model_uses_skip, nns_or_build};
use super::model::QuantMethod;
use super::prepared::PreparedModel;

/// Shard-parallel fp-emulation forward.  `features` is the full resident
/// `[N, in_dim]` feature matrix; returns the `[N, out]` logits, bitwise
/// identical to [`super::infer::forward_fp_prepared`] over the same graph
/// at any thread count.  Node-level gcn/gin sessions only.
pub fn forward_fp_sharded(
    prep: &PreparedModel,
    features: &[f32],
    graph: &ShardedGraph,
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    forward_sharded_impl(prep, features, graph, cfg, false, None)
}

/// [`forward_fp_sharded`] that also records every layer's global
/// activation matrix (`acts[0]` input, `acts[l]` layer `l` output) — the
/// same convention as `forward_fp_prepared_recording`, so a sharded
/// resident session can feed the incremental delta patcher.
pub fn forward_fp_sharded_recording(
    prep: &PreparedModel,
    features: &[f32],
    graph: &ShardedGraph,
    cfg: &ParallelConfig,
    acts: &mut Vec<Matrix<f32>>,
) -> Matrix<f32> {
    forward_sharded_impl(prep, features, graph, cfg, false, Some(acts))
}

/// Shard-parallel integer-path forward.  Falls back to the fp kernels for
/// sessions the integer path does not govern (non-A²Q methods), exactly
/// like [`super::infer::forward_int_prepared`].
pub fn forward_int_sharded(
    prep: &PreparedModel,
    features: &[f32],
    graph: &ShardedGraph,
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    forward_sharded_impl(prep, features, graph, cfg, prep.int_path_semantics(true), None)
}

/// Recording variant of [`forward_int_sharded`].
pub fn forward_int_sharded_recording(
    prep: &PreparedModel,
    features: &[f32],
    graph: &ShardedGraph,
    cfg: &ParallelConfig,
    acts: &mut Vec<Matrix<f32>>,
) -> Matrix<f32> {
    forward_sharded_impl(
        prep,
        features,
        graph,
        cfg,
        prep.int_path_semantics(true),
        Some(acts),
    )
}

fn forward_sharded_impl(
    prep: &PreparedModel,
    features: &[f32],
    graph: &ShardedGraph,
    cfg: &ParallelConfig,
    int_path: bool,
    mut record: Option<&mut Vec<Matrix<f32>>>,
) -> Matrix<f32> {
    let model = &prep.model;
    assert!(
        model.arch != "gat" && model.head.is_none() && model.node_level,
        "sharded forward supports node-level gcn/gin sessions"
    );
    let n = graph.num_nodes;
    let mut h = Matrix::from_vec(n, model.in_dim, features.to_vec()).expect("feature shape");
    if let Some(r) = record.as_deref_mut() {
        r.clear();
        r.push(h.clone());
    }
    let n_layers = model.layers.len();
    // shard fan-out is the parallelism; parallel_map clamps to the shard
    // count, and per-row determinism makes the thread count invisible
    let threads = cfg.threads.max(1);
    for l in 0..n_layers {
        let last = l == n_layers - 1;
        // shard-parallel: each shard gathers its mirror (halo exchange),
        // computes its owned rows, and hands the block back
        let blocks: Vec<Matrix<f32>> =
            threadpool::parallel_map(graph.num_shards(), threads, |s| {
                shard_layer(prep, l, last, &h, &graph.shards[s], int_path, cfg.simd)
            });
        // scatter: every global row has exactly one owner
        let d_out = blocks[0].cols;
        let mut h_next = Matrix::zeros(n, d_out);
        for (sh, block) in graph.shards.iter().zip(&blocks) {
            for (li, &gid) in sh.owned.iter().enumerate() {
                h_next.row_mut(gid as usize).copy_from_slice(block.row(li));
            }
        }
        h = h_next;
        if let Some(r) = record.as_deref_mut() {
            r.push(h.clone());
        }
    }
    h
}

/// Quantize a mirror (or hidden) block row-by-row with each row's
/// **global** per-node parameters — the row mirror of
/// `infer::quantize_features` over a gathered block whose local row `li`
/// holds global node `gids(li)`.
fn quantize_block(
    prep: &PreparedModel,
    layer: usize,
    p: Option<&NodeQuantParams>,
    prepared_nns: Option<&NnsTable>,
    block: &mut Matrix<f32>,
    n_global: usize,
    gids: impl Fn(usize) -> usize,
) {
    let model = &prep.model;
    let per_node = p.map(|p| p.len() == n_global).unwrap_or(false);
    let table: Option<Cow<NnsTable>> = match (p, per_node, model.method) {
        (Some(p), false, QuantMethod::A2q) => Some(nns_or_build(prepared_nns, p)),
        _ => None,
    };
    for li in 0..block.rows {
        let gid = gids(li);
        quantize_row(
            model,
            layer,
            p,
            per_node,
            table.as_deref(),
            block.row_mut(li),
            gid,
        );
    }
}

/// Global id of mirror-local row `li` of a shard.
fn mirror_gid(sh: &ShardLocal, li: usize) -> usize {
    if li < sh.owned.len() {
        sh.owned[li] as usize
    } else {
        sh.halo[li - sh.owned.len()] as usize
    }
}

/// One layer of one shard: gather → quantize → aggregate → transform,
/// returning the owned output block (rows in `sh.owned` order).  All
/// kernels run serially inside the shard — the shard fan-out *is* the
/// parallelism — and replicate the single-shard op sequence per row.
/// `simd` is the caller's kernel dispatch, threaded into the per-shard
/// serial budget so an ISA forced at the top level governs shard kernels
/// too (threading and ISA stay orthogonal).
#[allow(clippy::too_many_arguments)]
fn shard_layer(
    prep: &PreparedModel,
    l: usize,
    last: bool,
    h: &Matrix<f32>,
    sh: &ShardLocal,
    int_path: bool,
    simd: crate::tensor::Isa,
) -> Matrix<f32> {
    let model = &prep.model;
    let lay = &model.layers[l];
    let pl = &prep.layers[l];
    let serial = ParallelConfig::serial().with_simd(simd);
    let skip_q = l == 0 && model.skip_input_quant;
    let n_own = sh.owned.len();
    let n_global = h.rows;
    let cols = h.cols;

    // halo exchange: bit-copy owned + halo rows of the global activations
    let mut hq = Matrix {
        rows: sh.mirror_rows(),
        cols,
        data: sh.gather_mirror(&h.data, cols),
    };
    if !skip_q {
        quantize_block(prep, l, lay.feat.as_ref(), pl.nns.as_ref(), &mut hq, n_global, |li| {
            mirror_gid(sh, li)
        });
    }

    let mut out = match model.arch.as_str() {
        "gcn" => {
            let wq = pl.wq.as_ref().expect("gcn weight");
            let agg = Matrix {
                rows: n_own,
                cols,
                data: sh.plan.aggregate_with(&hq.data, cols, &sh.src, &sh.gcn_w, &serial),
            };
            let mut out = ops::matmul_with(&agg, wq, &serial);
            ops::add_bias(&mut out, &lay.b);
            out
        }
        "gin" => {
            let w1q = pl.wq.as_ref().expect("gin w1");
            let neigh = sh.plan.aggregate_with(&hq.data, cols, &sh.src, &sh.sum_w, &serial);
            // (1 + eps)·own + neighbour sum, over the owned mirror block
            let mut agg = Matrix {
                rows: n_own,
                cols,
                data: hq.data[..n_own * cols].to_vec(),
            };
            for (a, nv) in agg.data.iter_mut().zip(&neigh) {
                *a = (1.0 + lay.eps) * *a + nv;
            }
            let mut hid = ops::matmul_with(&agg, w1q, &serial);
            ops::add_bias(&mut hid, &lay.b);
            ops::relu_inplace(&mut hid);

            if int_path {
                // true integer hidden-map matmul off the shard's packed
                // slab, through the session-cached weight-code panel and
                // the same bucketed per-bitwidth kernels as the
                // single-shard path
                let panel = pl.w2_panel.as_ref().expect("gin w2 codes");
                let mut out = match lay.feat2.as_ref() {
                    None => {
                        // unquantized hidden map: unit-step codes (the
                        // forward_int `feat.is_none()` branch)
                        let codes: Vec<i32> =
                            hid.data.iter().map(|&v| v as i32).collect();
                        let a = Matrix::from_vec(hid.rows, hid.cols, codes).unwrap();
                        let acc = ops::matmul_codes_with(&a, panel, &serial);
                        ops::rescale_outer(&acc, &vec![1.0f32; hid.rows], &pl.w2_steps_clamped)
                    }
                    Some(p) => {
                        let slab = pack_shard_hidden(p, pl.nns2.as_ref(), sh, &hid, n_global);
                        let acc = slab.matmul_panel(panel, &serial);
                        ops::rescale_outer(&acc, slab.steps(), &pl.w2_steps_clamped)
                    }
                };
                ops::add_bias(&mut out, &lay.b2);
                out
            } else {
                let w2q = pl.w2q.as_ref().expect("gin w2");
                if model.method != QuantMethod::Fp32 {
                    quantize_block(
                        prep,
                        l,
                        lay.feat2.as_ref(),
                        pl.nns2.as_ref(),
                        &mut hid,
                        n_global,
                        |li| sh.owned[li] as usize,
                    );
                }
                let mut out = ops::matmul_with(&hid, w2q, &serial);
                ops::add_bias(&mut out, &lay.b2);
                out
            }
        }
        other => panic!("sharded forward unsupported for arch {other}"),
    };
    // shared epilogue, mirroring the single-shard tail: skip connection
    // (fp only — the int path never takes it) then ReLU on every
    // non-final layer; the final layer of a node-level model is the
    // logits and gets neither
    if !last {
        if !int_path && model_uses_skip(model) && out.cols == cols {
            for li in 0..n_own {
                let orow = out.row_mut(li);
                for (o, v) in orow.iter_mut().zip(&hq.data[li * cols..(li + 1) * cols]) {
                    *o += *v;
                }
            }
        }
        ops::relu_inplace(&mut out);
    }
    out
}

/// Quantize a shard's owned hidden rows to codes and pack them as the
/// shard's slab.  Per-node parameters are indexed by the rows' global
/// ids ([`pack::pack_rows_subset`]); grouped parameters run the per-row
/// NNS lookup — both identical to the single-shard `forward_int` `mm`.
fn pack_shard_hidden(
    p: &NodeQuantParams,
    prepared_nns: Option<&NnsTable>,
    sh: &ShardLocal,
    hid: &Matrix<f32>,
    n_global: usize,
) -> pack::PackedFeatures {
    let f = hid.cols;
    let mut codes = vec![0i32; hid.rows * f];
    if p.len() == n_global {
        for (li, &gid) in sh.owned.iter().enumerate() {
            let (s, b) = (p.steps[gid as usize], p.bits[gid as usize]);
            for (c, &v) in codes[li * f..(li + 1) * f].iter_mut().zip(hid.row(li)) {
                *c = uniform::quantize_value(v, s, b, p.signed);
            }
        }
        pack::pack_rows_subset(&codes, &p.steps, &p.bits, &sh.owned, f, p.signed)
    } else {
        let table = nns_or_build(prepared_nns, p);
        let mut steps = vec![0.0f32; hid.rows];
        let mut bits = vec![0u8; hid.rows];
        for li in 0..hid.rows {
            let row = hid.row(li);
            let fmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let (_, s, b) = table.select(fmax);
            steps[li] = s;
            bits[li] = b;
            for (c, &v) in codes[li * f..(li + 1) * f].iter_mut().zip(row) {
                *c = uniform::quantize_value(v, s, b, p.signed);
            }
        }
        pack::pack_rows(&codes, &steps, &bits, f, p.signed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::infer::{forward_fp_prepared, forward_int_prepared, GraphInput};
    use crate::gnn::model::{GnnModel, LayerParams};
    use crate::graph::norm::EdgeForm;
    use crate::util::json::Json;
    use crate::util::prop::{property, Gen};
    use crate::util::rng::Rng;

    fn random_model(g: &mut Gen, arch: &str, n: usize, in_dim: usize, hidden: usize) -> GnnModel {
        let n_layers = g.usize_range(1, 4);
        let mut layers = Vec::new();
        for l in 0..n_layers {
            let d_in = if l == 0 { in_dim } else { hidden };
            let feat = NodeQuantParams::new(
                g.vec_uniform(n, 0.02, 0.1),
                (0..n).map(|_| g.usize_range(2, 9) as u8).collect(),
                l == 0,
            )
            .unwrap();
            let lay = match arch {
                "gcn" => LayerParams {
                    w: Some(
                        Matrix::from_vec(d_in, hidden, g.vec_normal(d_in * hidden, 0.5)).unwrap(),
                    ),
                    b: g.vec_uniform(hidden, -0.1, 0.1),
                    w_steps: g.vec_uniform(hidden, 0.02, 0.08),
                    feat: Some(feat),
                    ..Default::default()
                },
                _ => LayerParams {
                    w: Some(
                        Matrix::from_vec(d_in, hidden, g.vec_normal(d_in * hidden, 0.5)).unwrap(),
                    ),
                    b: g.vec_uniform(hidden, -0.1, 0.1),
                    w_steps: g.vec_uniform(hidden, 0.02, 0.08),
                    w2: Some(
                        Matrix::from_vec(hidden, hidden, g.vec_normal(hidden * hidden, 0.5))
                            .unwrap(),
                    ),
                    b2: g.vec_uniform(hidden, -0.1, 0.1),
                    w2_steps: g.vec_uniform(hidden, 0.02, 0.08),
                    eps: g.f32_range(0.0, 0.2),
                    feat: Some(feat),
                    feat2: Some(
                        NodeQuantParams::new(
                            g.vec_uniform(n, 0.02, 0.1),
                            (0..n).map(|_| g.usize_range(2, 9) as u8).collect(),
                            false,
                        )
                        .unwrap(),
                    ),
                    ..Default::default()
                },
            };
            layers.push(lay);
        }
        GnnModel {
            name: format!("shard-{arch}"),
            arch: arch.into(),
            dataset: "unit".into(),
            method: QuantMethod::A2q,
            layers,
            head: None,
            dq_steps: vec![],
            skip_input_quant: false,
            node_level: true,
            num_nodes: n,
            in_dim,
            out_dim: hidden,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        }
    }

    /// The module-level bitwise anchor (the full matrix runs in
    /// `rust/tests/shard_parity.rs`): fp and int sharded forwards at
    /// several shard counts reproduce the single-shard prepared path
    /// exactly, and recording captures the same per-layer matrices.
    #[test]
    fn sharded_forward_bitwise_matches_prepared() {
        property("sharded == single-shard (fp/int)", 8, |g: &mut Gen| {
            let n = g.usize_range(8, 60);
            let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
            let csr = crate::graph::generate::preferential_attachment(&mut rng, n, 2);
            let ef = EdgeForm::from_csr(&csr);
            let in_dim = g.usize_range(2, 6);
            let hidden = g.usize_range(2, 8);
            let x = g.vec_normal(n * in_dim, 0.5);
            let cfg = ParallelConfig {
                threads: g.usize_range(1, 5),
                min_rows_per_task: 1,
                ..ParallelConfig::serial()
            };
            for arch in ["gcn", "gin"] {
                let model = random_model(g, arch, n, in_dim, hidden);
                let prep = PreparedModel::prepare(model).unwrap();
                let input = GraphInput::node_level(&x, in_dim, &ef);
                let want_fp = forward_fp_prepared(&prep, &input, &ParallelConfig::serial());
                let want_int = forward_int_prepared(&prep, &input, &ParallelConfig::serial());
                for s in [1usize, 2, 4] {
                    let sg = ShardedGraph::build(&csr, &ef, s).unwrap();
                    let got_fp = forward_fp_sharded(&prep, &x, &sg, &cfg);
                    assert_eq!(want_fp.data, got_fp.data, "{arch} S={s} fp diverged");
                    let mut acts = Vec::new();
                    let got_int =
                        forward_int_sharded_recording(&prep, &x, &sg, &cfg, &mut acts);
                    assert_eq!(want_int.data, got_int.data, "{arch} S={s} int diverged");
                    assert_eq!(acts.len(), prep.model.layers.len() + 1);
                    assert_eq!(acts[0].data, x, "acts[0] is the raw input");
                    assert_eq!(
                        acts.last().unwrap().data,
                        got_int.data,
                        "acts[L] is the logits"
                    );
                }
            }
        });
    }
}
