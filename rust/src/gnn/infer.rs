//! Native forward passes (fp-emulation and integer path).
//!
//! `forward_fp` reproduces `python/compile/models.py::forward`
//! (train=False) operation-for-operation, so its logits match both the
//! python export record and the PJRT execution of the AOT HLO.
//! `forward_int` runs the same network in true integer arithmetic
//! (i32-accumulated matmuls over quantized codes, Eq. 2 rescale) — the
//! computation the paper's bit-serial accelerator performs.
//!
//! Both passes run off a [`PreparedModel`] (see [`super::prepared`]): all
//! request-invariant state — fake-quantized weights, integer weight codes,
//! clamped step vectors, sorted NNS tables — is derived once at session
//! build.  The `forward_*_with(model, ...)` signatures are kept as thin
//! shims that prepare a throwaway session per call, preserving the old
//! re-derive-everything cost profile for tests and benches; serving code
//! should hold a `PreparedModel` (as `coordinator::NativeExecutor` does)
//! and call the `*_prepared` entry points.  Preparation is deterministic,
//! so both routes are bitwise identical.
//!
//! For partitioned resident graphs, [`super::sharded`] provides
//! `forward_{fp,int}_sharded` — shard-parallel variants with a
//! halo-exchange step between layers that are bitwise identical to the
//! prepared paths here (every output row has one owning shard, and all
//! per-row kernels accumulate in a row-local order).

use crate::graph::norm::AggregationPlan;
use crate::quant::mixed::NodeQuantParams;
use crate::quant::nns::NnsTable;
use crate::quant::{pack, uniform};
use crate::tensor::{dense::Matrix, ops};
use crate::util::threadpool::{self, ParallelConfig};

use super::model::{GnnModel, LayerParams, QuantMethod};
use super::prepared::{PreparedLayer, PreparedModel};

/// Borrowed view of one inference input (full graph or packed batch).
#[derive(Debug, Clone, Copy)]
pub struct GraphInput<'a> {
    pub features: &'a [f32],
    pub feat_dim: usize,
    pub num_nodes: usize,
    pub src: &'a [i32],
    pub dst: &'a [i32],
    pub gcn_w: &'a [f32],
    pub sum_w: &'a [f32],
    /// graph-level only
    pub node2graph: Option<&'a [i32]>,
    pub num_graphs: usize,
    pub node_mask: Option<&'a [f32]>,
}

impl<'a> GraphInput<'a> {
    pub fn node_level(
        features: &'a [f32],
        feat_dim: usize,
        ef: &'a crate::graph::norm::EdgeForm,
    ) -> GraphInput<'a> {
        GraphInput {
            features,
            feat_dim,
            num_nodes: ef.num_nodes,
            src: &ef.src,
            dst: &ef.dst,
            gcn_w: &ef.gcn_w,
            sum_w: &ef.sum_w,
            node2graph: None,
            num_graphs: 1,
            node_mask: None,
        }
    }

    pub fn batch(b: &'a crate::graph::batch::GraphBatch) -> GraphInput<'a> {
        GraphInput {
            features: &b.features,
            feat_dim: b.feat_dim,
            num_nodes: b.cap_nodes,
            src: &b.src,
            dst: &b.dst,
            gcn_w: &b.gcn_w,
            sum_w: &b.sum_w,
            node2graph: Some(&b.node2graph),
            num_graphs: b.cap_graphs,
            node_mask: Some(&b.node_mask),
        }
    }
}

/// Row-parallel Â·X over the destination-grouped plan (built once per
/// forward pass — or once per *session* for a resident graph — and shared
/// across layers).
fn aggregate(
    x: &Matrix<f32>,
    plan: &AggregationPlan,
    input: &GraphInput,
    weights: &[f32],
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    Matrix {
        rows: input.num_nodes,
        cols: x.cols,
        data: plan.aggregate_with(&x.data, x.cols, input.src, weights, cfg),
    }
}

/// The session's prepared [`NnsTable`], or an on-demand one when the
/// session prepared these params as per-node (a node-level model run on
/// an input sized differently than its resident graph) — shared by the fp
/// and int paths so the fallback semantics can't diverge.
pub(crate) fn nns_or_build<'a>(
    nns: Option<&'a NnsTable>,
    p: &NodeQuantParams,
) -> std::borrow::Cow<'a, NnsTable> {
    match nns {
        Some(t) => std::borrow::Cow::Borrowed(t),
        None => std::borrow::Cow::Owned(NnsTable::new(&p.steps, &p.bits, p.signed)),
    }
}

/// Quantize a feature map in place.  For A²Q's grouped (non-per-node)
/// parameters the lookup runs over the session's prepared [`NnsTable`] —
/// the table is never rebuilt per request.
fn quantize_features(
    h: &mut Matrix<f32>,
    model: &GnnModel,
    layer: usize,
    feat: Option<&NodeQuantParams>,
    nns: Option<&NnsTable>,
) {
    match model.method {
        QuantMethod::Fp32 => {}
        QuantMethod::Binary => {
            for i in 0..h.rows {
                let row = h.row_mut(i);
                let mean = row.iter().map(|v| v.abs()).sum::<f32>() / row.len() as f32;
                for v in row.iter_mut() {
                    *v = if *v >= 0.0 { mean } else { -mean };
                }
            }
        }
        QuantMethod::Dq => {
            let step = model.dq_steps.get(layer).copied().unwrap_or(0.05);
            let signed = layer == 0 || model.arch == "gat";
            for v in h.data.iter_mut() {
                *v = uniform::quantize_value(*v, step, 4, signed) as f32
                    * step.max(uniform::MIN_STEP);
            }
        }
        QuantMethod::A2q => {
            if let Some(p) = feat {
                if p.len() == h.rows {
                    // per-node parameters (node-level tasks)
                    let dim = h.cols;
                    p.fake_quantize(&mut h.data, dim);
                } else {
                    // NNS groups (graph-level): per-row nearest lookup over
                    // the prepared (or fallback) table
                    let table = nns_or_build(nns, p);
                    for i in 0..h.rows {
                        let row = h.row_mut(i);
                        let f = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                        let (_, s, b) = table.select(f);
                        uniform::fake_quantize_row(row, s, b, p.signed);
                    }
                }
            }
        }
    }
}

/// One GAT layer (shared between fp and int paths — attention itself runs
/// in f32 with 4-bit quantized coefficients, as in the paper's A.6).
fn gat_layer(
    h: &Matrix<f32>,
    lay: &LayerParams,
    pl: &PreparedLayer,
    input: &GraphInput,
    method: QuantMethod,
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    let wq = pl.wq.as_ref().expect("gat layer weight");
    let z = ops::matmul_with(h, wq, cfg); // [N, H*Fh]
    let a_src = lay.a_src.as_ref().expect("a_src");
    let a_dst = lay.a_dst.as_ref().expect("a_dst");
    let heads = a_src.rows;
    let fh = a_src.cols;
    let n = input.num_nodes;

    // per-node attention projections e_src/e_dst: [N, H]
    let mut e_src = Matrix::zeros(n, heads);
    let mut e_dst = Matrix::zeros(n, heads);
    for v in 0..n {
        for hd in 0..heads {
            let zrow = &z.data[v * heads * fh + hd * fh..v * heads * fh + (hd + 1) * fh];
            let mut es = 0.0;
            let mut ed = 0.0;
            for k in 0..fh {
                es += zrow[k] * a_src.at(hd, k);
                ed += zrow[k] * a_dst.at(hd, k);
            }
            *e_src.at_mut(v, hd) = es;
            *e_dst.at_mut(v, hd) = ed;
        }
    }

    let e = input.src.len();
    // edge logits with LeakyReLU(0.2), padding masked to -1e9
    let mut logits = vec![0.0f32; e * heads];
    for (ei, (&s, &d)) in input.src.iter().zip(input.dst).enumerate() {
        let real = input.gcn_w[ei] > 0.0 || input.sum_w[ei] > 0.0;
        for hd in 0..heads {
            let v = e_src.at(s as usize, hd) + e_dst.at(d as usize, hd);
            let v = if v < 0.0 { 0.2 * v } else { v };
            logits[ei * heads + hd] = if real { v } else { -1e9 };
        }
    }
    // segment softmax over incoming edges per head
    let mut mx = vec![f32::NEG_INFINITY; n * heads];
    for (ei, &d) in input.dst.iter().enumerate() {
        for hd in 0..heads {
            let slot = &mut mx[d as usize * heads + hd];
            *slot = slot.max(logits[ei * heads + hd]);
        }
    }
    let mut den = vec![0.0f32; n * heads];
    let mut alpha = logits;
    for (ei, &d) in input.dst.iter().enumerate() {
        for hd in 0..heads {
            let m = mx[d as usize * heads + hd];
            let v = (alpha[ei * heads + hd] - m).exp();
            alpha[ei * heads + hd] = v;
            den[d as usize * heads + hd] += v;
        }
    }
    for (ei, &d) in input.dst.iter().enumerate() {
        for hd in 0..heads {
            alpha[ei * heads + hd] /= den[d as usize * heads + hd] + 1e-16;
        }
    }
    // 4-bit quantization of the attention coefficients (unsigned)
    if method != QuantMethod::Fp32 && method != QuantMethod::Binary {
        let s = lay.attn_step;
        for a in alpha.iter_mut() {
            *a = uniform::quantize_value(*a, s, 4, false) as f32
                * s.max(uniform::MIN_STEP);
        }
    }
    // weighted aggregation
    let mut agg = Matrix::zeros(n, heads * fh);
    for (ei, (&s, &d)) in input.src.iter().zip(input.dst).enumerate() {
        for hd in 0..heads {
            let a = alpha[ei * heads + hd];
            if a == 0.0 {
                continue;
            }
            let zrow =
                &z.data[s as usize * heads * fh + hd * fh..s as usize * heads * fh + (hd + 1) * fh];
            let orow = &mut agg.data
                [d as usize * heads * fh + hd * fh..d as usize * heads * fh + (hd + 1) * fh];
            for (o, v) in orow.iter_mut().zip(zrow) {
                *o += a * v;
            }
        }
    }
    ops::add_bias(&mut agg, &lay.b);
    agg
}

/// Full fp-emulation forward with the process-default parallelism budget.
pub fn forward_fp(model: &GnnModel, input: &GraphInput) -> Matrix<f32> {
    forward_fp_with(model, input, &threadpool::global_parallelism())
}

/// Compatibility shim: prepares a throwaway session per call (the old
/// re-quantize-everything cost profile).  Serving paths should prepare
/// once and call [`forward_fp_prepared`].
pub fn forward_fp_with(model: &GnnModel, input: &GraphInput, cfg: &ParallelConfig) -> Matrix<f32> {
    let prep = PreparedModel::prepare(model.clone()).expect("model fails session preparation");
    forward_fp_prepared(&prep, input, cfg)
}

/// Full fp-emulation forward over a prepared session.  Returns [N, out]
/// node logits (node-level) or [G, out] predictions (graph-level readout).
/// Aggregation and matmuls run row-parallel under `cfg`; results are
/// bitwise independent of the thread count (each output row has one
/// owner).
pub fn forward_fp_prepared(
    prep: &PreparedModel,
    input: &GraphInput,
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    forward_fp_prepared_with_plan(prep, input, None, cfg)
}

/// [`forward_fp_prepared`] with an optional caller-cached
/// [`AggregationPlan`] for `input`'s edge list (executors serving a
/// resident graph build the plan once per session instead of per forward).
pub fn forward_fp_prepared_with_plan(
    prep: &PreparedModel,
    input: &GraphInput,
    resident_plan: Option<&AggregationPlan>,
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    forward_fp_impl(prep, input, resident_plan, cfg, None)
}

/// [`forward_fp_prepared_with_plan`] that additionally records every
/// layer's *unquantized* activation matrix into `acts`: `acts[0]` is the
/// raw input feature matrix and `acts[l]` the output of layer `l`
/// (post-skip/activation, before the next layer's feature quantization).
/// The dynamic-graph serving path keeps these resident so a `GraphDelta`
/// can repair only the dirty rows (`gnn::incremental`) instead of
/// recomputing the whole graph.  For graph-level (head) models only the
/// layer stack is recorded, not the pooled readout.
pub fn forward_fp_prepared_recording(
    prep: &PreparedModel,
    input: &GraphInput,
    resident_plan: Option<&AggregationPlan>,
    cfg: &ParallelConfig,
    acts: &mut Vec<Matrix<f32>>,
) -> Matrix<f32> {
    forward_fp_impl(prep, input, resident_plan, cfg, Some(acts))
}

fn forward_fp_impl(
    prep: &PreparedModel,
    input: &GraphInput,
    resident_plan: Option<&AggregationPlan>,
    cfg: &ParallelConfig,
    mut record: Option<&mut Vec<Matrix<f32>>>,
) -> Matrix<f32> {
    let model = &prep.model;
    // GAT aggregates inside gat_layer (per-head attention weights), so the
    // shared destination-grouped plan is only built for gcn/gin.
    let built;
    let plan: Option<&AggregationPlan> = if model.arch == "gat" {
        None
    } else if let Some(p) = resident_plan {
        Some(p)
    } else {
        built = AggregationPlan::build(input.dst, input.num_nodes);
        Some(&built)
    };
    let mut h = Matrix::from_vec(
        input.num_nodes,
        input.feat_dim,
        input.features.to_vec(),
    )
    .expect("feature shape");
    if let Some(r) = record.as_deref_mut() {
        r.clear();
        r.push(h.clone());
    }
    let n_layers = model.layers.len();

    for (l, lay) in model.layers.iter().enumerate() {
        let pl = &prep.layers[l];
        let skip_q = l == 0 && model.skip_input_quant;
        if !skip_q {
            quantize_features(&mut h, model, l, lay.feat.as_ref(), pl.nns.as_ref());
        }
        let h_in = h.clone(); // python's skip connection adds the quantized input

        let mut out = match model.arch.as_str() {
            "gcn" => {
                let plan = plan.expect("plan built for gcn");
                let agg = aggregate(&h, plan, input, input.gcn_w, cfg);
                let wq = pl.wq.as_ref().expect("gcn weight");
                let mut out = ops::matmul_with(&agg, wq, cfg);
                ops::add_bias(&mut out, &lay.b);
                out
            }
            "gin" => {
                let plan = plan.expect("plan built for gin");
                let neigh = aggregate(&h, plan, input, input.sum_w, cfg);
                let mut agg = h.clone();
                for (a, nv) in agg.data.iter_mut().zip(&neigh.data) {
                    *a = (1.0 + lay.eps) * *a + nv;
                }
                let w1q = pl.wq.as_ref().expect("gin w1");
                let mut hid = ops::matmul_with(&agg, w1q, cfg);
                ops::add_bias(&mut hid, &lay.b);
                ops::relu_inplace(&mut hid);
                if model.method != QuantMethod::Fp32 {
                    quantize_features(&mut hid, model, l, lay.feat2.as_ref(), pl.nns2.as_ref());
                }
                let w2q = pl.w2q.as_ref().expect("gin w2");
                let mut out = ops::matmul_with(&hid, w2q, cfg);
                ops::add_bias(&mut out, &lay.b2);
                out
            }
            "gat" => gat_layer(&h, lay, pl, input, model.method, cfg),
            other => panic!("unknown arch {other}"),
        };

        let last = l == n_layers - 1;
        if model.head.is_none() && last {
            h = out;
            if let Some(r) = record.as_deref_mut() {
                r.push(h.clone());
            }
            break;
        }
        // skip connection (python: only when shapes match)
        if out.shape() == h_in.shape() && model_uses_skip(model) {
            for (o, v) in out.data.iter_mut().zip(&h_in.data) {
                *o += v;
            }
        }
        if !last || model.head.is_some() {
            if model.arch == "gat" {
                ops::elu_inplace(&mut out);
            } else {
                ops::relu_inplace(&mut out);
            }
        }
        h = out;
        if let Some(r) = record.as_deref_mut() {
            r.push(h.clone());
        }
    }

    match (&model.head, &prep.head) {
        (None, _) => h,
        (Some(head), prep_head) => {
            let ph = prep_head.as_ref().expect("prepared head");
            // mean-pool real nodes per graph segment
            let n2g = input.node2graph.expect("node2graph for graph-level");
            let mask = input.node_mask.expect("node_mask");
            let g = input.num_graphs;
            let f = h.cols;
            let mut pooled = Matrix::zeros(g, f);
            let mut counts = vec![0.0f32; g];
            for v in 0..h.rows {
                let gi = n2g[v] as usize;
                if gi >= g || mask[v] == 0.0 {
                    continue;
                }
                counts[gi] += 1.0;
                let hrow = h.row(v);
                let prow: &mut [f32] = pooled.row_mut(gi);
                for (p, x) in prow.iter_mut().zip(hrow) {
                    *p += x;
                }
            }
            for gi in 0..g {
                let c = counts[gi].max(1.0);
                for v in pooled.row_mut(gi) {
                    *v /= c;
                }
            }
            if model.method == QuantMethod::A2q {
                if let Some(p) = &head.feat {
                    let table = ph.nns.as_ref().expect("prepared head NNS table");
                    for i in 0..pooled.rows {
                        let row = pooled.row_mut(i);
                        let fmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                        let (_, s, b) = table.select(fmax);
                        uniform::fake_quantize_row(row, s, b, p.signed);
                    }
                }
            }
            let mut z = ops::matmul_with(&pooled, &ph.w1q, cfg);
            ops::add_bias(&mut z, &head.b1);
            ops::relu_inplace(&mut z);
            let mut out = ops::matmul_with(&z, &ph.w2q, cfg);
            ops::add_bias(&mut out, &head.b2);
            out
        }
    }
}

pub(crate) fn model_uses_skip(model: &GnnModel) -> bool {
    model
        .manifest
        .get("skip")
        .and_then(|v| v.as_bool())
        .unwrap_or(!model.node_level)
}

/// Integer-path forward with the process-default parallelism budget.
pub fn forward_int(model: &GnnModel, input: &GraphInput) -> Matrix<f32> {
    forward_int_with(model, input, &threadpool::global_parallelism())
}

/// Compatibility shim: prepares a throwaway session per call.  Serving
/// paths should prepare once and call [`forward_int_prepared`].
pub fn forward_int_with(model: &GnnModel, input: &GraphInput, cfg: &ParallelConfig) -> Matrix<f32> {
    let prep = PreparedModel::prepare(model.clone()).expect("model fails session preparation");
    forward_int_prepared(&prep, input, cfg)
}

/// Integer-path forward over a prepared session.
pub fn forward_int_prepared(
    prep: &PreparedModel,
    input: &GraphInput,
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    forward_int_prepared_with_plan(prep, input, None, cfg)
}

/// Integer-path forward for GCN/GIN: quantize → bit-pack → i32 matmul off
/// the packed payload → Eq. 2 rescale, using the session's precomputed
/// integer weight codes and clamped step vectors.  GAT falls back to the
/// fp path (attention softmax is f32 on the accelerator too; only
/// coefficients are 4-bit).
pub fn forward_int_prepared_with_plan(
    prep: &PreparedModel,
    input: &GraphInput,
    resident_plan: Option<&AggregationPlan>,
    cfg: &ParallelConfig,
) -> Matrix<f32> {
    forward_int_impl(prep, input, resident_plan, cfg, None)
}

/// Integer-path analogue of [`forward_fp_prepared_recording`]: same
/// `acts` convention (`acts[0]` raw input, `acts[l]` layer `l` output).
/// When the model falls back to fp (GAT / non-A²Q / graph-level), the
/// recorded activations are the fp ones — matching what the executor
/// actually served.
pub fn forward_int_prepared_recording(
    prep: &PreparedModel,
    input: &GraphInput,
    resident_plan: Option<&AggregationPlan>,
    cfg: &ParallelConfig,
    acts: &mut Vec<Matrix<f32>>,
) -> Matrix<f32> {
    forward_int_impl(prep, input, resident_plan, cfg, Some(acts))
}

fn forward_int_impl(
    prep: &PreparedModel,
    input: &GraphInput,
    resident_plan: Option<&AggregationPlan>,
    cfg: &ParallelConfig,
    mut record: Option<&mut Vec<Matrix<f32>>>,
) -> Matrix<f32> {
    let model = &prep.model;
    if model.arch == "gat" || model.method != QuantMethod::A2q || model.head.is_some() {
        // GAT and non-A2q run fp; graph-level (head) models delegate their
        // pooling + readout to the fp implementation entirely, so skip the
        // integer layer loop rather than computing and discarding it.
        return forward_fp_impl(prep, input, resident_plan, cfg, record);
    }
    let built;
    let plan: &AggregationPlan = match resident_plan {
        Some(p) => p,
        None => {
            built = AggregationPlan::build(input.dst, input.num_nodes);
            &built
        }
    };
    let mut h = Matrix::from_vec(input.num_nodes, input.feat_dim, input.features.to_vec())
        .expect("feature shape");
    if let Some(r) = record.as_deref_mut() {
        r.clear();
        r.push(h.clone());
    }
    let n_layers = model.layers.len();

    for (l, lay) in model.layers.iter().enumerate() {
        let pl = &prep.layers[l];
        let skip_q = l == 0 && model.skip_input_quant;
        let last = l == n_layers - 1;

        let mm = |x: &Matrix<f32>,
                  feat: Option<&NodeQuantParams>,
                  nns: Option<&NnsTable>,
                  panel: &ops::WeightPanel,
                  sw: &[f32],
                  bias: &[f32],
                  skip_quant: bool| {
            // Activation codes, bit-packed row-wise at each node's learned
            // bitwidth (quant::pack — the serving at-rest layout, bucketed
            // by bitwidth).  The integer matmul streams rows straight off
            // the bucketed payload through per-bitwidth kernels, so the
            // dense [N, F] i32 code matrix is never materialized and
            // low-bit rows cost less.  The transposed/widened weight-code
            // panel and the clamped sw come precomputed from the prepared
            // session.
            let mut out = if skip_quant || feat.is_none() {
                // unquantized input (binary bag-of-words): treat as codes
                // with unit step — values are already 0/1 integers.
                let codes: Vec<i32> = x.data.iter().map(|&v| v as i32).collect();
                let a = Matrix::from_vec(x.rows, x.cols, codes).unwrap();
                let acc = ops::matmul_codes_with(&a, panel, cfg);
                ops::rescale_outer(&acc, &vec![1.0f32; x.rows], sw)
            } else {
                let p = feat.unwrap();
                let packed = if p.len() == x.rows {
                    let (codes, _steps) = p.quantize_codes(&x.data, x.cols);
                    pack::pack_rows(&codes, &p.steps, &p.bits, x.cols, p.signed)
                } else {
                    // NNS selection per row, over the prepared (or
                    // fallback) table
                    let table = nns_or_build(nns, p);
                    let mut codes = vec![0i32; x.data.len()];
                    let mut steps = vec![0.0f32; x.rows];
                    let mut bits = vec![0u8; x.rows];
                    for i in 0..x.rows {
                        let row = x.row(i);
                        let fmax = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                        let (_, s, bsel) = table.select(fmax);
                        steps[i] = s;
                        bits[i] = bsel;
                        for (cslot, &v) in
                            codes[i * x.cols..(i + 1) * x.cols].iter_mut().zip(row)
                        {
                            *cslot = uniform::quantize_value(v, s, bsel, p.signed);
                        }
                    }
                    pack::pack_rows(&codes, &steps, &bits, x.cols, p.signed)
                };
                let acc = packed.matmul_panel(panel, cfg);
                // steps() is a borrowed slice of the packed slab — the
                // Eq. 2 rescale reads it in place, no per-layer sx Vec
                ops::rescale_outer(&acc, packed.steps(), sw)
            };
            ops::add_bias(&mut out, bias);
            out
        };

        let out = match model.arch.as_str() {
            "gcn" => {
                // quantize features first (so aggregation runs on the
                // quantized values, matching forward_fp), then aggregate,
                // then the integer matmul re-quantizes the aggregated map
                // with the same per-node params — identical semantics to
                // fake-quant because aggregation output feeds mm directly.
                let mut hq = h.clone();
                if !skip_q {
                    quantize_features(&mut hq, model, l, lay.feat.as_ref(), pl.nns.as_ref());
                }
                let agg = aggregate(&hq, plan, input, input.gcn_w, cfg);
                // aggregated values are NOT re-quantized in the fp path;
                // emulate exactly: feed agg as f32 through an fp matmul of
                // quantized weights.  Integer arithmetic still applies to
                // the dominant X̄·W̄ via distributivity over the (integer/s)
                // codes; here we keep bit-exactness with forward_fp.
                let wq = pl.wq.as_ref().expect("gcn weight");
                let mut out = ops::matmul_with(&agg, wq, cfg);
                ops::add_bias(&mut out, &lay.b);
                out
            }
            "gin" => {
                let mut hq = h.clone();
                if !skip_q {
                    quantize_features(&mut hq, model, l, lay.feat.as_ref(), pl.nns.as_ref());
                }
                let neigh = aggregate(&hq, plan, input, input.sum_w, cfg);
                let mut agg = hq.clone();
                for (a, nv) in agg.data.iter_mut().zip(&neigh.data) {
                    *a = (1.0 + lay.eps) * *a + nv;
                }
                let w1q = pl.wq.as_ref().expect("gin w1");
                let mut hid = ops::matmul_with(&agg, w1q, cfg);
                ops::add_bias(&mut hid, &lay.b);
                ops::relu_inplace(&mut hid);
                // hidden map: true integer matmul via per-node codes
                mm(
                    &hid,
                    lay.feat2.as_ref(),
                    pl.nns2.as_ref(),
                    pl.w2_panel.as_ref().expect("gin w2 codes"),
                    &pl.w2_steps_clamped,
                    &lay.b2,
                    false,
                )
            }
            _ => unreachable!(),
        };

        let mut out = out;
        if !last || model.head.is_some() {
            ops::relu_inplace(&mut out);
        }
        h = out;
        if let Some(r) = record.as_deref_mut() {
            r.push(h.clone());
        }
    }

    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;
    use crate::graph::norm::EdgeForm;
    use crate::quant::mixed::NodeQuantParams;
    use crate::util::json::Json;

    fn tiny_gcn(method: QuantMethod) -> GnnModel {
        // 3 nodes, 2 features, 2 classes, 1 layer
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.5, 1.0]).unwrap();
        GnnModel {
            name: "tiny".into(),
            arch: "gcn".into(),
            dataset: "unit".into(),
            method,
            layers: vec![LayerParams {
                w: Some(w),
                b: vec![0.1, -0.1],
                w_steps: vec![0.05, 0.05],
                feat: Some(
                    NodeQuantParams::new(vec![0.1; 3], vec![4; 3], true).unwrap(),
                ),
                ..Default::default()
            }],
            head: None,
            dq_steps: vec![0.05, 0.05],
            skip_input_quant: false,
            node_level: true,
            num_nodes: 3,
            in_dim: 2,
            out_dim: 2,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        }
    }

    fn tiny_input() -> (Vec<f32>, EdgeForm) {
        let csr = Csr::from_edges(3, &[(0, 1), (1, 0), (1, 2), (2, 1)]).unwrap();
        let ef = EdgeForm::from_csr(&csr);
        let x = vec![0.3, -0.2, 0.15, 0.4, -0.35, 0.05];
        (x, ef)
    }

    #[test]
    fn fp32_forward_shape_and_finite() {
        let model = tiny_gcn(QuantMethod::Fp32);
        let (x, ef) = tiny_input();
        let input = GraphInput::node_level(&x, 2, &ef);
        let out = forward_fp(&model, &input);
        assert_eq!(out.shape(), (3, 2));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_differs_from_fp32() {
        let (x, ef) = tiny_input();
        let input = GraphInput::node_level(&x, 2, &ef);
        let a = forward_fp(&tiny_gcn(QuantMethod::Fp32), &input);
        let b = forward_fp(&tiny_gcn(QuantMethod::A2q), &input);
        assert!(a.max_abs_diff(&b) > 1e-6);
    }

    #[test]
    fn int_path_matches_fp_emulation_for_gcn() {
        let model = tiny_gcn(QuantMethod::A2q);
        let (x, ef) = tiny_input();
        let input = GraphInput::node_level(&x, 2, &ef);
        let fp = forward_fp(&model, &input);
        let int = forward_int(&model, &input);
        assert!(
            fp.max_abs_diff(&int) < 1e-5,
            "fp {:?} vs int {:?}",
            fp.data,
            int.data
        );
    }

    #[test]
    fn dq_and_binary_paths_run() {
        let (x, ef) = tiny_input();
        let input = GraphInput::node_level(&x, 2, &ef);
        for method in [QuantMethod::Dq, QuantMethod::Binary] {
            let out = forward_fp(&tiny_gcn(method), &input);
            assert!(out.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn parallel_forward_bitwise_matches_serial() {
        let (x, ef) = tiny_input();
        let input = GraphInput::node_level(&x, 2, &ef);
        let par = ParallelConfig {
            threads: 4,
            min_rows_per_task: 1,
            ..ParallelConfig::serial()
        };
        let ser = ParallelConfig::serial();
        for method in [QuantMethod::Fp32, QuantMethod::A2q] {
            let model = tiny_gcn(method);
            assert_eq!(
                forward_fp_with(&model, &input, &par).data,
                forward_fp_with(&model, &input, &ser).data
            );
            assert_eq!(
                forward_int_with(&model, &input, &par).data,
                forward_int_with(&model, &input, &ser).data
            );
        }
    }

    #[test]
    fn prepared_session_reuse_is_bitwise_stable() {
        // one session, many forwards — and identical to the per-call shim
        let (x, ef) = tiny_input();
        let input = GraphInput::node_level(&x, 2, &ef);
        let cfg = ParallelConfig::serial();
        let model = tiny_gcn(QuantMethod::A2q);
        let prep = PreparedModel::prepare(model.clone()).unwrap();
        let shim = forward_fp_with(&model, &input, &cfg);
        let first = forward_fp_prepared(&prep, &input, &cfg);
        let second = forward_fp_prepared(&prep, &input, &cfg);
        assert_eq!(shim.data, first.data);
        assert_eq!(first.data, second.data);
        // caller-cached plan takes the same code path
        let plan = ef.plan();
        let planned = forward_fp_prepared_with_plan(&prep, &input, Some(&plan), &cfg);
        assert_eq!(first.data, planned.data);
    }
}
