//! Prepared inference sessions: derive all request-invariant model state
//! once, serve forever.
//!
//! A²Q's economics (and Degree-Quant's / SGQuant's — see PAPERS.md) hinge
//! on quantization being an *offline specialization* step: the learned
//! per-node bitwidths, the sorted NNS lookup table, and the quantized
//! weight matrices are all functions of the trained model alone, never of
//! a request.  Before this module existed the serving path re-derived all
//! of it per forward pass — every request re-fake-quantized every weight
//! matrix, re-computed integer weight codes, and re-sorted a fresh
//! [`NnsTable`] per layer.  [`PreparedModel::prepare`] hoists that work to
//! session-build time (one call when the model is loaded) and doubles as
//! the validation boundary: malformed static state (missing layer tensors,
//! step/column-count mismatches, empty or non-finite NNS tables) is
//! rejected here with a descriptive [`Error::artifact`] instead of
//! panicking inside a runner thread on the first request.
//!
//! The forward passes in [`super::infer`] run off `&PreparedModel`; the
//! old `forward_fp_with`/`forward_int_with` signatures survive as thin
//! shims that prepare a throwaway session per call (tests/benches).
//! Preparation is deterministic, so prepared and per-call-prepared
//! forwards are bitwise identical (property-tested in
//! `rust/tests/forward_parity.rs`).

use crate::error::{Error, Result};
use crate::quant::nns::NnsTable;
use crate::quant::uniform::{self, MIN_STEP};
use crate::tensor::dense::Matrix;
use crate::tensor::ops::WeightPanel;

use super::model::{GnnModel, QuantMethod};

/// Fake-quantize weights per output column at 4 bits (paper §3.1).
/// Request-invariant — [`PreparedModel::prepare`] calls this once per
/// weight matrix instead of once per forward pass.
pub(crate) fn quantize_weights(w: &Matrix<f32>, steps: &[f32], method: QuantMethod) -> Matrix<f32> {
    match method {
        QuantMethod::Fp32 => w.clone(),
        QuantMethod::Binary => {
            // per-column sign * mean|w| (Bi-GCN form, mirrors python)
            let mut out = w.clone();
            for j in 0..w.cols {
                let mut mean = 0.0f32;
                for i in 0..w.rows {
                    mean += w.at(i, j).abs();
                }
                mean /= w.rows as f32;
                for i in 0..w.rows {
                    let v = w.at(i, j);
                    *out.at_mut(i, j) = if v >= 0.0 { mean } else { -mean };
                }
            }
            out
        }
        _ => {
            assert_eq!(steps.len(), w.cols, "weight steps per output column");
            let mut out = w.clone();
            for i in 0..w.rows {
                for j in 0..w.cols {
                    let v = w.at(i, j);
                    *out.at_mut(i, j) =
                        uniform::quantize_value(v, steps[j], 4, true) as f32
                            * steps[j].max(MIN_STEP);
                }
            }
            out
        }
    }
}

/// Per-column 4-bit integer codes of a weight matrix (the `W̄` of the
/// Eq. 2 integer matmul).
fn weight_codes(w: &Matrix<f32>, steps: &[f32]) -> Matrix<i32> {
    let mut codes = vec![0i32; w.rows * w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            codes[i * w.cols + j] = uniform::quantize_value(w.at(i, j), steps[j], 4, true);
        }
    }
    Matrix::from_vec(w.rows, w.cols, codes).expect("weight code shape")
}

fn clamp_steps(steps: &[f32]) -> Vec<f32> {
    steps.iter().map(|s| s.max(MIN_STEP)).collect()
}

/// Validate a weight-step vector against its matrix before any quantizing
/// use (the old path hit an `assert_eq!` inside a runner thread instead).
/// Both checks apply only to methods whose weight quantization reads the
/// steps — Fp32/Binary artifacts may carry stale step tensors harmlessly.
fn check_wsteps(what: &str, w: &Matrix<f32>, steps: &[f32], method: QuantMethod) -> Result<()> {
    let needs_steps = !matches!(method, QuantMethod::Fp32 | QuantMethod::Binary);
    if !needs_steps {
        return Ok(());
    }
    if steps.len() != w.cols {
        return Err(Error::artifact(format!(
            "{what}: {} weight-quant steps for {} output columns",
            steps.len(),
            w.cols
        )));
    }
    if let Some(i) = steps.iter().position(|s| !s.is_finite()) {
        return Err(Error::artifact(format!(
            "{what}: non-finite weight-quant step {} at column {i}",
            steps[i]
        )));
    }
    Ok(())
}

/// Request-invariant state of one layer.
#[derive(Debug, Clone)]
pub struct PreparedLayer {
    /// fake-quantized `w` (GCN/GAT weight, GIN `w1`) — fp path and the
    /// GCN integer path (which keeps the aggregated map in f32, Proof 2)
    pub wq: Option<Matrix<f32>>,
    /// fake-quantized GIN `w2` (fp path)
    pub w2q: Option<Matrix<f32>>,
    /// integer codes of GIN `w2` as a k-major/widened [`WeightPanel`]
    /// (true integer path) — derived once here; the panel type freezes
    /// the layout contract every bucketed kernel streams
    pub w2_panel: Option<WeightPanel>,
    /// clamped per-output-column steps of `w2` (the Eq. 2 `sw`)
    pub w2_steps_clamped: Vec<f32>,
    /// sorted NNS lookup over the layer-input feature params (used when
    /// the params are per-group rather than per-node)
    pub nns: Option<NnsTable>,
    /// sorted NNS lookup over the GIN hidden-map params
    pub nns2: Option<NnsTable>,
}

/// Request-invariant state of the graph-level readout head.
#[derive(Debug, Clone)]
pub struct PreparedHead {
    pub w1q: Matrix<f32>,
    pub w2q: Matrix<f32>,
    pub nns: Option<NnsTable>,
}

/// A [`GnnModel`] plus everything derivable from it alone: quantized
/// weight matrices (f32 and integer codes), clamped step vectors, and
/// per-layer NNS tables.  Build once per loaded model, share across
/// requests (`&PreparedModel` is all the forward passes need).
///
/// The retained `model` has its raw layer weight tensors (`w`/`w2`)
/// released — the derived `wq`/`w2q`/`w2_panel` replace them — so a
/// session holds one resident copy of each weight, not two.  Re-preparing
/// from `prep.model` is therefore not supported; prepare from the loaded
/// model.
#[derive(Debug, Clone)]
pub struct PreparedModel {
    pub model: GnnModel,
    pub layers: Vec<PreparedLayer>,
    pub head: Option<PreparedHead>,
}

impl PreparedModel {
    /// Precompute all static inference state.  This is the model-load
    /// validation boundary: structural problems (missing tensors for the
    /// arch, malformed quant params) surface here as [`Error::artifact`]
    /// rather than as panics on the first served request.
    pub fn prepare(model: GnnModel) -> Result<PreparedModel> {
        let method = model.method;
        // integer path conditions (see forward_int): only GIN's hidden map
        // runs the true integer matmul today
        let int_gin = model.arch == "gin"
            && method == QuantMethod::A2q
            && model.head.is_none();
        let mut layers = Vec::with_capacity(model.layers.len());
        for (l, lay) in model.layers.iter().enumerate() {
            match model.arch.as_str() {
                "gcn" => {
                    if lay.w.is_none() {
                        return Err(Error::artifact(format!("gcn layer {l}: missing w")));
                    }
                }
                "gin" => {
                    if lay.w.is_none() || lay.w2.is_none() {
                        return Err(Error::artifact(format!(
                            "gin layer {l}: missing w1/w2"
                        )));
                    }
                }
                "gat" => {
                    if lay.w.is_none() || lay.a_src.is_none() || lay.a_dst.is_none() {
                        return Err(Error::artifact(format!(
                            "gat layer {l}: missing w/a_src/a_dst"
                        )));
                    }
                }
                other => {
                    return Err(Error::artifact(format!("unknown arch '{other}'")));
                }
            }
            let wq = match &lay.w {
                Some(w) => {
                    check_wsteps(&format!("layer {l} w"), w, &lay.w_steps, method)?;
                    Some(quantize_weights(w, &lay.w_steps, method))
                }
                None => None,
            };
            let w2q = match &lay.w2 {
                Some(w2) => {
                    check_wsteps(&format!("layer {l} w2"), w2, &lay.w2_steps, method)?;
                    Some(quantize_weights(w2, &lay.w2_steps, method))
                }
                None => None,
            };
            let (w2_panel, w2_steps_clamped) = match (&lay.w2, int_gin) {
                (Some(w2), true) => (
                    Some(WeightPanel::from_codes(weight_codes(w2, &lay.w2_steps))),
                    clamp_steps(&lay.w2_steps),
                ),
                _ => (None, Vec::new()),
            };
            // NNS tables are only consulted for *grouped* params (the
            // forward passes take the per-node branch whenever the param
            // count matches the resident node count), so skip the sort +
            // resident table for node-level per-node maps — for a large
            // resident graph that is O(n log n) load time and 12n bytes
            // per layer of dead weight.
            let grouped =
                |p: &crate::quant::mixed::NodeQuantParams| !(model.node_level && p.len() == model.num_nodes);
            let mut nns = None;
            let mut nns2 = None;
            if method == QuantMethod::A2q {
                if let Some(p) = &lay.feat {
                    if grouped(p) {
                        nns = Some(
                            NnsTable::try_new(&p.steps, &p.bits, p.signed)
                                .map_err(|e| Error::artifact(format!("layer {l} feat: {e}")))?,
                        );
                    }
                }
                if let Some(p) = &lay.feat2 {
                    if grouped(p) {
                        nns2 = Some(
                            NnsTable::try_new(&p.steps, &p.bits, p.signed)
                                .map_err(|e| Error::artifact(format!("layer {l} feat2: {e}")))?,
                        );
                    }
                }
            }
            layers.push(PreparedLayer {
                wq,
                w2q,
                w2_panel,
                w2_steps_clamped,
                nns,
                nns2,
            });
        }

        let head = match &model.head {
            None => None,
            Some(h) => {
                check_wsteps("head w1", &h.w1, &h.w1_steps, method)?;
                check_wsteps("head w2", &h.w2, &h.w2_steps, method)?;
                let nns = match (&h.feat, method) {
                    (Some(p), QuantMethod::A2q) => Some(
                        NnsTable::try_new(&p.steps, &p.bits, p.signed)
                            .map_err(|e| Error::artifact(format!("head feat: {e}")))?,
                    ),
                    _ => None,
                };
                Some(PreparedHead {
                    w1q: quantize_weights(&h.w1, &h.w1_steps, method),
                    w2q: quantize_weights(&h.w2, &h.w2_steps, method),
                    nns,
                })
            }
        };

        // The derived matrices/panels (wq/w2q/w2_panel) are the serving source of
        // truth from here on; release the raw layer weight tensors so a
        // prepared session doesn't keep two f32 copies of every weight
        // resident.  Everything the forwards still read from the model —
        // biases, eps, feat params, attention vectors, head tensors (whose
        // fields are not optional) — stays.
        let mut model = model;
        for lay in model.layers.iter_mut() {
            lay.w = None;
            lay.w2 = None;
        }

        Ok(PreparedModel {
            model,
            layers,
            head,
        })
    }

    pub fn model(&self) -> &GnnModel {
        &self.model
    }

    /// Whether the **true integer path** (packed codes, i32-accumulate,
    /// Eq. 2 rescale) governs execution for this session when the caller
    /// requests the int route: A²Q method, non-GAT arch, no graph-level
    /// head.  Everything else falls back to the fp emulation — shared by
    /// `forward_int_*`, the sharded forwards, and the executor's delta
    /// path so the fallback decision cannot diverge between them.
    pub fn int_path_semantics(&self, use_int_path: bool) -> bool {
        use_int_path
            && self.model.method == QuantMethod::A2q
            && self.model.head.is_none()
            && self.model.arch != "gat"
    }

    /// Rough resident-size accounting of the prepared (request-invariant)
    /// state in bytes — what a serving process pays per loaded session.
    pub fn prepared_bytes(&self) -> usize {
        let mat_f = |m: &Option<Matrix<f32>>| m.as_ref().map_or(0, |m| m.data.len() * 4);
        let panel = |p: &Option<WeightPanel>| p.as_ref().map_or(0, |p| p.bytes());
        let mut total = 0usize;
        for pl in &self.layers {
            total += mat_f(&pl.wq) + mat_f(&pl.w2q) + panel(&pl.w2_panel);
            total += pl.w2_steps_clamped.len() * 4;
            total += pl.nns.as_ref().map_or(0, |t| t.len() * 12);
            total += pl.nns2.as_ref().map_or(0, |t| t.len() * 12);
        }
        if let Some(h) = &self.head {
            total += h.w1q.data.len() * 4 + h.w2q.data.len() * 4;
            total += h.nns.as_ref().map_or(0, |t| t.len() * 12);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::model::LayerParams;
    use crate::quant::mixed::NodeQuantParams;
    use crate::util::json::Json;

    fn tiny_gcn(method: QuantMethod) -> GnnModel {
        let w = Matrix::from_vec(2, 2, vec![1.0, 0.5, -0.5, 1.0]).unwrap();
        GnnModel {
            name: "tiny".into(),
            arch: "gcn".into(),
            dataset: "unit".into(),
            method,
            layers: vec![LayerParams {
                w: Some(w),
                b: vec![0.1, -0.1],
                w_steps: vec![0.05, 0.05],
                feat: Some(NodeQuantParams::new(vec![0.1; 3], vec![4; 3], true).unwrap()),
                ..Default::default()
            }],
            head: None,
            dq_steps: vec![0.05, 0.05],
            skip_input_quant: false,
            node_level: true,
            num_nodes: 3,
            in_dim: 2,
            out_dim: 2,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        }
    }

    #[test]
    fn prepare_precomputes_quantized_weights_once() {
        let model = tiny_gcn(QuantMethod::A2q);
        let want = quantize_weights(
            model.layers[0].w.as_ref().unwrap(),
            &model.layers[0].w_steps,
            QuantMethod::A2q,
        );
        let prep = PreparedModel::prepare(model).unwrap();
        assert_eq!(prep.layers.len(), 1);
        assert_eq!(prep.layers[0].wq.as_ref().unwrap().data, want.data);
        // per-node params (len == num_nodes on a node-level model) never
        // hit the NNS branch, so no table is built or kept resident
        assert!(prep.layers[0].nns.is_none());
        assert!(prep.prepared_bytes() > 0);
    }

    #[test]
    fn prepare_builds_nns_table_only_for_grouped_params() {
        // 4 NNS groups for a 3-node model: the grouped lookup is live
        let mut model = tiny_gcn(QuantMethod::A2q);
        model.layers[0].feat =
            Some(NodeQuantParams::new(vec![0.05, 0.1, 0.2, 0.4], vec![4; 4], true).unwrap());
        let prep = PreparedModel::prepare(model).unwrap();
        let table = prep.layers[0].nns.as_ref().expect("grouped params need a table");
        assert_eq!(table.len(), 4);
    }

    #[test]
    fn prepare_rejects_missing_layer_weight() {
        let mut model = tiny_gcn(QuantMethod::A2q);
        model.layers[0].w = None;
        let err = PreparedModel::prepare(model).unwrap_err();
        assert!(format!("{err}").contains("missing w"));
    }

    #[test]
    fn prepare_rejects_unknown_arch() {
        let mut model = tiny_gcn(QuantMethod::A2q);
        model.arch = "transformer".into();
        let err = PreparedModel::prepare(model).unwrap_err();
        assert!(format!("{err}").contains("unknown arch"));
    }

    #[test]
    fn prepare_rejects_step_column_mismatch() {
        let mut model = tiny_gcn(QuantMethod::A2q);
        model.layers[0].w_steps = vec![0.05];
        let err = PreparedModel::prepare(model).unwrap_err();
        assert!(format!("{err}").contains("output columns"));
    }

    #[test]
    fn fp32_prepare_needs_no_steps() {
        let mut model = tiny_gcn(QuantMethod::Fp32);
        model.layers[0].w_steps = Vec::new();
        // garbage steps are harmless for methods that never read them
        let mut binary = tiny_gcn(QuantMethod::Binary);
        binary.layers[0].w_steps = vec![f32::NAN, f32::NAN];
        assert!(PreparedModel::prepare(binary).is_ok());

        let raw = model.layers[0].w.as_ref().unwrap().data.clone();
        let prep = PreparedModel::prepare(model).unwrap();
        // fp32 wq is a verbatim copy...
        assert_eq!(prep.layers[0].wq.as_ref().unwrap().data, raw);
        assert!(prep.layers[0].nns.is_none());
        // ...and the raw tensor is released from the retained model
        assert!(prep.model.layers[0].w.is_none());
    }

    #[test]
    fn weight_quantization_is_per_column() {
        let w = Matrix::from_vec(2, 2, vec![0.123, 0.9, -0.07, -0.9]).unwrap();
        let wq = quantize_weights(&w, &[0.1, 0.5], QuantMethod::A2q);
        // column 0 step 0.1: 0.123 -> 0.1; column 1 step 0.5: 0.9 -> 1.0
        assert!((wq.at(0, 0) - 0.1).abs() < 1e-6);
        assert!((wq.at(0, 1) - 1.0).abs() < 1e-6);
    }
}
