//! Incremental activation repair + online NNS assignment for dynamic
//! resident graphs.
//!
//! The serving path keeps every layer's activation matrix resident
//! (recorded by `forward_{fp,int}_prepared_recording`).  When a
//! [`crate::graph::GraphDelta`] mutates the graph, only the delta's L-hop
//! reverse frontier (`graph::delta::dirty_frontier`) can change, so
//! [`patch_activations`] recomputes exactly those rows, layer by layer —
//! **bitwise identical** to rerunning the full forward on the post-delta
//! graph (each helper below replicates the corresponding full-pass kernel
//! element-for-element: same accumulation order, same zero-skips, same
//! rounding expressions).
//!
//! Nodes that arrive after training have no learned quantization
//! parameters.  Per the paper's Nearest Neighbor Strategy (Algorithm 1),
//! each appended node is assigned the learned `(step, bits)` group whose
//! `q_max = s·(2^{b−1}−1)` is nearest to the node's max-|x| at that layer
//! — evaluated *online* against [`NnsAssignTables`] frozen over the
//! originally-learned per-node parameters, then persisted into the
//! resident `NodeQuantParams` so later full recomputes (epoch bumps,
//! from-scratch rebuilds with the same extended parameters) reproduce the
//! patched values exactly.  Topology-fixed schemes (Degree-Quant, SGQuant
//! — see PAPERS.md) have no analogue of this: A²Q's value-keyed lookup is
//! what makes unseen-node serving well-defined.

use std::borrow::Cow;

use crate::error::{Error, Result};
use crate::graph::norm::{AggregationPlan, EdgeForm};
use crate::quant::mixed::NodeQuantParams;
use crate::quant::nns::NnsTable;
use crate::quant::uniform;
use crate::tensor::dense::Matrix;
use crate::tensor::ops;
use crate::tensor::simd::Isa;

use super::infer::{model_uses_skip, nns_or_build};
use super::model::{GnnModel, QuantMethod};
use super::prepared::PreparedModel;

/// Frozen NNS lookup tables over the *originally learned* per-node
/// parameters of one layer (`None` for maps that are absent, grouped, or
/// non-A²Q).  Built once per session at the first delta; assignments for
/// appended nodes always search the learned groups, never previously
/// assigned copies (which carry no new `(step, bits)` values anyway).
#[derive(Debug, Clone, Default)]
pub struct NnsAssignTables {
    pub feat: Option<NnsTable>,
    pub feat2: Option<NnsTable>,
}

/// Build the per-layer assignment tables for a prepared session.  Only
/// A²Q per-node maps (length == resident node count of a node-level
/// model) get a table — grouped maps already serve any row count through
/// the prepared `NnsTable`s in [`PreparedModel`].
pub fn build_assign_tables(prep: &PreparedModel) -> Result<Vec<NnsAssignTables>> {
    let model = &prep.model;
    let per_node =
        |p: &NodeQuantParams| model.node_level && p.len() == model.num_nodes;
    let mut out = Vec::with_capacity(model.layers.len());
    for (l, lay) in model.layers.iter().enumerate() {
        let mut t = NnsAssignTables::default();
        if model.method == QuantMethod::A2q {
            if let Some(p) = &lay.feat {
                if per_node(p) {
                    t.feat = Some(NnsTable::try_new(&p.steps, &p.bits, p.signed).map_err(
                        |e| Error::artifact(format!("layer {l} feat NNS table: {e}")),
                    )?);
                }
            }
            if let Some(p) = &lay.feat2 {
                if per_node(p) {
                    t.feat2 = Some(NnsTable::try_new(&p.steps, &p.bits, p.signed).map_err(
                        |e| Error::artifact(format!("layer {l} feat2 NNS table: {e}")),
                    )?);
                }
            }
        }
        out.push(t);
    }
    Ok(out)
}

/// One output row of `a @ b`, replicating `ops::matmul_rows_f32`
/// element-for-element for a single row: ascending-k accumulation with
/// the same `aik == 0.0` skip (blocking over k does not reorder a single
/// row's adds).
fn row_matmul_f32(a: &[f32], b: &Matrix<f32>, out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.rows);
    debug_assert_eq!(out.len(), b.cols);
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let n = b.cols;
    for (kk, &aik) in a.iter().enumerate() {
        if aik == 0.0 {
            continue;
        }
        let brow = &b.data[kk * n..(kk + 1) * n];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += aik * bv;
        }
    }
}

fn relu_row(row: &mut [f32]) {
    for v in row.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

fn add_bias_row(row: &mut [f32], bias: &[f32]) {
    debug_assert_eq!(row.len(), bias.len());
    for (v, b) in row.iter_mut().zip(bias) {
        *v += b;
    }
}

/// Row mirror of `infer::quantize_features` — identical per-method
/// expressions, applied to one row `v`.  Shared with the shard-parallel
/// forward (`super::sharded`), whose mirror buffers hold rows at local
/// indices but must quantize with the row's *global* per-node parameters.
pub(crate) fn quantize_row(
    model: &GnnModel,
    layer: usize,
    p: Option<&NodeQuantParams>,
    per_node: bool,
    nns: Option<&NnsTable>,
    row: &mut [f32],
    v: usize,
) {
    match model.method {
        QuantMethod::Fp32 => {}
        QuantMethod::Binary => {
            let mean = row.iter().map(|x| x.abs()).sum::<f32>() / row.len() as f32;
            for x in row.iter_mut() {
                *x = if *x >= 0.0 { mean } else { -mean };
            }
        }
        QuantMethod::Dq => {
            let step = model.dq_steps.get(layer).copied().unwrap_or(0.05);
            let signed = layer == 0 || model.arch == "gat";
            for x in row.iter_mut() {
                *x = uniform::quantize_value(*x, step, 4, signed) as f32
                    * step.max(uniform::MIN_STEP);
            }
        }
        QuantMethod::A2q => {
            if let Some(p) = p {
                if per_node {
                    uniform::fake_quantize_row(row, p.steps[v], p.bits[v], p.signed);
                } else {
                    let table = nns.expect("grouped A2q params need an NNS table");
                    let f = row.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                    let (_, s, b) = table.select(f);
                    uniform::fake_quantize_row(row, s, b, p.signed);
                }
            }
        }
    }
}

/// Row mirror of the integer GIN hidden-map matmul in `forward_int`:
/// quantize to codes → i32-accumulate against the session-cached
/// weight-code panel → Eq. 2 rescale `acc·sx·sw[j]`.  The accumulation
/// runs through the *same* [`ops::accumulate_code_row`] helper as the
/// bucketed bucket kernels — including the add/sub-only fast path when
/// this row's bitwidth keeps codes in {−1, 0, 1} — so the patcher
/// replicates the bucketed path element-for-element by construction
/// (i32 sums are exact either way; sharing the helper makes it one code
/// path, not two provably-equal ones).  `codes`/`acc` are caller-provided
/// scratch (the patch loop reuses one pair across all dirty rows instead
/// of allocating per row).
#[allow(clippy::too_many_arguments)]
fn int_mm_row(
    isa: Isa,
    hid: &[f32],
    p: Option<&NodeQuantParams>,
    per_node: bool,
    nns: Option<&NnsTable>,
    v: usize,
    panel: &ops::WeightPanel,
    sw: &[f32],
    codes: &mut [i32],
    acc: &mut [i32],
    out: &mut [f32],
) {
    debug_assert_eq!(hid.len(), panel.rows());
    debug_assert_eq!(codes.len(), hid.len());
    debug_assert_eq!(acc.len(), panel.cols());
    debug_assert_eq!(out.len(), panel.cols());
    let cols = panel.cols();
    let (sx, pm_one): (f32, bool) = match p {
        // unquantized hidden map (no feat2 params): codes are the raw
        // values truncated to i32 with unit step, as in forward_int
        None => {
            for (c, &x) in codes.iter_mut().zip(hid) {
                *c = x as i32;
            }
            (1.0, false)
        }
        Some(p) if per_node => {
            let (s, b) = (p.steps[v], p.bits[v]);
            for (c, &x) in codes.iter_mut().zip(hid) {
                *c = uniform::quantize_value(x, s, b, p.signed);
            }
            (s, ops::codes_fit_pm_one(b, p.signed))
        }
        Some(p) => {
            let table = nns.expect("grouped feat2 params need an NNS table");
            let fmax = hid.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let (_, s, b) = table.select(fmax);
            for (c, &x) in codes.iter_mut().zip(hid) {
                *c = uniform::quantize_value(x, s, b, p.signed);
            }
            (s, ops::codes_fit_pm_one(b, p.signed))
        }
    };
    for a in acc.iter_mut() {
        *a = 0;
    }
    ops::accumulate_code_row(isa, codes, panel.data(), cols, pm_one, acc);
    for (j, o) in out.iter_mut().enumerate() {
        *o = acc[j] as f32 * sx * sw[j];
    }
}

/// Recompute rows `dirty[l]` of every layer's output in `acts`, in place.
///
/// * `acts` — per-layer activation matrices over the **post-delta** graph:
///   `acts[0]` the full feature matrix (appended rows included), deeper
///   matrices carried over from the pre-delta state with zeroed rows for
///   appended nodes.  `acts.len() == model.layers.len() + 1`.
/// * `staged` — per-layer clones of the A²Q per-node quantization
///   parameters (`None` where [`build_assign_tables`] built no table);
///   appended nodes are assigned and appended here via the frozen
///   `tables`, so the caller can commit them atomically on success.
/// * `edges`/`plan` — the post-delta [`EdgeForm`] and its grouped plan.
/// * `dirty` — per-layer sorted dirty row ids from
///   `graph::delta::dirty_frontier`; every appended node must appear in
///   every layer's set (the frontier guarantees this).
/// * `int_path` — replicate `forward_int` (true for the A²Q integer
///   executor path; fp fallback archs/methods pass false).
/// * `simd` — the kernel dispatch ([`Isa`]) used for the integer
///   matmul rows; callers thread their `ParallelConfig::simd` through so
///   patched rows use the same (bitwise-identical) kernels as full
///   forwards.
///
/// Returns the number of final-layer rows recomputed.  On error (only
/// non-finite activations hitting the NNS assignment) `acts`/`staged` are
/// partially written — callers stage both and discard on failure.
#[allow(clippy::too_many_arguments)]
pub fn patch_activations(
    prep: &PreparedModel,
    staged: &mut [(Option<NodeQuantParams>, Option<NodeQuantParams>)],
    tables: &[NnsAssignTables],
    edges: &EdgeForm,
    plan: &AggregationPlan,
    acts: &mut [Matrix<f32>],
    dirty: &[Vec<u32>],
    int_path: bool,
    simd: Isa,
) -> Result<usize> {
    let model = &prep.model;
    let n_layers = model.layers.len();
    if model.arch == "gat" {
        return Err(Error::coordinator(
            "incremental patching is not supported for gat",
        ));
    }
    assert_eq!(acts.len(), n_layers + 1, "acts must hold input + every layer");
    assert_eq!(staged.len(), n_layers);
    assert_eq!(tables.len(), n_layers);
    assert_eq!(dirty.len(), n_layers);
    let n_new = acts[0].rows;

    for l in 0..n_layers {
        let lay = &model.layers[l];
        let pl = &prep.layers[l];
        let last = l + 1 == n_layers;
        let tail = last && model.head.is_none();
        let skip_q = l == 0 && model.skip_input_quant;
        let (before, after) = acts.split_at_mut(l + 1);
        let h_in = &before[l];
        let h_out = &mut after[0];

        // Online NNS assignment for appended nodes at this layer's input
        // map (Algorithm 1 keyed by the row's max |x|, which the frontier
        // patch of layer l-1 has already produced).
        if let (Some(p), Some(table)) =
            (staged[l].0.as_mut(), tables[l].feat.as_ref())
        {
            for v in p.len()..n_new {
                let fmax = h_in.row_abs_max(v);
                let (_, s, b) = table
                    .try_select(fmax)
                    .map_err(|e| Error::coordinator(format!("layer {l} node {v}: {e}")))?;
                p.push(s, b);
            }
        }
        let (sf, sf2) = {
            let s = &mut staged[l];
            (&s.0, &mut s.1)
        };
        let (feat_p, feat_per_node): (Option<&NodeQuantParams>, bool) =
            match (sf.as_ref(), lay.feat.as_ref()) {
                (Some(p), _) => (Some(p), true),
                (None, Some(p)) => (Some(p), p.len() == n_new),
                (None, None) => (None, false),
            };
        let feat_nns: Option<Cow<NnsTable>> = match (feat_p, feat_per_node) {
            (Some(p), false) if model.method == QuantMethod::A2q => {
                Some(nns_or_build(pl.nns.as_ref(), p))
            }
            _ => None,
        };
        // grouped feat2 table (per-node feat2 lives in `sf2` and needs no
        // lookup at quantize time)
        let feat2_grouped_nns: Option<Cow<NnsTable>> =
            match (sf2.is_some(), lay.feat2.as_ref()) {
                (false, Some(p))
                    if model.method == QuantMethod::A2q && p.len() != n_new =>
                {
                    Some(nns_or_build(pl.nns2.as_ref(), p))
                }
                _ => None,
            };

        match model.arch.as_str() {
            "gcn" => {
                let wq = pl.wq.as_ref().expect("gcn weight");
                let fin = h_in.cols;
                let dout = wq.cols;
                debug_assert_eq!(lay.b.len(), dout);
                let uses_skip =
                    !int_path && model_uses_skip(model) && dout == fin;
                let mut qrow = vec![0.0f32; fin];
                let mut agg = vec![0.0f32; fin];
                let mut out = vec![0.0f32; dout];
                for &v in &dirty[l] {
                    let v = v as usize;
                    for a in agg.iter_mut() {
                        *a = 0.0;
                    }
                    for &e in plan.in_edges(v) {
                        let e = e as usize;
                        let w = edges.gcn_w[e];
                        if w == 0.0 {
                            continue;
                        }
                        let s = edges.src[e] as usize;
                        qrow.copy_from_slice(h_in.row(s));
                        if !skip_q {
                            quantize_row(
                                model,
                                l,
                                feat_p,
                                feat_per_node,
                                feat_nns.as_deref(),
                                &mut qrow,
                                s,
                            );
                        }
                        for (o, x) in agg.iter_mut().zip(&qrow) {
                            *o += w * *x;
                        }
                    }
                    row_matmul_f32(&agg, wq, &mut out);
                    add_bias_row(&mut out, &lay.b);
                    if !tail {
                        if uses_skip {
                            qrow.copy_from_slice(h_in.row(v));
                            if !skip_q {
                                quantize_row(
                                    model,
                                    l,
                                    feat_p,
                                    feat_per_node,
                                    feat_nns.as_deref(),
                                    &mut qrow,
                                    v,
                                );
                            }
                            for (o, x) in out.iter_mut().zip(&qrow) {
                                *o += *x;
                            }
                        }
                        relu_row(&mut out);
                    }
                    h_out.row_mut(v).copy_from_slice(&out);
                }
            }
            "gin" => {
                let w1q = pl.wq.as_ref().expect("gin w1");
                let fin = h_in.cols;
                let hidden = w1q.cols;
                debug_assert_eq!(lay.b.len(), hidden);
                let mut qrow = vec![0.0f32; fin];
                let mut neigh = vec![0.0f32; fin];
                let mut agg = vec![0.0f32; fin];
                let mut hid = vec![0.0f32; hidden];
                let mut hqv = vec![0.0f32; fin];
                // int-path scratch, reused across rows
                let (mut codes_buf, mut acc_buf) = if int_path {
                    let panel = pl.w2_panel.as_ref().expect("gin w2 codes");
                    (vec![0i32; hidden], vec![0i32; panel.cols()])
                } else {
                    (Vec::new(), Vec::new())
                };
                for &v in &dirty[l] {
                    let v = v as usize;
                    hqv.copy_from_slice(h_in.row(v));
                    if !skip_q {
                        quantize_row(
                            model,
                            l,
                            feat_p,
                            feat_per_node,
                            feat_nns.as_deref(),
                            &mut hqv,
                            v,
                        );
                    }
                    for nv in neigh.iter_mut() {
                        *nv = 0.0;
                    }
                    for &e in plan.in_edges(v) {
                        let e = e as usize;
                        let w = edges.sum_w[e];
                        if w == 0.0 {
                            continue;
                        }
                        let s = edges.src[e] as usize;
                        qrow.copy_from_slice(h_in.row(s));
                        if !skip_q {
                            quantize_row(
                                model,
                                l,
                                feat_p,
                                feat_per_node,
                                feat_nns.as_deref(),
                                &mut qrow,
                                s,
                            );
                        }
                        for (o, x) in neigh.iter_mut().zip(&qrow) {
                            *o += w * *x;
                        }
                    }
                    for (k, a) in agg.iter_mut().enumerate() {
                        *a = (1.0 + lay.eps) * hqv[k] + neigh[k];
                    }
                    row_matmul_f32(&agg, w1q, &mut hid);
                    add_bias_row(&mut hid, &lay.b);
                    relu_row(&mut hid);
                    // assignment for an appended node's hidden map happens
                    // here — its hidden row now exists for the first time.
                    // Enforced hard (not debug-only): pushing at an index
                    // other than v would silently misalign every later
                    // per-node lookup of the resident params.
                    if let (Some(p2), Some(t2)) =
                        (sf2.as_mut(), tables[l].feat2.as_ref())
                    {
                        if v > p2.len() {
                            return Err(Error::coordinator(format!(
                                "layer {l}: appended node {v} patched out of \
                                 order ({} params assigned — dirty sets must \
                                 contain every appended node, ascending)",
                                p2.len()
                            )));
                        }
                        if v == p2.len() {
                            let fmax =
                                hid.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                            let (_, s, b) = t2.try_select(fmax).map_err(|e| {
                                Error::coordinator(format!(
                                    "layer {l} node {v} hidden map: {e}"
                                ))
                            })?;
                            p2.push(s, b);
                        }
                    }
                    let (feat2_p, feat2_per_node): (Option<&NodeQuantParams>, bool) =
                        match (sf2.as_ref(), lay.feat2.as_ref()) {
                            (Some(p), _) => (Some(p), true),
                            (None, Some(p)) => (Some(p), p.len() == n_new),
                            (None, None) => (None, false),
                        };
                    let out_slice: &mut [f32] = h_out.row_mut(v);
                    if int_path {
                        let panel =
                            pl.w2_panel.as_ref().expect("gin w2 codes");
                        debug_assert_eq!(lay.b2.len(), panel.cols());
                        int_mm_row(
                            simd,
                            &hid,
                            feat2_p,
                            feat2_per_node,
                            feat2_grouped_nns.as_deref(),
                            v,
                            panel,
                            &pl.w2_steps_clamped,
                            &mut codes_buf,
                            &mut acc_buf,
                            out_slice,
                        );
                        add_bias_row(out_slice, &lay.b2);
                        if !tail {
                            relu_row(out_slice);
                        }
                    } else {
                        let w2q = pl.w2q.as_ref().expect("gin w2");
                        debug_assert_eq!(lay.b2.len(), w2q.cols);
                        if model.method != QuantMethod::Fp32 {
                            quantize_row(
                                model,
                                l,
                                feat2_p,
                                feat2_per_node,
                                feat2_grouped_nns.as_deref(),
                                &mut hid,
                                v,
                            );
                        }
                        row_matmul_f32(&hid, w2q, out_slice);
                        add_bias_row(out_slice, &lay.b2);
                        if !tail {
                            if model_uses_skip(model) && w2q.cols == fin {
                                for (o, x) in out_slice.iter_mut().zip(&hqv) {
                                    *o += *x;
                                }
                            }
                            relu_row(out_slice);
                        }
                    }
                }
            }
            other => {
                return Err(Error::coordinator(format!(
                    "incremental patching unsupported for arch '{other}'"
                )))
            }
        }
    }
    Ok(dirty.last().map(|d| d.len()).unwrap_or(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::infer::{
        forward_fp_prepared_recording, forward_int_prepared_recording, GraphInput,
    };
    use crate::gnn::model::LayerParams;
    use crate::graph::csr::Csr;
    use crate::util::json::Json;
    use crate::util::prop::{property, Gen};
    use crate::util::rng::Rng;
    use crate::util::threadpool::ParallelConfig;

    fn random_model(g: &mut Gen, arch: &str, n: usize, in_dim: usize, hidden: usize) -> GnnModel {
        let n_layers = g.usize_range(1, 4);
        let mut layers = Vec::new();
        for l in 0..n_layers {
            let d_in = if l == 0 { in_dim } else { hidden };
            let steps = g.vec_uniform(n, 0.02, 0.1);
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(2, 9) as u8).collect();
            let feat = NodeQuantParams::new(steps, bits, l == 0).unwrap();
            let lay = match arch {
                "gcn" => LayerParams {
                    w: Some(
                        Matrix::from_vec(d_in, hidden, g.vec_normal(d_in * hidden, 0.5)).unwrap(),
                    ),
                    b: g.vec_uniform(hidden, -0.1, 0.1),
                    w_steps: g.vec_uniform(hidden, 0.02, 0.08),
                    feat: Some(feat),
                    ..Default::default()
                },
                _ => LayerParams {
                    w: Some(
                        Matrix::from_vec(d_in, hidden, g.vec_normal(d_in * hidden, 0.5)).unwrap(),
                    ),
                    b: g.vec_uniform(hidden, -0.1, 0.1),
                    w_steps: g.vec_uniform(hidden, 0.02, 0.08),
                    w2: Some(
                        Matrix::from_vec(hidden, hidden, g.vec_normal(hidden * hidden, 0.5))
                            .unwrap(),
                    ),
                    b2: g.vec_uniform(hidden, -0.1, 0.1),
                    w2_steps: g.vec_uniform(hidden, 0.02, 0.08),
                    eps: g.f32_range(0.0, 0.2),
                    feat: Some(feat),
                    feat2: Some(
                        NodeQuantParams::new(
                            g.vec_uniform(n, 0.02, 0.1),
                            (0..n).map(|_| g.usize_range(2, 9) as u8).collect(),
                            false,
                        )
                        .unwrap(),
                    ),
                    ..Default::default()
                },
            };
            layers.push(lay);
        }
        GnnModel {
            name: format!("inc-{arch}"),
            arch: arch.into(),
            dataset: "unit".into(),
            method: QuantMethod::A2q,
            layers,
            head: None,
            dq_steps: vec![],
            skip_input_quant: false,
            node_level: true,
            num_nodes: n,
            in_dim,
            out_dim: hidden,
            heads: 1,
            graph_capacity: 0,
            accuracy: 0.0,
            avg_bits: 4.0,
            expected_head: vec![],
            manifest: Json::Null,
        }
    }

    /// The foundational bitwise guarantee: patching *every* row from
    /// zeroed output matrices reproduces the recording forward exactly,
    /// for both archs and both execution paths.
    #[test]
    fn patch_all_rows_reproduces_full_forward_bitwise() {
        property("row patch == full forward", 12, |g: &mut Gen| {
            let n = g.usize_range(8, 60);
            let mut rng = Rng::new(g.usize_range(0, 1 << 30) as u64);
            let csr = crate::graph::generate::preferential_attachment(&mut rng, n, 2);
            let ef = EdgeForm::from_csr(&csr);
            let plan = ef.plan();
            let in_dim = g.usize_range(2, 6);
            let hidden = g.usize_range(2, 8);
            let x = g.vec_normal(n * in_dim, 0.5);
            let cfg = ParallelConfig::serial();
            for arch in ["gcn", "gin"] {
                for int_path in [false, true] {
                    let model = random_model(g, arch, n, in_dim, hidden);
                    let n_layers = model.layers.len();
                    let prep = PreparedModel::prepare(model.clone()).unwrap();
                    let input = GraphInput::node_level(&x, in_dim, &ef);
                    let mut want = Vec::new();
                    if int_path {
                        forward_int_prepared_recording(&prep, &input, Some(&plan), &cfg, &mut want);
                    } else {
                        forward_fp_prepared_recording(&prep, &input, Some(&plan), &cfg, &mut want);
                    }
                    assert_eq!(want.len(), n_layers + 1);

                    let mut acts: Vec<Matrix<f32>> = Vec::new();
                    acts.push(want[0].clone());
                    for m in &want[1..] {
                        acts.push(Matrix::zeros(m.rows, m.cols));
                    }
                    let tables = build_assign_tables(&prep).unwrap();
                    let mut staged: Vec<_> = prep
                        .model
                        .layers
                        .iter()
                        .zip(&tables)
                        .map(|(lay, t)| {
                            (
                                t.feat.as_ref().and(lay.feat.clone()),
                                t.feat2.as_ref().and(lay.feat2.clone()),
                            )
                        })
                        .collect();
                    let all: Vec<u32> = (0..n as u32).collect();
                    let dirty = vec![all; n_layers];
                    let done = patch_activations(
                        &prep, &mut staged, &tables, &ef, &plan, &mut acts, &dirty, int_path,
                        cfg.simd,
                    )
                    .unwrap();
                    assert_eq!(done, n);
                    for (l, (got, exp)) in acts.iter().zip(&want).enumerate() {
                        assert_eq!(
                            got.data, exp.data,
                            "{arch} int={int_path} layer {l} diverged"
                        );
                    }
                    // no nodes appended → no params assigned
                    for (l, (sf, sf2)) in staged.iter().enumerate() {
                        if let Some(p) = sf {
                            assert_eq!(p.len(), n, "layer {l} feat grew");
                        }
                        if let Some(p) = sf2 {
                            assert_eq!(p.len(), n, "layer {l} feat2 grew");
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn assign_tables_cover_only_per_node_a2q_maps() {
        let mut g = Gen::new(11);
        let model = random_model(&mut g, "gin", 12, 3, 4);
        let prep = PreparedModel::prepare(model).unwrap();
        let tables = build_assign_tables(&prep).unwrap();
        for t in &tables {
            assert!(t.feat.is_some());
            assert!(t.feat2.is_some());
            assert_eq!(t.feat.as_ref().unwrap().len(), 12);
        }
        // non-A2q methods never assign
        let mut g = Gen::new(12);
        let mut model = random_model(&mut g, "gcn", 8, 3, 4);
        model.method = QuantMethod::Fp32;
        let prep = PreparedModel::prepare(model).unwrap();
        for t in build_assign_tables(&prep).unwrap() {
            assert!(t.feat.is_none() && t.feat2.is_none());
        }
    }
}
