//! Crate-wide error type.

use thiserror::Error;

/// Unified error for all a2q subsystems.
#[derive(Error, Debug)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("json error: {0}")]
    Json(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("dataset error: {0}")]
    Dataset(String),

    #[error("shape mismatch: {0}")]
    Shape(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("injected fault: {0}")]
    Fault(String),
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    pub fn dataset(msg: impl Into<String>) -> Self {
        Error::Dataset(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
    pub fn fault(msg: impl Into<String>) -> Self {
        Error::Fault(msg.into())
    }
}
