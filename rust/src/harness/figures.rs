//! Figure-series regeneration (CSV-style output for Figs. 1, 3, 4, 8, 22).

use std::fmt::Write as _;
use std::path::Path;

use crate::error::Result;
use crate::graph::io::{self, Dataset};
use crate::graph::stats as gstats;
use crate::quant::mixed::BitsFile;

use super::results::ResultsStore;
use super::tables::{energy_for, representative_csr};

/// Fig. 1: mean |sum-aggregated feature| per in-degree group.
pub fn fig1(artifacts: &Path, dataset: &str) -> Result<String> {
    let ds = match io::load_named(artifacts, dataset)? {
        Dataset::Node(d) => d,
        _ => {
            return Ok(format!("fig1: {dataset} is graph-level; use a node dataset\n"))
        }
    };
    let n = ds.num_nodes();
    let f = ds.num_features;
    // sum-aggregate input features (the paper's aggregation magnitudes)
    let mut agg = vec![0.0f32; n * f];
    for v in 0..n {
        for &s in ds.csr.in_neighbors(v) {
            let srow = &ds.features[s as usize * f..(s as usize + 1) * f];
            let orow = &mut agg[v * f..(v + 1) * f];
            for (o, x) in orow.iter_mut().zip(srow) {
                *o += x;
            }
        }
    }
    let mags: Vec<f32> = (0..n)
        .map(|v| {
            agg[v * f..(v + 1) * f].iter().map(|x| x.abs()).sum::<f32>() / f as f32
        })
        .collect();
    let groups = gstats::mean_by_degree_group(&ds.csr, &mags, &[2, 4, 8, 16, 32, 64]);
    let mut out = format!("# fig1 {dataset}: degree_group,mean_agg_magnitude,count\n");
    for (label, mean, count) in groups {
        let _ = writeln!(out, "{label},{mean:.5},{count}");
    }
    Ok(out)
}

/// Fig. 3: task-gradient sparsity (fraction of zero-gradient nodes),
/// recorded by the python training probe.
pub fn fig3(store: &ResultsStore) -> String {
    let mut out = String::from("# fig3: task,method,zero_grad_fraction\n");
    for e in &store.entries {
        if e.grad_zero_frac >= 0.0 && e.seed == 0 {
            let _ = writeln!(
                out,
                "{}-{},{},{:.4}",
                e.arch, e.dataset, e.method, e.grad_zero_frac
            );
        }
    }
    out
}

/// Fig. 4 / Figs. 10–16: learned bitwidth vs average in-degree + node
/// counts, from the exported `.bits.bin` of an A²Q run.
pub fn fig4(store: &ResultsStore, artifacts: &Path, dataset: &str, arch: &str) -> Result<String> {
    let entries = store.find(dataset, arch, "a2q");
    let mut out = format!("# fig4 {arch}-{dataset}: map,bits,avg_in_degree,node_count\n");
    let Some(entry) = entries.iter().find(|e| e.bits_path().exists()) else {
        out.push_str("# (no bits.bin exported yet — run `make experiments`)\n");
        return Ok(out);
    };
    let bf = BitsFile::load(&entry.bits_path())?;
    let csr = representative_csr(artifacts, dataset)?;
    for (mi, (bits, _dim)) in bf.maps.iter().enumerate() {
        // node-level maps align with node ids; NNS group maps are skipped
        if bits.len() != csr.num_nodes() {
            continue;
        }
        for (b, avg_deg, count) in gstats::bits_vs_degree(&csr, bits) {
            if count > 0 {
                let _ = writeln!(out, "{mi},{b},{avg_deg:.2},{count}");
            }
        }
        let corr = gstats::degree_correlation(
            &csr,
            &bits.iter().map(|&b| b as f32).collect::<Vec<_>>(),
        );
        let _ = writeln!(out, "# map {mi} bits-degree pearson = {corr:.3}");
    }
    Ok(out)
}

/// Fig. 8: in-degree histogram per dataset.
pub fn fig8(artifacts: &Path, dataset: &str) -> Result<String> {
    let csr = representative_csr(artifacts, dataset)?;
    let mut out = format!("# fig8 {dataset}: degree_bucket_lo,count\n");
    for (lo, count) in gstats::degree_histogram(&csr) {
        let _ = writeln!(out, "{lo},{count}");
    }
    Ok(out)
}

/// Fig. 22: energy-efficiency ratio vs the GPU model per task.
pub fn fig22(store: &ResultsStore, artifacts: &Path) -> String {
    let mut out = String::from("# fig22: task,energy_efficiency_vs_gpu\n");
    let tasks = [
        ("gcn", "synth-cora", 7usize),
        ("gat", "synth-cora", 7),
        ("gcn", "synth-citeseer", 6),
        ("gin", "synth-citeseer", 6),
        ("gcn", "synth-zinc", 1),
        ("gin", "synth-reddit-b", 2),
    ];
    for (arch, dataset, out_dim) in tasks {
        let entries = store.find(dataset, arch, "a2q");
        if let Some(e) = entries.iter().find(|e| e.bits_path().exists()) {
            if let Some(eff) = energy_for(e, artifacts, out_dim) {
                let _ = writeln!(out, "{arch}-{dataset},{eff:.2}");
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_renders_from_store() {
        let store = ResultsStore::default();
        let out = fig3(&store);
        assert!(out.starts_with("# fig3"));
    }
}
