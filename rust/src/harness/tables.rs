//! Paper-table regeneration (Tables 1, 2, 3, 6, 11, 13, 16 + Fig. 5).

use std::fmt::Write as _;
use std::path::Path;

use crate::accel::{
    compare::{energy_efficiency_vs_gpu, float_op_ratio, speedup_vs_dq},
    AccelConfig, ModelWorkload, Simulator,
};
use crate::error::Result;
use crate::graph::csr::Csr;
use crate::graph::io::{self, Dataset};
use crate::quant::mixed::BitsFile;

use super::results::{ResultEntry, ResultsStore};

/// Identifier of one regenerable table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableSpec {
    Table1,
    Table2,
    Table3,
    Table6,
    Table11,
    Table13,
    Table16,
    Fig5,
}

impl TableSpec {
    pub fn parse(s: &str) -> Option<TableSpec> {
        Some(match s {
            "table1" => TableSpec::Table1,
            "table2" => TableSpec::Table2,
            "table3" => TableSpec::Table3,
            "table6" => TableSpec::Table6,
            "table11" => TableSpec::Table11,
            "table13" => TableSpec::Table13,
            "table16" => TableSpec::Table16,
            "fig5" => TableSpec::Fig5,
            _ => return None,
        })
    }

    pub fn all() -> &'static [TableSpec] {
        &[
            TableSpec::Table1,
            TableSpec::Table2,
            TableSpec::Table3,
            TableSpec::Table6,
            TableSpec::Table11,
            TableSpec::Table13,
            TableSpec::Table16,
            TableSpec::Fig5,
        ]
    }
}

/// Load a dataset's representative CSR: the full graph (node-level) or a
/// block-diagonal pack of the first 32 graphs (graph-level batch shape).
pub fn representative_csr(artifacts: &Path, dataset: &str) -> Result<Csr> {
    match io::load_named(artifacts, dataset)? {
        Dataset::Node(d) => Ok(d.csr),
        Dataset::Graphs(g) => {
            let mut edges: Vec<(u32, u32)> = Vec::new();
            let mut off = 0u32;
            let mut total = 0usize;
            for gr in g.graphs.iter().take(32) {
                for (s, d) in gr.csr.edge_list() {
                    edges.push((s + off, d + off));
                }
                off += gr.num_nodes() as u32;
                total += gr.num_nodes();
            }
            Csr::from_edges(total, &edges)
        }
    }
}

/// Simulated speedup vs DQ-INT4 for an A²Q result (needs its .bits.bin).
pub fn speedup_for(entry: &ResultEntry, artifacts: &Path, out_dim: usize) -> Option<f64> {
    let bits_path = entry.bits_path();
    if !bits_path.exists() {
        return None;
    }
    let bf = BitsFile::load(&bits_path).ok()?;
    let csr = representative_csr(artifacts, &entry.dataset).ok()?;
    let workload = workload_from_bits(&bf, entry, out_dim);
    let sim = Simulator::new(AccelConfig::default());
    Some(speedup_vs_dq(&sim, &csr, &workload))
}

/// Energy-efficiency ratio vs the GPU model (Fig. 22 column for a task).
pub fn energy_for(entry: &ResultEntry, artifacts: &Path, out_dim: usize) -> Option<f64> {
    let bits_path = entry.bits_path();
    if !bits_path.exists() {
        return None;
    }
    let bf = BitsFile::load(&bits_path).ok()?;
    let csr = representative_csr(artifacts, &entry.dataset).ok()?;
    let workload = workload_from_bits(&bf, entry, out_dim);
    let sim = Simulator::new(AccelConfig::default());
    Some(energy_efficiency_vs_gpu(&sim, &csr, &workload))
}

fn workload_from_bits(bf: &BitsFile, entry: &ResultEntry, out_dim: usize) -> ModelWorkload {
    // bits.bin records each quantized map's input feature dim; the map's
    // matmul output is the hidden width except for the final map.
    let hidden = 64.max(16); // conservative; exact dims recorded per map
    let n_maps = bf.maps.len();
    let matmuls: Vec<(usize, usize)> = bf
        .maps
        .iter()
        .enumerate()
        .map(|(i, (_b, dim))| {
            let f_out = if i + 1 == n_maps { out_dim } else { hidden };
            (*dim, f_out)
        })
        .collect();
    ModelWorkload::from_bits_file(
        bf,
        matmuls,
        if entry.nns_m > 0 && !entry.dataset.contains("cora") {
            entry.nns_m
        } else {
            0
        },
    )
}

fn fmt_acc(e: &ResultEntry, mean: f64, std: f64) -> String {
    if e.metric_name == "mae" {
        format!("{:.3}±{:.3}", -mean, std)
    } else {
        format!("{:.1}±{:.1}%", mean * 100.0, std * 100.0)
    }
}

fn table_header(out: &mut String, cols: &[&str]) {
    let _ = writeln!(out, "| {} |", cols.join(" | "));
    let _ = writeln!(
        out,
        "|{}|",
        cols.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    );
}

/// Tables 1 & 2: accuracy / avg bits / compression / speedup per task.
fn accuracy_table(
    store: &ResultsStore,
    artifacts: &Path,
    rows: &[(&str, &str)],
    title: &str,
) -> String {
    let mut out = format!("## {title}\n\n");
    table_header(
        &mut out,
        &["Dataset", "Model", "Method", "Accuracy", "Avg bits", "Compression", "Speedup"],
    );
    for &(arch, dataset) in rows {
        for method in ["fp32", "dq", "a2q"] {
            let found = store.find(dataset, arch, method);
            // exclude ablation rows that share (dataset,arch,method)
            let found: Vec<&ResultEntry> = found
                .into_iter()
                .filter(|e| e.nns_m == 0 || e.nns_m == 1000)
                .filter(|e| e.layers <= 4 && !e.skip)
                .collect();
            let Some((mean, std, bits)) = ResultsStore::aggregate(&found) else {
                continue;
            };
            let e0 = found[0];
            let (compr, speed) = match method {
                "fp32" => ("1x".to_string(), "—".to_string()),
                "dq" => ("8x".to_string(), "1x".to_string()),
                _ => {
                    let out_dim = guess_out_dim(dataset);
                    let speed = found
                        .iter()
                        .filter_map(|e| speedup_for(e, artifacts, out_dim))
                        .next()
                        .map(|s| format!("{s:.2}x"))
                        .unwrap_or_else(|| "n/a".into());
                    (format!("{:.1}x", 32.0 / bits.max(0.01)), speed)
                }
            };
            let _ = writeln!(
                out,
                "| {} | {}({}) | {} | {} | {:.2} | {} | {} |",
                dataset,
                arch.to_uppercase(),
                method,
                method,
                fmt_acc(e0, mean, std),
                if method == "fp32" { 32.0 } else { bits },
                compr,
                speed,
            );
        }
    }
    out
}

fn guess_out_dim(dataset: &str) -> usize {
    match dataset {
        "synth-cora" => 7,
        "synth-citeseer" => 6,
        "synth-pubmed" => 3,
        "synth-arxiv" => 23,
        "synth-zinc" => 1,
        "synth-reddit-b" => 2,
        _ => 10,
    }
}

pub fn table1(store: &ResultsStore, artifacts: &Path) -> String {
    accuracy_table(
        store,
        artifacts,
        &[
            ("gcn", "synth-cora"),
            ("gat", "synth-cora"),
            ("gcn", "synth-citeseer"),
            ("gin", "synth-citeseer"),
            ("gat", "synth-pubmed"),
            ("gcn", "synth-arxiv"),
        ],
        "Table 1 — node-level tasks",
    )
}

pub fn table2(store: &ResultsStore, artifacts: &Path) -> String {
    accuracy_table(
        store,
        artifacts,
        &[
            ("gcn", "synth-mnist"),
            ("gin", "synth-mnist"),
            ("gcn", "synth-cifar10"),
            ("gat", "synth-cifar10"),
            ("gcn", "synth-zinc"),
            ("gin", "synth-reddit-b"),
        ],
        "Table 2 — graph-level tasks (NNS)",
    )
}

/// Table 3: quantizer-learning ablations + Local vs Global gradient.
pub fn table3(store: &ResultsStore) -> String {
    let mut out = String::from("## Table 3 — ablation study\n\n");
    table_header(&mut out, &["Model", "Config", "Accuracy", "Average bits"]);
    let gin_cora = |lb: bool, ls: bool, label: &str, out: &mut String| {
        let found = store.find_where(|e| {
            e.dataset == "synth-cora"
                && e.arch == "gin"
                && e.method == "a2q"
                && e.learn_bits == lb
                && e.learn_step == ls
        });
        if let Some((mean, std, bits)) = ResultsStore::aggregate(&found) {
            let _ = writeln!(
                out,
                "| GIN-Cora | {label} | {:.1}±{:.1}% | {bits:.2} |",
                mean * 100.0,
                std * 100.0
            );
        }
    };
    gin_cora(false, false, "no-lr", &mut out);
    gin_cora(false, true, "no-lr-b", &mut out);
    gin_cora(true, false, "no-lr-s", &mut out);
    gin_cora(true, true, "lr-all", &mut out);
    for (method, label) in [("a2q_global", "Global"), ("a2q", "Local")] {
        let found = store.find_where(|e| {
            e.dataset == "synth-citeseer" && e.arch == "gcn" && e.method == method
                && e.learn_bits && e.learn_step && e.layers == 2
        });
        if let Some((mean, std, bits)) = ResultsStore::aggregate(&found) {
            let _ = writeln!(
                out,
                "| GCN-CiteSeer | {label} | {:.1}±{:.1}% | {bits:.2} |",
                mean * 100.0,
                std * 100.0
            );
        }
    }
    out
}

/// Table 6: fixed vs float op counts (NNS overhead) per graph-level task.
pub fn table6(artifacts: &Path) -> String {
    let mut out = String::from("## Table 6 — fixed vs float op counts (NNS)\n\n");
    table_header(&mut out, &["Task", "Fixed-point (M)", "Float-point (M)", "Ratio"]);
    let sim = Simulator::new(AccelConfig::default());
    for (dataset, dims) in [
        ("synth-reddit-b", vec![(8usize, 64usize), (64, 64), (64, 64), (64, 2)]),
        ("synth-mnist", vec![(3, 64), (64, 64), (64, 64), (64, 10)]),
        ("synth-cifar10", vec![(5, 64), (64, 64), (64, 64), (64, 10)]),
        ("synth-zinc", vec![(28, 64), (64, 64), (64, 64), (64, 1)]),
    ] {
        let Ok(csr) = representative_csr(artifacts, dataset) else {
            continue;
        };
        let n = csr.num_nodes();
        let bits = vec![vec![4u8; n]; dims.len()];
        let workload = ModelWorkload {
            matmuls: dims.clone(),
            agg_dims: dims.iter().map(|&(_f, o)| o).collect(),
            bits,
            nns_m: 1000,
        };
        let (fixed, float, ratio) = float_op_ratio(&sim, &csr, &workload);
        let _ = writeln!(
            out,
            "| {dataset} | {:.2} | {:.2} | {:.2}% |",
            fixed as f64 / 1e6,
            float as f64 / 1e6,
            ratio * 100.0
        );
    }
    out
}

/// Table 11: NNS group-count (m) sweep.
pub fn table11(store: &ResultsStore) -> String {
    let mut out = String::from("## Table 11 — effect of m (GIN-REDDIT-B)\n\n");
    table_header(&mut out, &["m", "Accuracy", "Avg bits"]);
    let mut ms: Vec<usize> = store
        .find_where(|e| {
            e.dataset == "synth-reddit-b" && e.arch == "gin" && e.method == "a2q"
        })
        .iter()
        .map(|e| e.nns_m)
        .collect();
    ms.sort_unstable();
    ms.dedup();
    for m in ms {
        let found = store.find_where(|e| {
            e.dataset == "synth-reddit-b"
                && e.arch == "gin"
                && e.method == "a2q"
                && e.nns_m == m
        });
        if let Some((mean, std, bits)) = ResultsStore::aggregate(&found) {
            let _ = writeln!(
                out,
                "| {m} | {:.1}±{:.1}% | {bits:.2} |",
                mean * 100.0,
                std * 100.0
            );
        }
    }
    out
}

/// Tables 13/14: depth & skip-connection ablation on GCN-Cora.
pub fn table13(store: &ResultsStore) -> String {
    let mut out = String::from("## Tables 13/14 — depth & skip (GCN-Cora)\n\n");
    table_header(
        &mut out,
        &["Layers", "Skip", "FP32 acc", "A2Q acc", "A2Q avg bits"],
    );
    for layers in [3usize, 4, 5, 6] {
        for skip in [false, true] {
            let fp = store.find_where(|e| {
                e.dataset == "synth-cora" && e.arch == "gcn" && e.method == "fp32"
                    && e.layers == layers && e.skip == skip
            });
            let qz = store.find_where(|e| {
                e.dataset == "synth-cora" && e.arch == "gcn" && e.method == "a2q"
                    && e.layers == layers && e.skip == skip
            });
            let fp_s = ResultsStore::aggregate(&fp)
                .map(|(m, _s, _b)| format!("{:.1}%", m * 100.0))
                .unwrap_or_else(|| "—".into());
            if let Some((m, _s, b)) = ResultsStore::aggregate(&qz) {
                let _ = writeln!(
                    out,
                    "| {layers} | {} | {fp_s} | {:.1}% | {b:.2} |",
                    if skip { "yes" } else { "no" },
                    m * 100.0
                );
            }
        }
    }
    out
}

/// Table 16: binary-quantization comparison.
pub fn table16(store: &ResultsStore) -> String {
    let mut out = String::from("## Table 16 — vs binary quantization\n\n");
    table_header(
        &mut out,
        &["Dataset", "Model", "Method", "Accuracy", "Avg bits", "Compression"],
    );
    for dataset in ["synth-cora", "synth-citeseer"] {
        for arch in ["gcn", "gin", "gat"] {
            for method in ["fp32", "binary", "a2q"] {
                let found: Vec<&ResultEntry> = store
                    .find(dataset, arch, method)
                    .into_iter()
                    .filter(|e| e.layers == 2 && !e.skip)
                    .collect();
                if let Some((mean, std, bits)) = ResultsStore::aggregate(&found) {
                    let compr = if method == "fp32" {
                        "1x".into()
                    } else {
                        format!("{:.1}x", 32.0 / bits.max(0.01))
                    };
                    let _ = writeln!(
                        out,
                        "| {dataset} | {} | {method} | {:.1}±{:.1}% | {bits:.2} | {compr} |",
                        arch.to_uppercase(),
                        mean * 100.0,
                        std * 100.0
                    );
                }
            }
        }
    }
    out
}

/// Fig. 5 (rendered as a table): learned vs manual bit assignment.
pub fn fig5(store: &ResultsStore) -> String {
    let mut out = String::from("## Fig. 5 — learned vs manual mixed precision\n\n");
    table_header(&mut out, &["Task", "Budget bits", "Manual acc", "Learned acc"]);
    for (arch, dataset) in [("gcn", "synth-cora"), ("gin", "synth-citeseer")] {
        for budget in [2.2f64, 3.0] {
            let manual = store.find_where(|e| {
                e.dataset == dataset && e.arch == arch && e.method == "manual"
                    && (e.manual_avg_bits - budget).abs() < 1e-6
            });
            let learned = store.find_where(|e| {
                e.dataset == dataset && e.arch == arch && e.method == "a2q"
                    && (e.target_avg_bits - budget).abs() < 1e-6
            });
            let m = ResultsStore::aggregate(&manual)
                .map(|(m, _, _)| format!("{:.1}%", m * 100.0))
                .unwrap_or_else(|| "—".into());
            let l = ResultsStore::aggregate(&learned)
                .map(|(m, _, _)| format!("{:.1}%", m * 100.0))
                .unwrap_or_else(|| "—".into());
            if m != "—" || l != "—" {
                let _ = writeln!(
                    out,
                    "| {}-{dataset} | {budget:.1} | {m} | {l} |",
                    arch.to_uppercase()
                );
            }
        }
    }
    out
}

/// Render one table spec.
pub fn render_table(spec: TableSpec, store: &ResultsStore, artifacts: &Path) -> String {
    match spec {
        TableSpec::Table1 => table1(store, artifacts),
        TableSpec::Table2 => table2(store, artifacts),
        TableSpec::Table3 => table3(store),
        TableSpec::Table6 => table6(artifacts),
        TableSpec::Table11 => table11(store),
        TableSpec::Table13 => table13(store),
        TableSpec::Table16 => table16(store),
        TableSpec::Fig5 => fig5(store),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(TableSpec::parse("table1"), Some(TableSpec::Table1));
        assert_eq!(TableSpec::parse("fig5"), Some(TableSpec::Fig5));
        assert_eq!(TableSpec::parse("bogus"), None);
        assert_eq!(TableSpec::all().len(), 8);
    }

    #[test]
    fn empty_store_renders_headers_only() {
        let store = ResultsStore::default();
        let t = table3(&store);
        assert!(t.contains("| Model | Config |"));
        let t11 = table11(&store);
        assert!(t11.contains("| m |"));
    }
}
