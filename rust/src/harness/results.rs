//! Results store: parsed training-result JSONs.

use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::util::json::{self, Json};
use crate::util::stats;

/// One training run's recorded outcome.
#[derive(Debug, Clone)]
pub struct ResultEntry {
    pub path: PathBuf,
    pub dataset: String,
    pub arch: String,
    pub method: String,
    pub seed: usize,
    pub layers: usize,
    pub skip: bool,
    pub nns_m: usize,
    pub learn_bits: bool,
    pub learn_step: bool,
    pub manual_avg_bits: f64,
    pub target_avg_bits: f64,
    pub accuracy: f64,
    pub metric_name: String,
    pub avg_bits: f64,
    pub compression: f64,
    pub grad_zero_frac: f64,
    pub bits_hist: Vec<usize>,
    pub raw: Json,
}

impl ResultEntry {
    fn parse(path: &Path) -> Result<ResultEntry> {
        let j = json::parse_file(path)?;
        let cfg = j.req("config")?;
        Ok(ResultEntry {
            path: path.to_path_buf(),
            dataset: cfg.req_str("dataset")?.to_string(),
            arch: cfg.req_str("arch")?.to_string(),
            method: cfg.req_str("method")?.to_string(),
            seed: cfg.req_usize("seed")?,
            layers: cfg.req_usize("layers")?,
            skip: cfg.get("skip").and_then(|v| v.as_bool()).unwrap_or(false),
            nns_m: cfg.get("nns_m").and_then(|v| v.as_usize()).unwrap_or(0),
            learn_bits: cfg
                .get("learn_bits")
                .and_then(|v| v.as_bool())
                .unwrap_or(true),
            learn_step: cfg
                .get("learn_step")
                .and_then(|v| v.as_bool())
                .unwrap_or(true),
            manual_avg_bits: cfg
                .get("manual_avg_bits")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            target_avg_bits: cfg
                .get("target_avg_bits")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            accuracy: j.req_f64("accuracy")?,
            metric_name: j.req_str("metric_name")?.to_string(),
            avg_bits: j.req_f64("avg_bits")?,
            compression: j.req_f64("compression")?,
            grad_zero_frac: j
                .get("grad_zero_frac")
                .and_then(|v| v.as_f64())
                .unwrap_or(-1.0),
            bits_hist: j
                .get("bits_hist")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default(),
            raw: j,
        })
    }

    /// Path of the sibling `.bits.bin` (exported for a2q cells).
    pub fn bits_path(&self) -> PathBuf {
        let mut p = self.path.clone();
        p.set_extension("");
        let s = p.to_string_lossy().into_owned();
        PathBuf::from(format!("{s}.bits.bin"))
    }
}

/// All parsed results under `artifacts/results`.
#[derive(Debug, Clone, Default)]
pub struct ResultsStore {
    pub entries: Vec<ResultEntry>,
}

impl ResultsStore {
    pub fn load(artifacts: &Path) -> Result<ResultsStore> {
        let dir = artifacts.join("results");
        let mut entries = Vec::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(&dir)? {
                let path = entry?.path();
                if path.extension().and_then(|e| e.to_str()) == Some("json") {
                    match ResultEntry::parse(&path) {
                        Ok(e) => entries.push(e),
                        Err(err) => {
                            eprintln!("a2q: skipping result {}: {err}", path.display());
                        }
                    }
                }
            }
        }
        entries.sort_by(|a, b| a.path.cmp(&b.path));
        Ok(ResultsStore { entries })
    }

    /// All entries matching (dataset, arch, method) with default ablation
    /// flags (learnable bits+step, no manual assignment).
    pub fn find(&self, dataset: &str, arch: &str, method: &str) -> Vec<&ResultEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.dataset == dataset
                    && e.arch == arch
                    && e.method == method
                    && e.learn_bits
                    && e.learn_step
                    && e.manual_avg_bits == 0.0
            })
            .collect()
    }

    pub fn find_where<F: Fn(&ResultEntry) -> bool>(&self, pred: F) -> Vec<&ResultEntry> {
        self.entries.iter().filter(|e| pred(e)).collect()
    }

    /// Mean ± std of accuracy over seeds, plus mean avg-bits.
    pub fn aggregate(entries: &[&ResultEntry]) -> Option<(f64, f64, f64)> {
        if entries.is_empty() {
            return None;
        }
        let accs: Vec<f64> = entries.iter().map(|e| e.accuracy).collect();
        let bits: Vec<f64> = entries.iter().map(|e| e.avg_bits).collect();
        Some((stats::mean(&accs), stats::std_dev(&accs), stats::mean(&bits)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_result(dir: &Path, tag: &str, dataset: &str, method: &str, acc: f64) {
        let json = format!(
            r#"{{"config": {{"dataset": "{dataset}", "arch": "gcn", "method": "{method}",
                "seed": 0, "layers": 2, "nns_m": 0, "learn_bits": true,
                "learn_step": true, "manual_avg_bits": 0.0, "target_avg_bits": 2.0}},
                "accuracy": {acc}, "metric_name": "accuracy", "avg_bits": 2.0,
                "compression": 16.0, "bits_hist": [1, 2, 3], "grad_zero_frac": 0.5}}"#
        );
        std::fs::write(dir.join(format!("{tag}.json")), json).unwrap();
    }

    #[test]
    fn loads_and_filters() {
        let root = std::env::temp_dir().join(format!("a2q_results_{}", std::process::id()));
        let dir = root.join("results");
        std::fs::create_dir_all(&dir).unwrap();
        write_result(&dir, "a", "synth-cora", "a2q", 0.8);
        write_result(&dir, "b", "synth-cora", "fp32", 0.82);
        write_result(&dir, "c", "synth-pubmed", "a2q", 0.7);
        std::fs::write(dir.join("garbage.json"), "{not json").unwrap();

        let store = ResultsStore::load(&root).unwrap();
        assert_eq!(store.entries.len(), 3); // garbage skipped
        let found = store.find("synth-cora", "gcn", "a2q");
        assert_eq!(found.len(), 1);
        let (mean, std, bits) = ResultsStore::aggregate(&found).unwrap();
        assert_eq!(mean, 0.8);
        assert_eq!(std, 0.0);
        assert_eq!(bits, 2.0);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn aggregate_empty_is_none() {
        assert!(ResultsStore::aggregate(&[]).is_none());
    }
}
