//! Table/figure regeneration harness.
//!
//! Reads the training results (`artifacts/results/*.json`, written by
//! `python -m compile.experiments`), the exported bit vectors
//! (`*.bits.bin`) and the datasets, runs the accelerator simulator for the
//! speedup/energy columns, and renders every table and figure of the paper
//! (DESIGN.md §4 experiment index) as markdown + CSV.

pub mod figures;
pub mod results;
pub mod tables;

pub use results::{ResultEntry, ResultsStore};
