//! Small statistics helpers shared by the bench harness and serving metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 for < 2 samples).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx.sqrt() * dy.sqrt())
    }
}

/// Online histogram with fixed log-spaced latency buckets (µs scale).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket upper bounds in µs
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum_us: f64,
    n: u64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        // 1µs .. ~100s, 1-2-5 sequence
        let mut bounds = Vec::new();
        let mut base = 1.0;
        while base < 1e8 {
            for m in [1.0, 2.0, 5.0] {
                bounds.push(base * m);
            }
            base *= 10.0;
        }
        let n = bounds.len();
        LatencyHistogram {
            bounds,
            counts: vec![0; n + 1],
            sum_us: 0.0,
            n: 0,
            max_us: 0.0,
        }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, dur: std::time::Duration) {
        self.record_us(dur.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum_us += us;
        self.n += 1;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean_us(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_us / self.n as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Approximate percentile from bucket boundaries.
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.n as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.bounds.get(i).copied().unwrap_or(self.max_us);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.sum_us += other.sum_us;
        self.n += other.n;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0, 5.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [3.0, 2.0, 1.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentile_monotone() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record_us(i as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.percentile_us(50.0) <= h.percentile_us(99.0));
        assert!(h.mean_us() > 400.0 && h.mean_us() < 600.0);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_us(), 1000.0);
    }
}
