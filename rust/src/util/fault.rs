//! Deterministic fault injection (`A2Q_FAULTS=<seed>:<spec>`).
//!
//! Named injection sites — `fault::point("persist.wal_append")` — are
//! no-ops unless a schedule is armed, either programmatically
//! ([`arm`]) or from the environment on first use.  A schedule is one
//! replayable line, in the spirit of `A2Q_PROP_SEED`:
//!
//! ```text
//! A2Q_FAULTS=<seed>:<site>=<action>@<prob>[;<site>=<action>@<prob>...]
//! ```
//!
//! where `<action>` is `err` (the site returns [`Error::Fault`]),
//! `panic` (the site panics with a message carrying the replay line),
//! or `delay:<ms>` (the site sleeps, then succeeds), and `<prob>` is a
//! probability in (0, 1].  Whether a given *hit* of a site fires is a
//! pure function of `(seed, site, hit index)` — per-site hit counters
//! make the decision sequence independent of how threads interleave
//! *across* sites, so a chaos run is replayable from the one line even
//! though the serving stack is concurrent.
//!
//! The site registry lives in the README's "Fault injection &
//! supervision" section; a2q-lint rule R7 checks that every
//! `fault::point("…")` call site in the tree uses a unique, registered
//! name.  With `A2Q_FAULTS` unset every site costs one atomic load and
//! nothing else.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Once, RwLock};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::util::rng::Rng;

const STATE_UNINIT: u8 = 0;
const STATE_INERT: u8 = 1;
const STATE_ARMED: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(STATE_UNINIT);
static ENV_INIT: Once = Once::new();
static SCHEDULE: RwLock<Option<Schedule>> = RwLock::new(None);

/// One `site=action@prob` rule of an armed schedule.
#[derive(Debug)]
struct Rule {
    site: String,
    action: Action,
    prob: f64,
    /// Number of times this site has been hit since arming; the
    /// pre-increment value indexes the deterministic fire decision.
    hits: AtomicU64,
}

#[derive(Debug, Clone, PartialEq)]
enum Action {
    Err,
    Panic,
    Delay(Duration),
}

#[derive(Debug)]
struct Schedule {
    seed: u64,
    spec: String,
    rules: Vec<Rule>,
    /// Set when `A2Q_FAULTS` was present but malformed: every site then
    /// returns this config error, so a typo surfaces loudly at the
    /// first injection point instead of silently disarming the run.
    broken: Option<String>,
}

fn schedule_read() -> std::sync::RwLockReadGuard<'static, Option<Schedule>> {
    SCHEDULE.read().unwrap_or_else(|e| e.into_inner())
}

fn schedule_write() -> std::sync::RwLockWriteGuard<'static, Option<Schedule>> {
    SCHEDULE.write().unwrap_or_else(|e| e.into_inner())
}

/// Fault-injection site.  Returns `Ok(())` unless a schedule is armed
/// and this hit of `site` fires an `err` action; a `panic` action
/// panics (the message carries the replay line); `delay` sleeps first.
#[inline]
pub fn point(site: &str) -> Result<()> {
    // fast path: one atomic load when nothing was ever armed
    let state = STATE.load(Ordering::SeqCst);
    if state == STATE_INERT {
        return Ok(());
    }
    if state == STATE_UNINIT {
        ENV_INIT.call_once(init_from_env);
        if STATE.load(Ordering::SeqCst) != STATE_ARMED {
            return Ok(());
        }
    }
    fire(site)
}

/// Arm a schedule programmatically (tests, benches).  Replaces any
/// previously armed schedule and resets all hit counters.
pub fn arm(seed: u64, spec: &str) -> Result<()> {
    // claim the env-init Once so a concurrent first `point` can never
    // clobber an explicit arm with the environment's schedule
    ENV_INIT.call_once(|| {});
    let rules = parse_spec(spec)?;
    *schedule_write() = Some(Schedule {
        seed,
        spec: spec.to_string(),
        rules,
        broken: None,
    });
    STATE.store(STATE_ARMED, Ordering::SeqCst);
    Ok(())
}

/// Disarm: every site becomes inert again.
pub fn disarm() {
    ENV_INIT.call_once(|| {});
    *schedule_write() = None;
    STATE.store(STATE_INERT, Ordering::SeqCst);
}

/// The replay line of the armed schedule (`A2Q_FAULTS=<seed>:<spec>`),
/// or `None` when disarmed.  Chaos tests print this on entry so any
/// failure is reproducible by exporting the one line.
pub fn active() -> Option<String> {
    if STATE.load(Ordering::SeqCst) != STATE_ARMED {
        return None;
    }
    schedule_read()
        .as_ref()
        .map(|s| format!("A2Q_FAULTS={}:{}", s.seed, s.spec))
}

fn init_from_env() {
    match std::env::var("A2Q_FAULTS") {
        Ok(v) if !v.trim().is_empty() => match parse_env(v.trim()) {
            Ok((seed, spec, rules)) => {
                *schedule_write() = Some(Schedule {
                    seed,
                    spec,
                    rules,
                    broken: None,
                });
                STATE.store(STATE_ARMED, Ordering::SeqCst);
            }
            Err(e) => {
                *schedule_write() = Some(Schedule {
                    seed: 0,
                    spec: v.trim().to_string(),
                    rules: Vec::new(),
                    broken: Some(format!("{e}")),
                });
                STATE.store(STATE_ARMED, Ordering::SeqCst);
            }
        },
        _ => STATE.store(STATE_INERT, Ordering::SeqCst),
    }
}

fn parse_env(value: &str) -> Result<(u64, String, Vec<Rule>)> {
    let (seed_s, spec) = value.split_once(':').ok_or_else(|| {
        Error::config(format!(
            "A2Q_FAULTS must be '<seed>:<site>=<action>@<prob>[;...]', got '{value}'"
        ))
    })?;
    let seed: u64 = seed_s.trim().parse().map_err(|_| {
        Error::config(format!("A2Q_FAULTS seed '{seed_s}' is not a u64"))
    })?;
    let rules = parse_spec(spec)?;
    Ok((seed, spec.to_string(), rules))
}

fn parse_spec(spec: &str) -> Result<Vec<Rule>> {
    let mut rules: Vec<Rule> = Vec::new();
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (site, rest) = part.split_once('=').ok_or_else(|| {
            Error::config(format!("fault rule '{part}' missing '=' (want site=action@prob)"))
        })?;
        let site = site.trim();
        validate_site(site)?;
        let (action_s, prob_s) = rest.split_once('@').ok_or_else(|| {
            Error::config(format!("fault rule '{part}' missing '@' (want site=action@prob)"))
        })?;
        let action = parse_action(action_s.trim())?;
        let prob: f64 = prob_s.trim().parse().map_err(|_| {
            Error::config(format!("fault probability '{prob_s}' is not a float"))
        })?;
        if !(prob > 0.0 && prob <= 1.0) {
            return Err(Error::config(format!(
                "fault probability {prob} out of (0, 1]"
            )));
        }
        if rules.iter().any(|r| r.site == site) {
            return Err(Error::config(format!("duplicate fault site '{site}' in spec")));
        }
        rules.push(Rule {
            site: site.to_string(),
            action,
            prob,
            hits: AtomicU64::new(0),
        });
    }
    if rules.is_empty() {
        return Err(Error::config("empty fault spec (no rules)"));
    }
    Ok(rules)
}

fn parse_action(s: &str) -> Result<Action> {
    if s == "err" {
        return Ok(Action::Err);
    }
    if s == "panic" {
        return Ok(Action::Panic);
    }
    if let Some(ms) = s.strip_prefix("delay:") {
        let ms: u64 = ms.trim().parse().map_err(|_| {
            Error::config(format!("fault delay '{ms}' is not a millisecond count"))
        })?;
        return Ok(Action::Delay(Duration::from_millis(ms)));
    }
    Err(Error::config(format!(
        "unknown fault action '{s}' (want err | panic | delay:<ms>)"
    )))
}

/// Site names mirror the a2q-lint R7 registry grammar: two or more
/// dot-separated lowercase segments, each `[a-z][a-z0-9_]*`.
fn validate_site(site: &str) -> Result<()> {
    let segs: Vec<&str> = site.split('.').collect();
    let seg_ok = |s: &&str| {
        let mut chars = s.chars();
        matches!(chars.next(), Some('a'..='z'))
            && chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
    };
    if segs.len() < 2 || !segs.iter().all(seg_ok) {
        return Err(Error::config(format!(
            "fault site '{site}' invalid (want dot-separated lowercase, e.g. persist.wal_append)"
        )));
    }
    Ok(())
}

#[inline]
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn fire(site: &str) -> Result<()> {
    let guard = schedule_read();
    let sched = match guard.as_ref() {
        Some(s) => s,
        None => return Ok(()),
    };
    if let Some(msg) = &sched.broken {
        return Err(Error::config(format!(
            "A2Q_FAULTS is malformed: {msg} (value '{}')",
            sched.spec
        )));
    }
    let rule = match sched.rules.iter().find(|r| r.site == site) {
        Some(r) => r,
        None => return Ok(()),
    };
    let hit = rule.hits.fetch_add(1, Ordering::SeqCst);
    // pure function of (seed, site, hit index): replayable regardless of
    // thread interleaving across sites
    let mix = sched.seed
        ^ fnv1a(site).rotate_left(17)
        ^ hit.wrapping_mul(0xa24baed4963ee407);
    if Rng::new(mix).f64() >= rule.prob {
        return Ok(());
    }
    let replay = format!("A2Q_FAULTS={}:{}", sched.seed, sched.spec);
    match rule.action {
        Action::Err => Err(Error::fault(format!(
            "injected fault at '{site}' (hit {hit}; replay {replay})"
        ))),
        Action::Panic => {
            drop(guard);
            panic!("injected panic at '{site}' (hit {hit}; replay {replay})");
        }
        Action::Delay(d) => {
            drop(guard);
            std::thread::sleep(d);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // Arming is process-global; serialize the tests that touch it.  The
    // sites used here are `selftest.*` names that no production code
    // path ever hits, so a concurrently running server test sees no
    // injected faults even while one of these is armed.
    static LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn unarmed_sites_are_inert() {
        let _g = locked();
        disarm();
        for _ in 0..100 {
            assert!(point("selftest.alpha").is_ok());
        }
        assert!(active().is_none());
    }

    #[test]
    fn err_action_fires_deterministically() {
        let _g = locked();
        let pattern = |seed: u64| -> Vec<bool> {
            arm(seed, "selftest.alpha=err@0.5").unwrap();
            let p = (0..64).map(|_| point("selftest.alpha").is_err()).collect();
            disarm();
            p
        };
        let a = pattern(42);
        let b = pattern(42);
        assert_eq!(a, b, "same seed must fire the same hit pattern");
        assert!(a.iter().any(|&f| f), "p=0.5 over 64 hits should fire");
        assert!(a.iter().any(|&f| !f), "p=0.5 over 64 hits should also pass");
        let c = pattern(43);
        assert_ne!(a, c, "different seed should differ somewhere");
    }

    #[test]
    fn probability_one_always_fires_and_unlisted_sites_pass() {
        let _g = locked();
        arm(7, "selftest.alpha=err@1.0").unwrap();
        for _ in 0..16 {
            let e = point("selftest.alpha").unwrap_err();
            let msg = format!("{e}");
            assert!(msg.contains("selftest.alpha"), "{msg}");
            assert!(msg.contains("A2Q_FAULTS=7:selftest.alpha=err@1.0"), "{msg}");
            assert!(point("selftest.other_site").is_ok());
        }
        assert_eq!(
            active().as_deref(),
            Some("A2Q_FAULTS=7:selftest.alpha=err@1.0")
        );
        disarm();
    }

    #[test]
    fn panic_action_panics_with_replay_line() {
        let _g = locked();
        arm(3, "selftest.boom=panic@1.0").unwrap();
        let r = std::panic::catch_unwind(|| point("selftest.boom"));
        disarm();
        let payload = r.expect_err("panic action must panic");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("selftest.boom"), "{msg}");
        assert!(msg.contains("A2Q_FAULTS=3:"), "{msg}");
    }

    #[test]
    fn delay_action_sleeps_then_passes() {
        let _g = locked();
        arm(1, "selftest.slow=delay:20@1.0").unwrap();
        let t0 = std::time::Instant::now();
        assert!(point("selftest.slow").is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(20));
        disarm();
    }

    #[test]
    fn spec_parse_rejects_malformed() {
        let _g = locked();
        for bad in [
            "",
            "no_equals",
            "site.a=err",          // missing @prob
            "site.a=err@0.0",      // prob out of (0, 1]
            "site.a=err@1.5",
            "site.a=boom@0.5",     // unknown action
            "site.a=delay:x@0.5",  // bad delay
            "Site.A=err@0.5",      // uppercase site
            "nodot=err@0.5",       // single segment
            "site.a=err@0.5;site.a=err@0.5", // duplicate site
        ] {
            assert!(parse_spec(bad).is_err(), "'{bad}' should not parse");
        }
        let rules = parse_spec("a.b=err@0.25; c.d=panic@1.0 ;e.f=delay:5@0.5").unwrap();
        assert_eq!(rules.len(), 3);
        assert_eq!(rules[1].action, Action::Panic);
        assert_eq!(rules[2].action, Action::Delay(Duration::from_millis(5)));
    }

    #[test]
    fn env_form_parses_seed_prefix() {
        let (seed, spec, rules) = parse_env("1337:a.b=err@0.5;c.d=delay:10@1.0").unwrap();
        assert_eq!(seed, 1337);
        assert_eq!(spec, "a.b=err@0.5;c.d=delay:10@1.0");
        assert_eq!(rules.len(), 2);
        assert!(parse_env("noseed").is_err());
        assert!(parse_env("x:a.b=err@0.5").is_err());
    }
}
