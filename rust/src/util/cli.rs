//! Declarative command-line parser (clap is not in the offline vendor set).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, and positional arguments; generates `--help` text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Specification of one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Specification of a subcommand.
#[derive(Debug, Clone, Default)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional: Vec<(&'static str, &'static str)>,
}

impl CommandSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        CommandSpec {
            name,
            about,
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn pos(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help));
        self
    }
}

/// Parsed arguments of a matched subcommand.
#[derive(Debug, Clone)]
pub struct Matches {
    pub command: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Matches {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| Error::config(format!("missing required option --{key}")))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        let v = self.req(key)?;
        v.parse()
            .map_err(|_| Error::config(format!("--{key}: '{v}' is not an integer")))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        let v = self.req(key)?;
        v.parse()
            .map_err(|_| Error::config(format!("--{key}: '{v}' is not a number")))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Top-level application parser.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

impl App {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        App {
            name,
            about,
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, spec: CommandSpec) -> Self {
        self.commands.push(spec);
        self
    }

    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        out.push_str("\nRun `a2q <command> --help` for command options.\n");
        out
    }

    pub fn command_help(&self, spec: &CommandSpec) -> String {
        let mut out = format!("{} {} — {}\n\nOPTIONS:\n", self.name, spec.name, spec.about);
        for o in &spec.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = o.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            out.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, kind));
        }
        for (name, help) in &spec.positional {
            out.push_str(&format!("  <{name}>  {help}\n"));
        }
        out
    }

    /// Parse argv (excluding the binary name).
    pub fn parse(&self, args: &[String]) -> Result<Matches> {
        if args.is_empty() || args[0] == "--help" || args[0] == "-h" {
            return Err(Error::config(self.help()));
        }
        let cmd_name = &args[0];
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name.as_str())
            .ok_or_else(|| {
                Error::config(format!("unknown command '{cmd_name}'\n\n{}", self.help()))
            })?;

        let mut values = BTreeMap::new();
        for o in &spec.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut flags = Vec::new();
        let mut positional = Vec::new();

        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(Error::config(self.command_help(spec)));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = spec.opts.iter().find(|o| o.name == key).ok_or_else(|| {
                    Error::config(format!(
                        "unknown option --{key} for '{}'\n\n{}",
                        spec.name,
                        self.command_help(spec)
                    ))
                })?;
                if opt.is_flag {
                    flags.push(key.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    Error::config(format!("--{key} expects a value"))
                                })?
                        }
                    };
                    values.insert(key.to_string(), val);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }

        for o in &spec.opts {
            if !o.is_flag && o.default.is_none() && !values.contains_key(o.name) {
                return Err(Error::config(format!(
                    "missing required option --{} for '{}'",
                    o.name, spec.name
                )));
            }
        }

        Ok(Matches {
            command: spec.name.to_string(),
            values,
            flags,
            positional,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("a2q", "test app").command(
            CommandSpec::new("serve", "run server")
                .opt("port", "8080", "listen port")
                .opt_req("model", "model name")
                .flag("verbose", "log more")
                .pos("input", "input file"),
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let m = app()
            .parse(&argv(&["serve", "--model", "gcn", "--verbose", "file.bin"]))
            .unwrap();
        assert_eq!(m.command, "serve");
        assert_eq!(m.get("port"), Some("8080")); // default
        assert_eq!(m.get("model"), Some("gcn"));
        assert!(m.has_flag("verbose"));
        assert_eq!(m.positional, vec!["file.bin"]);
    }

    #[test]
    fn equals_syntax() {
        let m = app().parse(&argv(&["serve", "--model=gat", "--port=99"])).unwrap();
        assert_eq!(m.get("model"), Some("gat"));
        assert_eq!(m.get_usize("port").unwrap(), 99);
    }

    #[test]
    fn missing_required_errors() {
        assert!(app().parse(&argv(&["serve"])).is_err());
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(app().parse(&argv(&["nope"])).is_err());
        assert!(app()
            .parse(&argv(&["serve", "--model", "m", "--bogus", "1"]))
            .is_err());
    }

    #[test]
    fn help_is_error_carrying_text() {
        let err = app().parse(&argv(&["--help"])).unwrap_err();
        assert!(format!("{err}").contains("COMMANDS"));
    }
}
