//! Hand-rolled substrates.
//!
//! The offline vendor set only covers the `xla` crate's closure, so the
//! usual ecosystem crates (serde_json, clap, rand, criterion, proptest,
//! rayon) are unavailable.  Per the reproduction mandate ("build every
//! substrate"), this module implements the pieces the system needs:
//!
//! * [`json`]   — JSON parser/serializer (manifests, results, tables)
//! * [`cli`]    — declarative argument parser for the `a2q` binary
//! * [`rng`]    — SplitMix64 / xoshiro256++ PRNG (graph generators, benches)
//! * [`bench`]  — criterion-style micro-benchmark harness with robust stats
//! * [`fault`]  — seeded deterministic fault injection (`A2Q_FAULTS`)
//! * [`prop`]   — mini property-testing framework (shrinking by halving)
//! * [`stats`]  — mean/std/percentile helpers shared by bench + metrics
//! * [`threadpool`] — fixed worker pool used by the coordinator

pub mod bench;
pub mod cli;
pub mod fault;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
