//! Fixed-size worker thread pool (std-only; tokio is not in the offline
//! vendor set).  Used by the serving coordinator's worker stage and by the
//! parallel sections of the harness.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use crate::tensor::simd::{self, Isa};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared FIFO of jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let in_flight = Arc::clone(&in_flight);
            workers.push(
                thread::Builder::new()
                    .name(format!("a2q-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            in_flight,
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yields) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallelism budget for the data-parallel kernels (matmul, aggregation).
///
/// `threads` caps the worker count; `min_rows_per_task` is the smallest row
/// block worth shipping to a worker — inputs smaller than two such blocks
/// run serially (spawning scoped threads costs ~10µs, which dominates tiny
/// kernels).  `simd` selects the instruction-set path the inner loops run
/// on — by default the process-wide [`simd::active`] decision (best
/// available ISA, overridable via `A2Q_SIMD`); tests pin it to cross
/// scalar/SIMD explicitly.  The serving stack owns the budget:
/// `runtime::Engine` and `coordinator::NativeExecutor` both carry a
/// `ParallelConfig` and pass it down, so concurrent request handling,
/// intra-op parallelism and kernel dispatch are all controlled in one
/// place.  Threading and ISA are orthogonal: every (threads × simd)
/// combination is bitwise identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Maximum worker threads for one kernel invocation (>= 1).
    pub threads: usize,
    /// Minimum output rows per task; also the serial-fallback threshold.
    pub min_rows_per_task: usize,
    /// Instruction-set dispatch for the inner kernels.
    pub simd: Isa,
}

impl Default for ParallelConfig {
    fn default() -> Self {
        ParallelConfig {
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            min_rows_per_task: 64,
            simd: simd::active(),
        }
    }
}

impl ParallelConfig {
    /// Single-threaded configuration (the pre-parallel behaviour); still
    /// runs the active SIMD dispatch — thread count and ISA are orthogonal.
    pub fn serial() -> ParallelConfig {
        ParallelConfig {
            threads: 1,
            min_rows_per_task: usize::MAX,
            simd: simd::active(),
        }
    }

    pub fn with_threads(threads: usize) -> ParallelConfig {
        ParallelConfig {
            threads: threads.max(1),
            ..ParallelConfig::default()
        }
    }

    /// Builder-style ISA override (parity tests cross scalar vs active).
    pub fn with_simd(mut self, isa: Isa) -> ParallelConfig {
        self.simd = isa;
        self
    }

    /// Default budget, overridable via `A2Q_THREADS`,
    /// `A2Q_MIN_ROWS_PER_TASK` and `A2Q_SIMD` (used by benches and CI).
    pub fn from_env() -> ParallelConfig {
        let mut cfg = ParallelConfig::default();
        if let Some(t) = std::env::var("A2Q_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            cfg.threads = t.max(1);
        }
        if let Some(r) = std::env::var("A2Q_MIN_ROWS_PER_TASK")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
        {
            cfg.min_rows_per_task = r.max(1);
        }
        cfg
    }

    /// Workers to actually use for `rows` rows of output (1 = stay serial).
    /// A zero `min_rows_per_task` (fields are public) is treated as 1.
    pub fn effective_threads(&self, rows: usize) -> usize {
        let min_rows = self.min_rows_per_task.max(1);
        if self.threads <= 1 || rows < min_rows.saturating_mul(2) {
            return 1;
        }
        self.threads.min(rows / min_rows).max(1)
    }

    /// Row-block length per task: enough blocks for load balancing (about
    /// four per worker) without dropping below `min_rows_per_task`.
    pub fn rows_per_task(&self, rows: usize, threads: usize) -> usize {
        rows.div_ceil(threads.max(1) * 4)
            .max(self.min_rows_per_task.max(1).min(rows.max(1)))
    }
}

static GLOBAL_PARALLEL: Mutex<Option<ParallelConfig>> = Mutex::new(None);
static ENV_PARALLEL: std::sync::OnceLock<ParallelConfig> = std::sync::OnceLock::new();

/// Install the process-wide default budget used by the convenience kernel
/// entry points (`ops::matmul`, `EdgeForm::aggregate`, …).  Explicit
/// `*_with` variants ignore this.
pub fn set_global_parallelism(cfg: ParallelConfig) {
    *GLOBAL_PARALLEL.lock().unwrap() = Some(cfg);
}

/// The process-wide default budget.  Until set explicitly this is the
/// env-derived config, parsed once and cached (no getenv on hot paths).
pub fn global_parallelism() -> ParallelConfig {
    if let Some(cfg) = *GLOBAL_PARALLEL.lock().unwrap() {
        return cfg;
    }
    *ENV_PARALLEL.get_or_init(ParallelConfig::from_env)
}

/// Run `f(chunk_index, chunk)` over disjoint contiguous chunks of `data`
/// (each `chunk_len` elements, last one shorter) across `threads` scoped
/// workers.  Chunks are handed out through a shared iterator, so uneven
/// chunk costs self-balance; each output region is owned by exactly one
/// task, so no synchronization is needed on the data itself.
pub fn parallel_for_chunks<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk_len = chunk_len.max(1);
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = threads.max(1).min(n_chunks.max(1));
    if threads == 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let work = Mutex::new(data.chunks_mut(chunk_len).enumerate());
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let next = { work.lock().unwrap().next() };
                match next {
                    Some((i, chunk)) => f(i, chunk),
                    None => break,
                }
            });
        }
    });
}

/// Row-parallel dispatch policy shared by every kernel: interpret `data`
/// as `rows` rows of `row_width` contiguous elements, apply `cfg`'s
/// serial-fallback and chunk-size policy, and invoke `f(first_row, chunk)`
/// over disjoint row ranges (serially when below the threshold).
pub fn parallel_rows<T, F>(
    cfg: &ParallelConfig,
    rows: usize,
    row_width: usize,
    data: &mut [T],
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    debug_assert_eq!(data.len(), rows * row_width);
    if rows == 0 || row_width == 0 {
        return;
    }
    let threads = cfg.effective_threads(rows);
    let rpt = if threads == 1 {
        rows
    } else {
        cfg.rows_per_task(rows, threads)
    };
    parallel_for_chunks(data, rpt * row_width, threads, move |ci, chunk| {
        f(ci * rpt, chunk)
    });
}

/// Run `f(i)` for i in 0..n across `threads` scoped threads, collecting
/// results in order.  Convenience for data-parallel harness sections.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let out_ptr = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let v = f(i);
                let mut guard = out_ptr.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain via join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(50, 4, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        assert_eq!(parallel_map(3, 1, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn parallel_for_chunks_covers_all_elements() {
        for threads in [1usize, 2, 4] {
            let mut data = vec![0u32; 1000];
            parallel_for_chunks(&mut data, 64, threads, |ci, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (ci * 64 + j) as u32;
                }
            });
            assert!(data.iter().enumerate().all(|(i, &v)| v == i as u32));
        }
    }

    #[test]
    fn parallel_for_chunks_empty_and_tiny() {
        let mut empty: Vec<u8> = Vec::new();
        parallel_for_chunks(&mut empty, 8, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![7u8];
        parallel_for_chunks(&mut one, 8, 4, |_, c| c[0] += 1);
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn effective_threads_respects_serial_threshold() {
        let cfg = ParallelConfig {
            threads: 8,
            min_rows_per_task: 64,
            ..ParallelConfig::serial()
        };
        assert_eq!(cfg.effective_threads(10), 1); // too small
        assert_eq!(cfg.effective_threads(127), 1); // below 2 blocks
        assert!(cfg.effective_threads(1024) > 1);
        assert!(cfg.effective_threads(1024) <= 8);
        assert_eq!(ParallelConfig::serial().effective_threads(1 << 20), 1);
    }

    #[test]
    fn rows_per_task_never_zero() {
        let cfg = ParallelConfig {
            threads: 4,
            min_rows_per_task: 64,
            ..ParallelConfig::serial()
        };
        assert!(cfg.rows_per_task(0, 4) >= 1);
        assert!(cfg.rows_per_task(1000, 4) >= 62);
        assert!(cfg.rows_per_task(1_000_000, 4) >= 64);
    }

    #[test]
    fn zero_min_rows_does_not_panic() {
        let cfg = ParallelConfig {
            threads: 4,
            min_rows_per_task: 0,
            ..ParallelConfig::serial()
        };
        assert!(cfg.effective_threads(100) >= 1);
        assert!(cfg.rows_per_task(100, 4) >= 1);
        assert!(cfg.rows_per_task(0, 0) >= 1);
    }
}
