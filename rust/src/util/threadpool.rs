//! Fixed-size worker thread pool (std-only; tokio is not in the offline
//! vendor set).  Used by the serving coordinator's worker stage and by the
//! parallel sections of the harness.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming a shared FIFO of jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::with_capacity(threads);
        for i in 0..threads {
            let rx = Arc::clone(&rx);
            let in_flight = Arc::clone(&in_flight);
            workers.push(
                thread::Builder::new()
                    .name(format!("a2q-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            in_flight,
        }
    }

    /// Enqueue a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool alive")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yields) until all submitted jobs finished.
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel -> workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for i in 0..n across `threads` scoped threads, collecting
/// results in order.  Convenience for data-parallel harness sections.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let threads = threads.max(1).min(n.max(1));
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let out_ptr = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let v = f(i);
                let mut guard = out_ptr.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_workers() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for queue drain via join
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(50, 4, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        assert_eq!(parallel_map(3, 1, |i| i + 1), vec![1, 2, 3]);
    }
}
