//! Minimal JSON parser / serializer.
//!
//! Used for model manifests, result files and table output.  Supports the
//! full JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null); numbers are stored as `f64` (adequate: manifests carry
//! shapes, offsets and metrics, all within 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.field` access that produces a descriptive error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::json(format!("missing field '{key}'")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::json(format!("field '{key}' is not a string")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::json(format!("field '{key}' is not a number")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }

    // --------------------------------------------------------- construction
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(values: &[f64]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Num(*v)).collect())
    }

    pub fn arr_str(values: &[&str]) -> Json {
        Json::Arr(values.iter().map(|v| Json::Str(v.to_string())).collect())
    }

    // --------------------------------------------------------- serialization
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if n == n.trunc() && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parsing

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::json(format!("trailing data at byte {}", p.pos)));
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)?;
    parse(&text).map_err(|e| Error::json(format!("{}: {e}", path.display())))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| Error::json("unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            return Err(Error::json(format!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            )));
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(Error::json(format!("invalid literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(Error::json("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(map)),
                c => {
                    return Err(Error::json(format!(
                        "expected ',' or '}}', got '{}'",
                        c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => {
                    return Err(Error::json(format!(
                        "expected ',' or ']', got '{}'",
                        c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| Error::json("bad \\u escape"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => {
                        return Err(Error::json(format!("bad escape '\\{}'", c as char)))
                    }
                },
                _ => {
                    // decode UTF-8 continuation transparently
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(Error::json("truncated utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| Error::json("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| Error::json("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::json(format!("invalid number '{text}'")))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"nested":{"k":-3}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = parse(r#"{"a":[1,{"b":[]}],"c":{}}"#).unwrap();
        let v2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\"b\\c\n\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn req_helpers_error_messages() {
        let v = parse(r#"{"n": 1}"#).unwrap();
        assert!(v.req("missing").is_err());
        assert_eq!(v.req_usize("n").unwrap(), 1);
        assert!(v.req_str("n").is_err());
    }

    #[test]
    fn integer_formatting_has_no_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
