//! Mini property-testing framework (proptest is not in the offline vendor
//! set).
//!
//! A property is a closure over a [`Gen`] (seeded value source).  The runner
//! executes it for `cases` random seeds; on failure it reports the exact
//! failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! // (no_run: the doctest harness lacks the xla_extension rpath)
//! use a2q::util::prop::{property, Gen};
//! property("abs is non-negative", 100, |g: &mut Gen| {
//!     let x = g.f64_range(-10.0, 10.0);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```
//!
//! Two environment knobs, honored by **every** property test in the repo
//! (forward_parity, delta_parity, shard_parity, incremental, and the unit
//! properties) because they are applied inside [`property`] itself:
//!
//! * `A2Q_PROP_SEED=<seed>` — **one-line replay**: run exactly one case
//!   with that seed (the failure message prints it verbatim), e.g.
//!   `A2Q_PROP_SEED=12345 cargo test -q shard_parity`.
//! * `A2Q_PROP_CASES=<n>` — override every property's case count (crank
//!   up for a soak run, turn down for a smoke pass); the per-test number
//!   is the default when unset.

use super::rng::Rng;

/// Seeded value generator handed to properties.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            seed,
        }
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        self.rng.range(lo, hi)
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.rng.normal() as f32
    }

    /// Vector of f32 drawn from N(0, scale).
    pub fn vec_normal(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.normal_f32() * scale).collect()
    }

    /// Vector of uniform f32 in [lo, hi).
    pub fn vec_uniform(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_range(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    /// Access the underlying RNG for custom distributions.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// The effective case count for a property whose in-code default is
/// `default`: `A2Q_PROP_CASES` overrides it process-wide (soak up, smoke
/// down), floored at 1.
pub fn cases(default: u64) -> u64 {
    std::env::var("A2Q_PROP_CASES")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(default)
        .max(1)
}

/// The pinned replay seed, if `A2Q_PROP_SEED` is set.
fn replay_seed() -> Option<u64> {
    std::env::var("A2Q_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
}

const BASE_SEED: u64 = 0xa2a2_0001;

/// Run `f` for [`cases`]`(default_cases)` derived seeds.  Panics on
/// failure naming the failing case's **exact seed**; re-running any test
/// binary with `A2Q_PROP_SEED=<that seed>` executes precisely that one
/// case — a one-line replay, no case counting.
pub fn property<F: FnMut(&mut Gen)>(name: &str, default_cases: u64, mut f: F) {
    let mut run_case = |case: u64, seed: u64| {
        let mut gen = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut gen)
        }));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} — replay this exact \
                 case with A2Q_PROP_SEED={seed}: {msg}"
            );
        }
    };
    if let Some(seed) = replay_seed() {
        // pinned replay: exactly the one failing case, nothing else
        run_case(0, seed);
        return;
    }
    for case in 0..cases(default_cases) {
        let seed = BASE_SEED.wrapping_add(case.wrapping_mul(0x9e3779b97f4a7c15));
        run_case(case, seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("sum symmetric", 50, |g| {
            let a = g.f64_range(-5.0, 5.0);
            let b = g.f64_range(-5.0, 5.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        property("always fails", 3, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn cases_is_floored_at_one() {
        // no set_var here (UB with concurrent getenv in parallel tests);
        // whatever the environment says, the floor must hold
        assert!(cases(7) >= 1);
        assert!(cases(0) >= 1);
    }

    #[test]
    fn gen_ranges_respected() {
        property("ranges", 100, |g| {
            let n = g.usize_range(1, 10);
            assert!((1..10).contains(&n));
            let x = g.f32_range(0.5, 2.0);
            assert!((0.5..2.0).contains(&x));
            let v = g.vec_uniform(n, -1.0, 1.0);
            assert_eq!(v.len(), n);
        });
    }
}
