//! Deterministic PRNG (SplitMix64 seeding + xoshiro256++ core).
//!
//! Used by graph generators, workload synthesis, the property-testing
//! framework and benches.  No external `rand` crate is available offline;
//! this implementation matches the published reference outputs (tested).

/// xoshiro256++ generator with SplitMix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).  Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= lo.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform float in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a child generator (independent stream).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(100, 30);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 30);
    }
}
