//! Criterion-style micro-benchmark harness (criterion itself is not in the
//! offline vendor set).
//!
//! Provides warm-up, adaptive iteration counts targeting a fixed measurement
//! time, robust statistics (median ± MAD, mean ± σ) and a `black_box` to
//! defeat constant folding.  `cargo bench` targets use
//! [`BenchRunner::bench`] and print one line per benchmark:
//!
//! ```text
//! table1/gcn-synth-cora/a2q  time: [median 1.24 ms]  mean 1.25 ms ± 0.03
//! ```

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::stats;

/// Re-export of the standard black box, spelled like criterion's.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's measurements.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        stats::median(&self.samples_ns)
    }
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }
    pub fn std_ns(&self) -> f64 {
        stats::std_dev(&self.samples_ns)
    }
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.median_ns()
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Fast profile when A2Q_BENCH_FAST is set (CI), fuller otherwise.
        if std::env::var("A2Q_BENCH_FAST").is_ok() {
            BenchConfig {
                warmup: Duration::from_millis(50),
                measure: Duration::from_millis(200),
                samples: 10,
            }
        } else {
            BenchConfig {
                warmup: Duration::from_millis(300),
                measure: Duration::from_secs(1),
                samples: 20,
            }
        }
    }
}

impl BenchConfig {
    /// Smoke profile for the CI `--quick` mode: a couple of short samples,
    /// just enough to prove the kernel runs and produce a nonzero number.
    pub fn quick() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            samples: 3,
        }
    }

    /// True when `--quick` was passed to the bench binary (cargo forwards
    /// arguments after `--`; the libtest-style `--bench` flag is ignored).
    pub fn quick_requested() -> bool {
        std::env::args().any(|a| a == "--quick")
    }

    /// `quick()` when `--quick` was requested, `default()` otherwise.
    pub fn from_args() -> BenchConfig {
        if Self::quick_requested() {
            Self::quick()
        } else {
            Self::default()
        }
    }
}

/// Runs and records a suite of benchmarks.
pub struct BenchRunner {
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
    /// derived metrics reported alongside timings (name, value, unit)
    pub metrics: Vec<(String, f64, String)>,
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self::new(BenchConfig::default())
    }
}

impl BenchRunner {
    pub fn new(cfg: BenchConfig) -> Self {
        BenchRunner {
            cfg,
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Benchmark `f`, which must perform one unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up and iteration-count calibration.
        let warmup_end = Instant::now() + self.cfg.warmup;
        let mut calib_iters = 0u64;
        let calib_start = Instant::now();
        while Instant::now() < warmup_end {
            f();
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let budget = self.cfg.measure.as_secs_f64() / self.cfg.samples as f64;
        let iters = ((budget / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples = Vec::with_capacity(self.cfg.samples);
        for _ in 0..self.cfg.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            iters_per_sample: iters,
            samples_ns: samples,
        };
        println!(
            "{name:<52} time: [median {}]  mean {} ± {}",
            fmt_ns(result.median_ns()),
            fmt_ns(result.mean_ns()),
            fmt_ns(result.std_ns()),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Report a derived metric alongside bench output (e.g. simulated
    /// speedup), keeping the bench log single-source.  Metrics are also
    /// recorded for [`Self::write_json`].
    pub fn report_metric(&mut self, name: &str, value: f64, unit: &str) {
        println!("{name:<52} metric: {value:.4} {unit}");
        self.metrics.push((name.to_string(), value, unit.to_string()));
    }

    /// Machine-readable dump of every timing and metric recorded so far
    /// (the `BENCH_*.json` files CI archives for the perf trajectory).
    pub fn to_json(&self) -> Json {
        let benchmarks = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("median_ns", Json::Num(r.median_ns())),
                    ("mean_ns", Json::Num(r.mean_ns())),
                    ("std_ns", Json::Num(r.std_ns())),
                    ("iters_per_sample", Json::Num(r.iters_per_sample as f64)),
                    ("samples", Json::Num(r.samples_ns.len() as f64)),
                ])
            })
            .collect();
        let metrics = self
            .metrics
            .iter()
            .map(|(name, value, unit)| {
                Json::obj(vec![
                    ("name", Json::Str(name.clone())),
                    ("value", Json::Num(*value)),
                    ("unit", Json::Str(unit.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("benchmarks", Json::Arr(benchmarks)),
            ("metrics", Json::Arr(metrics)),
        ])
    }

    /// Write [`Self::to_json`] to `path` (pretty-printed).
    pub fn write_json(&self, path: &std::path::Path) -> crate::error::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        println!("wrote {}", path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            samples: 4,
        }
    }

    #[test]
    fn measures_something_positive() {
        let mut r = BenchRunner::new(fast_cfg());
        let res = r.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(res.median_ns() > 0.0);
        assert_eq!(res.samples_ns.len(), 4);
    }

    #[test]
    fn slower_work_measures_slower() {
        let mut r = BenchRunner::new(fast_cfg());
        let fast = r.bench("fast", || {
            black_box((0..10u64).sum::<u64>());
        })
        .median_ns();
        let slow = r.bench("slow", || {
            black_box((0..10_000u64).sum::<u64>());
        })
        .median_ns();
        assert!(slow > fast * 2.0, "slow {slow} vs fast {fast}");
    }

    #[test]
    fn json_dump_records_benchmarks_and_metrics() {
        let mut r = BenchRunner::new(fast_cfg());
        r.bench("k", || {
            black_box((0..50u64).sum::<u64>());
        });
        r.report_metric("speedup", 2.5, "x");
        let j = r.to_json();
        let benches = j.get("benchmarks").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(benches.len(), 1);
        assert_eq!(benches[0].get("name").and_then(|v| v.as_str()), Some("k"));
        assert!(benches[0].get("median_ns").and_then(|v| v.as_f64()).unwrap() > 0.0);
        let metrics = j.get("metrics").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(metrics[0].get("value").and_then(|v| v.as_f64()), Some(2.5));
        // round-trips through the serializer
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("version").and_then(|v| v.as_f64()), Some(1.0));
    }

    #[test]
    fn quick_config_is_small() {
        let q = BenchConfig::quick();
        assert!(q.measure < BenchConfig::default().measure);
        assert!(q.samples <= 3);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
