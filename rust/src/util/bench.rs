//! Criterion-style micro-benchmark harness (criterion itself is not in the
//! offline vendor set).
//!
//! Provides warm-up, adaptive iteration counts targeting a fixed measurement
//! time, robust statistics (median ± MAD, mean ± σ) and a `black_box` to
//! defeat constant folding.  `cargo bench` targets use
//! [`BenchRunner::bench`] and print one line per benchmark:
//!
//! ```text
//! table1/gcn-synth-cora/a2q  time: [median 1.24 ms]  mean 1.25 ms ± 0.03
//! ```

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

use super::stats;

/// Re-export of the standard black box, spelled like criterion's.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// One benchmark's measurements.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters_per_sample: u64,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        stats::median(&self.samples_ns)
    }
    pub fn mean_ns(&self) -> f64 {
        stats::mean(&self.samples_ns)
    }
    pub fn std_ns(&self) -> f64 {
        stats::std_dev(&self.samples_ns)
    }
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.median_ns()
    }
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Fast profile when A2Q_BENCH_FAST is set (CI), fuller otherwise.
        if std::env::var("A2Q_BENCH_FAST").is_ok() {
            BenchConfig {
                warmup: Duration::from_millis(50),
                measure: Duration::from_millis(200),
                samples: 10,
            }
        } else {
            BenchConfig {
                warmup: Duration::from_millis(300),
                measure: Duration::from_secs(1),
                samples: 20,
            }
        }
    }
}

/// Runs and records a suite of benchmarks.
pub struct BenchRunner {
    cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Default for BenchRunner {
    fn default() -> Self {
        Self::new(BenchConfig::default())
    }
}

impl BenchRunner {
    pub fn new(cfg: BenchConfig) -> Self {
        BenchRunner {
            cfg,
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which must perform one unit of work per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up and iteration-count calibration.
        let warmup_end = Instant::now() + self.cfg.warmup;
        let mut calib_iters = 0u64;
        let calib_start = Instant::now();
        while Instant::now() < warmup_end {
            f();
            calib_iters += 1;
        }
        let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let budget = self.cfg.measure.as_secs_f64() / self.cfg.samples as f64;
        let iters = ((budget / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples = Vec::with_capacity(self.cfg.samples);
        for _ in 0..self.cfg.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            iters_per_sample: iters,
            samples_ns: samples,
        };
        println!(
            "{name:<52} time: [median {}]  mean {} ± {}",
            fmt_ns(result.median_ns()),
            fmt_ns(result.mean_ns()),
            fmt_ns(result.std_ns()),
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Report a derived metric alongside bench output (e.g. simulated
    /// speedup), keeping the bench log single-source.
    pub fn report_metric(&self, name: &str, value: f64, unit: &str) {
        println!("{name:<52} metric: {value:.4} {unit}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            samples: 4,
        }
    }

    #[test]
    fn measures_something_positive() {
        let mut r = BenchRunner::new(fast_cfg());
        let res = r.bench("noop-ish", || {
            black_box((0..100).sum::<u64>());
        });
        assert!(res.median_ns() > 0.0);
        assert_eq!(res.samples_ns.len(), 4);
    }

    #[test]
    fn slower_work_measures_slower() {
        let mut r = BenchRunner::new(fast_cfg());
        let fast = r.bench("fast", || {
            black_box((0..10u64).sum::<u64>());
        })
        .median_ns();
        let slow = r.bench("slow", || {
            black_box((0..10_000u64).sum::<u64>());
        })
        .median_ns();
        assert!(slow > fast * 2.0, "slow {slow} vs fast {fast}");
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
