//! Durable resident state: delta WAL + snapshots of the serving session.
//!
//! A²Q's per-node quantization state *accretes at serve time*: every
//! applied [`GraphDelta`] can append nodes whose `(step, bits)` params are
//! NNS-assigned online and persisted into the resident
//! [`NodeQuantParams`].  Without durability a restart silently discards
//! those assignments, the resident graph, and the epoch history — so this
//! module makes the delta/shard parity guarantee survive a process
//! boundary: **snapshot + WAL-tail replay reproduces served logits
//! bit-for-bit** against the continuously-running executor.
//!
//! ## On-disk layout
//!
//! The state dir holds one *generation* of files at a time (plus, briefly,
//! the next one during rotation):
//!
//! ```text
//! <state-dir>/snapshot-<G>.a2qs   resident state at some epoch (binary, CRC'd)
//! <state-dir>/wal-<G>.log         deltas applied after snapshot G
//! ```
//!
//! A WAL record reuses the wire protocol's framing discipline
//! (`coordinator::net::protocol`: big-endian length prefix, version and
//! kind bytes) plus a checksum, with the delta payload encoded by the
//! *same* JSON codec the protocol's `update` request uses
//! ([`GraphDelta::to_json`]):
//!
//! ```text
//! ┌──────────┬─────────┬──────────┬──────────┬───────────────────┐
//! │ len: u32 │ ver: u8 │ kind: u8 │ crc: u32 │ payload (JSON)    │
//! └──────────┴─────────┴──────────┴──────────┴───────────────────┘
//! ```
//!
//! `len` counts everything after itself (ver + kind + crc + payload, so
//! ≥ 6); `crc` is IEEE CRC-32 over the payload.  All record integers are
//! big-endian like the wire protocol; the *snapshot* body is
//! little-endian like the artifact formats (`quant::mixed::BitsFile`) —
//! each format follows the discipline of the family it belongs to.
//!
//! ## Rotation and recovery
//!
//! Snapshots rotate generations instead of truncating in place: write
//! `snapshot-(G+1).tmp` → fsync → rename → fsync dir → create empty
//! `wal-(G+1)` → switch the writer → delete generation G.  Every crash
//! point leaves a consistent pair: a crash before the rename recovers
//! `(snapshot-G, wal-G)`; one after it recovers `snapshot-(G+1)` with an
//! empty (possibly still missing) `wal-(G+1)` — never a snapshot paired
//! with a WAL of deltas it already contains.
//!
//! Recovery loads the highest-generation snapshot and replays only that
//! generation's WAL.  A torn WAL tail (the expected crash artifact) is
//! recovered to the **longest valid prefix** — scanning stops at the
//! first record that is short, version-skewed, checksum-broken, or
//! unparseable, reports what was dropped, and never panics.  A snapshot
//! that fails its checksum is different: the write discipline makes torn
//! snapshots impossible, so corruption there is a hard, descriptive error
//! rather than a silent rebuild from guessed state.

use std::fs::{self, File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::graph::delta::GraphDelta;
use crate::util::fault;
use crate::util::json::parse;

/// WAL record format version (the `ver` byte of every record).
pub const WAL_VERSION: u8 = 1;
/// WAL record kind: one applied [`GraphDelta`].
pub const REC_DELTA: u8 = 0x01;
/// Header bytes counted by a record's length prefix (ver + kind + crc).
const WAL_HEADER: usize = 6;
/// Allocation guard: largest record `scan`/`append` will accept.
const MAX_WAL_RECORD: usize = 64 << 20;

/// Snapshot file magic + format version.
const SNAP_MAGIC: &[u8; 4] = b"A2QS";
const SNAP_VERSION: u32 = 1;

// ------------------------------------------------------------------ crc32

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// IEEE CRC-32 (the zlib/PNG polynomial, reflected).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in data {
        c = (c >> 8) ^ CRC_TABLE[((c ^ b as u32) & 0xFF) as usize];
    }
    !c
}

// ------------------------------------------------------------------ config

/// When WAL appends reach the disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync after every append: an acknowledged delta survives power loss
    Always,
    /// leave flushing to the OS: an OS crash may drop the newest suffix of
    /// acknowledged deltas (recovery still keeps the longest valid prefix)
    Never,
}

impl FsyncPolicy {
    pub fn parse(raw: &str) -> Result<FsyncPolicy> {
        match raw.trim().to_ascii_lowercase().as_str() {
            "" | "always" => Ok(FsyncPolicy::Always),
            "never" => Ok(FsyncPolicy::Never),
            other => Err(Error::config(format!(
                "A2Q_FSYNC must be 'always' or 'never', got '{other}'"
            ))),
        }
    }
}

/// Durability policy for one serving session.
#[derive(Debug, Clone)]
pub struct PersistConfig {
    /// state directory (created on open)
    pub dir: PathBuf,
    /// rotate a snapshot after this many WAL records; `0` = never (the
    /// WAL grows unboundedly and recovery replays from the beginning)
    pub snapshot_every: usize,
    /// fsync policy for WAL appends (snapshot installs always sync)
    pub fsync: FsyncPolicy,
}

impl PersistConfig {
    pub fn new(dir: impl Into<PathBuf>) -> PersistConfig {
        PersistConfig {
            dir: dir.into(),
            snapshot_every: 64,
            fsync: FsyncPolicy::Always,
        }
    }

    /// Read `A2Q_STATE_DIR` / `A2Q_SNAPSHOT_EVERY` / `A2Q_FSYNC`.  An
    /// unset or empty `A2Q_STATE_DIR` means persistence is off
    /// (`Ok(None)`); bad values in the other knobs are startup errors,
    /// never silent defaults.
    pub fn from_env() -> Result<Option<PersistConfig>> {
        PersistConfig::from_env_with_dir(None)
    }

    /// [`Self::from_env`] with the state directory forced (a CLI
    /// `--state-dir` wins over `A2Q_STATE_DIR`; the cadence and fsync
    /// knobs still come from the environment).
    pub fn from_env_with_dir(dir_override: Option<&str>) -> Result<Option<PersistConfig>> {
        let dir = match dir_override {
            Some(d) if !d.trim().is_empty() => d.to_string(),
            _ => match std::env::var("A2Q_STATE_DIR") {
                Ok(d) if !d.trim().is_empty() => d,
                _ => return Ok(None),
            },
        };
        let mut cfg = PersistConfig::new(dir);
        if let Ok(raw) = std::env::var("A2Q_SNAPSHOT_EVERY") {
            cfg.snapshot_every = raw.trim().parse().map_err(|_| {
                Error::config(format!(
                    "A2Q_SNAPSHOT_EVERY: expected a non-negative integer, got '{raw}'"
                ))
            })?;
        }
        if let Ok(raw) = std::env::var("A2Q_FSYNC") {
            cfg.fsync = FsyncPolicy::parse(&raw)?;
        }
        Ok(Some(cfg))
    }
}

// ---------------------------------------------------------------- snapshot

/// One layer's per-node quantization params as captured on disk.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotParams {
    pub steps: Vec<f32>,
    pub bits: Vec<u8>,
    pub signed: bool,
}

/// Per-layer mutable quantization state (`feat` = layer input, `feat2` =
/// the GIN hidden map).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotLayer {
    pub feat: Option<SnapshotParams>,
    pub feat2: Option<SnapshotParams>,
}

/// Everything a restarted executor needs to reconstruct the resident
/// serving state: the post-delta graph, the (possibly NNS-extended)
/// per-node params, and the epoch counter.  Weights are *not* captured —
/// they come from the model artifact on disk, and a hot swap installs a
/// fresh snapshot so a snapshot never predates its weights.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    /// logits-cache epoch at capture time
    pub epoch: u64,
    /// model the state belongs to (identity-checked on restore)
    pub model_name: String,
    pub arch: String,
    pub in_dim: u32,
    pub out_dim: u32,
    pub num_nodes: u64,
    /// resident dst-major CSR
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    /// row-major `[num_nodes, in_dim]` resident features
    pub features: Vec<f32>,
    pub layers: Vec<SnapshotLayer>,
}

impl Snapshot {
    /// Serialize: `"A2QS" | version: u32 | crc32(body): u32 | body`, all
    /// integers little-endian (artifact-format family).
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        put_u64(&mut body, self.epoch);
        put_str(&mut body, &self.model_name);
        put_str(&mut body, &self.arch);
        put_u32(&mut body, self.in_dim);
        put_u32(&mut body, self.out_dim);
        put_u64(&mut body, self.num_nodes);
        put_u32s(&mut body, &self.indptr);
        put_u32s(&mut body, &self.indices);
        put_f32s(&mut body, &self.features);
        put_u32(&mut body, self.layers.len() as u32);
        for lay in &self.layers {
            put_params(&mut body, lay.feat.as_ref());
            put_params(&mut body, lay.feat2.as_ref());
        }
        let mut out = Vec::with_capacity(body.len() + 12);
        out.extend_from_slice(SNAP_MAGIC);
        out.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
        if bytes.len() < 12 || &bytes[..4] != SNAP_MAGIC {
            return Err(Error::artifact("snapshot: bad magic (not an A2QS file)"));
        }
        let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
        if version != SNAP_VERSION {
            return Err(Error::artifact(format!(
                "snapshot: format version {version}, this build reads {SNAP_VERSION}"
            )));
        }
        let crc = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        let body = &bytes[12..];
        let actual = crc32(body);
        if crc != actual {
            return Err(Error::artifact(format!(
                "snapshot: checksum mismatch (stored {crc:#010x}, computed {actual:#010x})"
            )));
        }
        let mut c = Cursor::new(body);
        let snap = Snapshot {
            epoch: c.u64()?,
            model_name: c.string()?,
            arch: c.string()?,
            in_dim: c.u32()?,
            out_dim: c.u32()?,
            num_nodes: c.u64()?,
            indptr: c.u32s()?,
            indices: c.u32s()?,
            features: c.f32s()?,
            layers: {
                let n = c.u32()? as usize;
                // each layer costs ≥ 2 bytes; cheap bound before allocating
                if n > body.len() {
                    return Err(Error::artifact(format!("snapshot: layer count {n} exceeds body")));
                }
                let mut layers = Vec::with_capacity(n);
                for _ in 0..n {
                    layers.push(SnapshotLayer {
                        feat: c.params()?,
                        feat2: c.params()?,
                    });
                }
                layers
            },
        };
        if c.off != body.len() {
            return Err(Error::artifact(format!(
                "snapshot: {} trailing bytes after the last field",
                body.len() - c.off
            )));
        }
        Ok(snap)
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_u32s(out: &mut Vec<u8>, vs: &[u32]) {
    put_u32(out, vs.len() as u32);
    for &v in vs {
        put_u32(out, v);
    }
}

fn put_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    put_u32(out, vs.len() as u32);
    for &v in vs {
        put_u32(out, v.to_bits());
    }
}

fn put_params(out: &mut Vec<u8>, p: Option<&SnapshotParams>) {
    match p {
        None => out.push(0),
        Some(p) => {
            out.push(1);
            out.push(u8::from(p.signed));
            put_f32s(out, &p.steps);
            put_u32(out, p.bits.len() as u32);
            out.extend_from_slice(&p.bits);
        }
    }
}

/// Bounds-checked little-endian reader over a snapshot body.
struct Cursor<'a> {
    data: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn new(data: &'a [u8]) -> Cursor<'a> {
        Cursor { data, off: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let rest = self.data.len() - self.off;
        if n > rest {
            return Err(Error::artifact(format!(
                "snapshot: truncated body (need {n} bytes at offset {}, {rest} left)",
                self.off
            )));
        }
        let s = &self.data[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Element count for a length-prefixed array, bounds-checked against
    /// the remaining bytes *before* any allocation.
    fn len_of(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let rest = self.data.len() - self.off;
        if n.checked_mul(elem_bytes).map(|b| b > rest).unwrap_or(true) {
            return Err(Error::artifact(format!(
                "snapshot: array of {n} elements overruns the body at offset {}",
                self.off
            )));
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.len_of(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::artifact("snapshot: non-UTF-8 string field"))
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.len_of(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()?);
        }
        Ok(out)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_of(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_bits(self.u32()?));
        }
        Ok(out)
    }

    fn params(&mut self) -> Result<Option<SnapshotParams>> {
        match self.u8()? {
            0 => Ok(None),
            1 => {
                let signed = self.u8()? != 0;
                let steps = self.f32s()?;
                let n = self.len_of(1)?;
                let bits = self.take(n)?.to_vec();
                Ok(Some(SnapshotParams { steps, bits, signed }))
            }
            other => Err(Error::artifact(format!(
                "snapshot: bad params presence byte {other}"
            ))),
        }
    }
}

// --------------------------------------------------------------- recovery

/// What `Persistence::open` found on disk.
#[derive(Debug)]
pub struct Recovery {
    /// highest-generation snapshot, if any
    pub snapshot: Option<Snapshot>,
    /// valid WAL tail of that generation, in append order
    pub deltas: Vec<GraphDelta>,
    /// active generation number
    pub generation: u64,
    /// bytes discarded from a torn/corrupt WAL tail (already truncated)
    pub dropped_bytes: u64,
    /// why scanning stopped early, when it did
    pub dropped_note: Option<String>,
}

struct WalScan {
    deltas: Vec<GraphDelta>,
    valid_bytes: u64,
    dropped_bytes: u64,
    note: Option<String>,
}

/// Longest-valid-prefix scan of a WAL image.  Never panics: every
/// malformed shape (short prefix, absurd length, version/kind skew,
/// checksum or JSON failure) stops the scan with a note.
fn scan_wal(data: &[u8]) -> WalScan {
    let mut deltas = Vec::new();
    let mut off = 0usize;
    let mut note = None;
    while off < data.len() {
        let rest = data.len() - off;
        if rest < 4 {
            note = Some(format!("torn length prefix at byte {off} ({rest} trailing bytes)"));
            break;
        }
        let len =
            u32::from_be_bytes([data[off], data[off + 1], data[off + 2], data[off + 3]]) as usize;
        if !(WAL_HEADER..=MAX_WAL_RECORD).contains(&len) {
            note = Some(format!("corrupt record length {len} at byte {off}"));
            break;
        }
        if rest - 4 < len {
            note = Some(format!(
                "torn record at byte {off} (length says {len} bytes, {} present)",
                rest - 4
            ));
            break;
        }
        let ver = data[off + 4];
        let kind = data[off + 5];
        if ver != WAL_VERSION {
            note = Some(format!(
                "record version {ver} at byte {off}, this build reads {WAL_VERSION}"
            ));
            break;
        }
        if kind != REC_DELTA {
            note = Some(format!("unknown record kind {kind:#04x} at byte {off}"));
            break;
        }
        let crc = u32::from_be_bytes([
            data[off + 6],
            data[off + 7],
            data[off + 8],
            data[off + 9],
        ]);
        let payload = &data[off + 10..off + 4 + len];
        if crc32(payload) != crc {
            note = Some(format!(
                "checksum mismatch in record {} at byte {off}",
                deltas.len()
            ));
            break;
        }
        let parsed = std::str::from_utf8(payload)
            .ok()
            .and_then(|s| parse(s).ok())
            .and_then(|j| GraphDelta::from_json(&j).ok());
        match parsed {
            Some(d) => {
                deltas.push(d);
                off += 4 + len;
            }
            None => {
                note = Some(format!(
                    "unparseable payload in record {} at byte {off} (checksum valid)",
                    deltas.len()
                ));
                break;
            }
        }
    }
    WalScan {
        deltas,
        valid_bytes: off as u64,
        dropped_bytes: (data.len() - off) as u64,
        note,
    }
}

// ------------------------------------------------------------- persistence

fn snapshot_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("snapshot-{generation}.a2qs"))
}

fn wal_path(dir: &Path, generation: u64) -> PathBuf {
    dir.join(format!("wal-{generation}.log"))
}

fn parse_generation(name: &str, prefix: &str, suffix: &str) -> Option<u64> {
    name.strip_prefix(prefix)?.strip_suffix(suffix)?.parse().ok()
}

/// POSIX durability for renames/creates: fsync the containing directory.
fn sync_dir(dir: &Path) -> Result<()> {
    File::open(dir)?.sync_all()?;
    Ok(())
}

/// Open WAL writer + snapshot rotation for one state directory.
///
/// One `Persistence` owns its directory's active generation; the executor
/// serializes access (appends happen under the resident-state write
/// lock), so there is no in-process concurrency to guard here.
#[derive(Debug)]
pub struct Persistence {
    dir: PathBuf,
    snapshot_every: usize,
    fsync: FsyncPolicy,
    generation: u64,
    wal: File,
    wal_records: usize,
    wal_bytes: u64,
    note: Option<String>,
}

impl Persistence {
    /// Open (or create) a state dir: load the newest snapshot, recover the
    /// longest valid WAL prefix of its generation (truncating any torn
    /// tail in place), delete superseded generations, and position the
    /// writer at the end of the valid log.
    pub fn open(cfg: PersistConfig) -> Result<(Persistence, Recovery)> {
        fs::create_dir_all(&cfg.dir)?;
        let mut snap_gens: Vec<u64> = Vec::new();
        let mut wal_gens: Vec<u64> = Vec::new();
        for entry in fs::read_dir(&cfg.dir)? {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(g) = parse_generation(name, "snapshot-", ".a2qs") {
                snap_gens.push(g);
            }
            if let Some(g) = parse_generation(name, "wal-", ".log") {
                wal_gens.push(g);
            }
        }
        let snapshot = match snap_gens.iter().max().copied() {
            Some(g) => {
                let path = snapshot_path(&cfg.dir, g);
                let bytes = fs::read(&path)?;
                // the temp+rename+dir-fsync discipline makes torn snapshots
                // impossible, so a decode failure here is real corruption:
                // refuse to serve guessed state
                let snap = Snapshot::decode(&bytes).map_err(|e| {
                    Error::artifact(format!(
                        "corrupt snapshot {}: {e} — restore the file from a replica, or \
                         remove the state dir to rebuild from the model artifact",
                        path.display()
                    ))
                })?;
                Some((g, snap))
            }
            None => None,
        };
        // active generation: the snapshot's, else the newest WAL's (a log
        // that never reached its first snapshot), else 0.  A missing WAL
        // file for the active generation is an empty tail — the expected
        // state after a crash between snapshot rename and WAL creation.
        let generation = snapshot
            .as_ref()
            .map(|(g, _)| *g)
            .or_else(|| wal_gens.iter().max().copied())
            .unwrap_or(0);
        let active_wal = wal_path(&cfg.dir, generation);
        let data = match fs::read(&active_wal) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        let scan = scan_wal(&data);
        let mut wal = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(&active_wal)?;
        if scan.dropped_bytes > 0 {
            // drop the torn tail so appends extend the valid prefix
            wal.set_len(scan.valid_bytes)?;
            wal.sync_all()?;
        }
        wal.seek(SeekFrom::Start(scan.valid_bytes))?;
        for &g in snap_gens.iter().chain(&wal_gens) {
            if g < generation {
                let _ = fs::remove_file(snapshot_path(&cfg.dir, g));
                let _ = fs::remove_file(wal_path(&cfg.dir, g));
            }
        }
        let recovery = Recovery {
            snapshot: snapshot.map(|(_, s)| s),
            generation,
            dropped_bytes: scan.dropped_bytes,
            dropped_note: scan.note,
            deltas: scan.deltas,
        };
        let persist = Persistence {
            dir: cfg.dir,
            snapshot_every: cfg.snapshot_every,
            fsync: cfg.fsync,
            generation,
            wal,
            wal_records: recovery.deltas.len(),
            wal_bytes: scan.valid_bytes,
            note: None,
        };
        Ok((persist, recovery))
    }

    /// Append one delta record; returns the record's full byte length
    /// (length prefix included) so a failed commit can roll it back.
    pub fn append_delta(&mut self, delta: &GraphDelta) -> Result<u64> {
        fault::point("persist.wal_append")?;
        let payload = delta.to_json().to_string().into_bytes();
        let len = payload.len() + WAL_HEADER;
        if len > MAX_WAL_RECORD {
            return Err(Error::coordinator(format!(
                "delta record of {len} bytes exceeds the {MAX_WAL_RECORD}-byte WAL record cap"
            )));
        }
        let mut rec = Vec::with_capacity(4 + len);
        rec.extend_from_slice(&(len as u32).to_be_bytes());
        rec.push(WAL_VERSION);
        rec.push(REC_DELTA);
        rec.extend_from_slice(&crc32(&payload).to_be_bytes());
        rec.extend_from_slice(&payload);
        self.wal.write_all(&rec)?;
        if self.fsync == FsyncPolicy::Always {
            self.wal.sync_data()?;
        }
        self.wal_records += 1;
        self.wal_bytes += rec.len() as u64;
        Ok(rec.len() as u64)
    }

    /// Rewind the most recent append (the executor calls this when a
    /// logged delta fails to commit, so the log never replays a delta the
    /// resident session refused).
    pub fn rollback_last(&mut self, record_bytes: u64) -> Result<()> {
        let new_len = self.wal_bytes.saturating_sub(record_bytes);
        self.wal.set_len(new_len)?;
        self.wal.seek(SeekFrom::Start(new_len))?;
        if self.fsync == FsyncPolicy::Always {
            self.wal.sync_data()?;
        }
        self.wal_bytes = new_len;
        self.wal_records = self.wal_records.saturating_sub(1);
        Ok(())
    }

    /// Whether the WAL has grown past the snapshot cadence.
    pub fn snapshot_due(&self) -> bool {
        self.snapshot_every > 0 && self.wal_records >= self.snapshot_every
    }

    /// Install `snap` as the next generation and rotate to a fresh WAL.
    /// Ordering: tmp write → fsync → rename → dir fsync → empty WAL →
    /// dir fsync → switch writer → delete the superseded generation; see
    /// the module docs for why every crash point recovers consistently.
    pub fn install_snapshot(&mut self, snap: &Snapshot) -> Result<()> {
        fault::point("persist.snapshot")?;
        let next = self.generation + 1;
        let final_path = snapshot_path(&self.dir, next);
        let tmp_path = self.dir.join(format!("snapshot-{next}.a2qs.tmp"));
        {
            let mut f = File::create(&tmp_path)?;
            f.write_all(&snap.encode())?;
            f.sync_all()?;
        }
        fs::rename(&tmp_path, &final_path)?;
        sync_dir(&self.dir)?;
        let next_wal_path = wal_path(&self.dir, next);
        let next_wal = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&next_wal_path)?;
        next_wal.sync_all()?;
        sync_dir(&self.dir)?;
        let prev = self.generation;
        self.wal = next_wal;
        self.generation = next;
        self.wal_records = 0;
        self.wal_bytes = 0;
        // best-effort cleanup: recovery prefers the highest generation
        // regardless, so a leftover pair is wasted disk, not wrong state
        let _ = fs::remove_file(snapshot_path(&self.dir, prev));
        let _ = fs::remove_file(wal_path(&self.dir, prev));
        Ok(())
    }

    /// Records in the active WAL (since the last snapshot).
    pub fn wal_records(&self) -> usize {
        self.wal_records
    }

    /// Bytes in the active WAL.
    pub fn wal_bytes(&self) -> u64 {
        self.wal_bytes
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Record a non-fatal problem (e.g. a failed best-effort snapshot —
    /// the WAL keeps the state recoverable) for operators to read back.
    pub fn set_note(&mut self, note: String) {
        self.note = Some(note);
    }

    pub fn note(&self) -> Option<&str> {
        self.note.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("a2q_persist_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn delta(i: u32) -> GraphDelta {
        GraphDelta {
            add_nodes: 1,
            new_features: vec![0.5 + i as f32, -0.25 * i as f32],
            add_edges: vec![(i, i + 1)],
            remove_edges: if i % 2 == 0 { vec![(0, i)] } else { vec![] },
        }
    }

    fn delta_key(d: &GraphDelta) -> String {
        d.to_json().to_string()
    }

    fn snap_fixture() -> Snapshot {
        Snapshot {
            epoch: 7,
            model_name: "unit".into(),
            arch: "gcn".into(),
            in_dim: 2,
            out_dim: 3,
            num_nodes: 4,
            indptr: vec![0, 1, 2, 2, 3],
            indices: vec![1, 0, 3],
            features: vec![0.1, -0.2, f32::MIN_POSITIVE, 3.5e7, 0.0, -0.0, 1.0, 2.0],
            layers: vec![SnapshotLayer {
                feat: Some(SnapshotParams {
                    steps: vec![0.1, 0.2, 0.3, 0.4],
                    bits: vec![4, 2, 8, 1],
                    signed: true,
                }),
                feat2: None,
            }],
        }
    }

    #[test]
    fn crc32_matches_the_ieee_check_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn snapshot_roundtrips_bitwise() {
        let snap = snap_fixture();
        let decoded = Snapshot::decode(&snap.encode()).unwrap();
        assert_eq!(decoded.epoch, snap.epoch);
        assert_eq!(decoded.model_name, snap.model_name);
        assert_eq!(decoded.indptr, snap.indptr);
        assert_eq!(decoded.indices, snap.indices);
        // features compare as bit patterns (−0.0 and denormals included)
        assert_eq!(
            decoded.features.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            snap.features.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(decoded.layers, snap.layers);
    }

    #[test]
    fn snapshot_decode_rejects_malformed_bytes_without_panicking() {
        let good = snap_fixture().encode();
        // bad magic
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(Snapshot::decode(&bad).is_err());
        // unknown version
        let mut bad = good.clone();
        bad[4] = 9;
        assert!(Snapshot::decode(&bad).is_err());
        // any flipped body byte must fail the checksum
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert!(Snapshot::decode(&bad).is_err());
        // trailing garbage is rejected, not ignored
        let mut bad = good.clone();
        bad.push(0);
        assert!(Snapshot::decode(&bad).is_err());
        // every truncation errors cleanly (the checksum catches them all)
        for cut in 0..good.len() {
            assert!(Snapshot::decode(&good[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn wal_append_then_reopen_replays_in_order() {
        let dir = tmp_dir("replay");
        let (mut p, rec) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        assert!(rec.snapshot.is_none());
        assert!(rec.deltas.is_empty());
        let originals: Vec<GraphDelta> = (0..5).map(delta).collect();
        for d in &originals {
            p.append_delta(d).unwrap();
        }
        assert_eq!(p.wal_records(), 5);
        drop(p);
        let (p2, rec) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        assert_eq!(rec.dropped_bytes, 0);
        assert!(rec.dropped_note.is_none());
        assert_eq!(
            rec.deltas.iter().map(delta_key).collect::<Vec<_>>(),
            originals.iter().map(delta_key).collect::<Vec<_>>()
        );
        assert_eq!(p2.wal_records(), 5);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rollback_last_unwrites_exactly_one_record() {
        let dir = tmp_dir("rollback");
        let (mut p, _) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        p.append_delta(&delta(0)).unwrap();
        let n = p.append_delta(&delta(1)).unwrap();
        p.rollback_last(n).unwrap();
        // a new append lands where the rolled-back record was
        p.append_delta(&delta(2)).unwrap();
        drop(p);
        let (_, rec) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        assert_eq!(
            rec.deltas.iter().map(delta_key).collect::<Vec<_>>(),
            vec![delta_key(&delta(0)), delta_key(&delta(2))]
        );
        assert_eq!(rec.dropped_bytes, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_rotation_supersedes_the_old_generation() {
        let dir = tmp_dir("rotate");
        let cfg = PersistConfig {
            snapshot_every: 2,
            ..PersistConfig::new(&dir)
        };
        let (mut p, _) = Persistence::open(cfg.clone()).unwrap();
        p.append_delta(&delta(0)).unwrap();
        assert!(!p.snapshot_due());
        p.append_delta(&delta(1)).unwrap();
        assert!(p.snapshot_due());
        p.install_snapshot(&snap_fixture()).unwrap();
        assert_eq!(p.generation(), 1);
        assert_eq!(p.wal_records(), 0);
        // post-snapshot deltas land in the new generation's WAL
        p.append_delta(&delta(2)).unwrap();
        drop(p);
        let (p2, rec) = Persistence::open(cfg).unwrap();
        assert_eq!(p2.generation(), 1);
        let snap = rec.snapshot.expect("snapshot restored");
        assert_eq!(snap.epoch, 7);
        assert_eq!(
            rec.deltas.iter().map(delta_key).collect::<Vec<_>>(),
            vec![delta_key(&delta(2))]
        );
        // generation 0's files are gone
        assert!(!wal_path(&dir, 0).exists());
        assert!(!snapshot_path(&dir, 0).exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_recovers_longest_valid_prefix() {
        let dir = tmp_dir("torn");
        let (mut p, _) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        for i in 0..3 {
            p.append_delta(&delta(i)).unwrap();
        }
        drop(p);
        let full = fs::read(wal_path(&dir, 0)).unwrap();
        // cut 5 bytes into the final record
        let cut = full.len() - 5;
        fs::write(wal_path(&dir, 0), &full[..cut]).unwrap();
        let (p2, rec) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        assert_eq!(rec.deltas.len(), 2);
        assert!(rec.dropped_bytes > 0);
        assert!(rec.dropped_note.is_some(), "drop must be reported");
        // the torn bytes were truncated away: the file ends at the valid
        // prefix and new appends extend it cleanly
        assert_eq!(fs::metadata(wal_path(&dir, 0)).unwrap().len(), p2.wal_bytes());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_snapshot_is_a_hard_error() {
        let dir = tmp_dir("corrupt_snap");
        let (mut p, _) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        p.append_delta(&delta(0)).unwrap();
        p.install_snapshot(&snap_fixture()).unwrap();
        drop(p);
        let path = snapshot_path(&dir, 1);
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 1;
        fs::write(&path, &bytes).unwrap();
        let err = Persistence::open(PersistConfig::new(&dir)).unwrap_err();
        assert!(
            err.to_string().contains("corrupt snapshot"),
            "descriptive error, got: {err}"
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_wal_after_snapshot_is_an_empty_tail() {
        // simulates a crash between snapshot rename and WAL creation
        let dir = tmp_dir("no_wal");
        let (mut p, _) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        p.append_delta(&delta(0)).unwrap();
        p.install_snapshot(&snap_fixture()).unwrap();
        drop(p);
        fs::remove_file(wal_path(&dir, 1)).unwrap();
        let (p2, rec) = Persistence::open(PersistConfig::new(&dir)).unwrap();
        assert!(rec.snapshot.is_some());
        assert!(rec.deltas.is_empty());
        assert_eq!(p2.generation(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsync_policy_parses_and_rejects() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse(" NEVER ").unwrap(), FsyncPolicy::Never);
        assert_eq!(FsyncPolicy::parse("").unwrap(), FsyncPolicy::Always);
        assert!(FsyncPolicy::parse("sometimes").is_err());
    }
}
