//! The PJRT execution engine: compile-once, execute-many.
//!
//! The `xla` crate's `PjRtClient` holds `Rc` internals, so it is neither
//! `Send` nor `Sync`.  [`Engine`] is therefore a single-threaded object,
//! and [`EngineHandle`] runs one behind a dedicated service thread (actor
//! pattern): the coordinator's runner threads talk to it over channels.
//! PJRT CPU executions were serialized anyway (single device); the actor
//! makes that explicit and safe.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;

use crate::error::{Error, Result};

use super::artifact::ModelArtifact;

/// One typed, shaped input buffer for an executable.
#[derive(Debug, Clone)]
pub enum ExecInput {
    /// (flat data, dims) — dims empty or len-1 means rank-1
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl ExecInput {
    pub fn f32_1d(data: Vec<f32>) -> ExecInput {
        let n = data.len() as i64;
        ExecInput::F32(data, vec![n])
    }
    pub fn f32_2d(data: Vec<f32>, rows: usize, cols: usize) -> ExecInput {
        ExecInput::F32(data, vec![rows as i64, cols as i64])
    }
    /// Rank-0 scalar (dims = []).
    pub fn f32_scalar(v: f32) -> ExecInput {
        ExecInput::F32(vec![v], vec![])
    }
    /// Arbitrary-shape f32 tensor.
    pub fn f32_shaped(data: Vec<f32>, dims: Vec<i64>) -> ExecInput {
        ExecInput::F32(data, dims)
    }
    pub fn i32_1d(data: Vec<i32>) -> ExecInput {
        let n = data.len() as i64;
        ExecInput::I32(data, vec![n])
    }
}

/// Single-threaded compiled-executable cache over a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
            executables: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the HLO-text artifact.
    pub fn load_artifact(&mut self, artifact: &ModelArtifact) -> Result<()> {
        self.load_hlo_file(&artifact.name, &artifact.hlo_path)
    }

    /// Compile an HLO text file under a cache key.
    pub fn load_hlo_file(&mut self, key: &str, path: &Path) -> Result<()> {
        if self.executables.contains_key(key) {
            return Ok(());
        }
        let path_str = path
            .to_str()
            .ok_or_else(|| Error::artifact("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executables.insert(key.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, key: &str) -> bool {
        self.executables.contains_key(key)
    }

    pub fn loaded_count(&self) -> usize {
        self.executables.len()
    }

    /// Execute a loaded computation.  The AOT export wraps the result in a
    /// 1-tuple (`return_tuple=True`), unwrapped here; returns the flat f32
    /// output buffer.
    pub fn execute(&self, key: &str, inputs: &[ExecInput]) -> Result<Vec<f32>> {
        let mut literals: Vec<xla::Literal> = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = match inp {
                ExecInput::F32(v, dims) if dims.is_empty() => xla::Literal::from(v[0]),
                ExecInput::I32(v, dims) if dims.is_empty() => xla::Literal::from(v[0]),
                ExecInput::F32(v, dims) => reshape_if_needed(xla::Literal::vec1(v), dims)?,
                ExecInput::I32(v, dims) => reshape_if_needed(xla::Literal::vec1(v), dims)?,
            };
            literals.push(lit);
        }
        let exe = self
            .executables
            .get(key)
            .ok_or_else(|| Error::Runtime(format!("executable '{key}' not loaded")))?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("expected 1-tuple output: {e:?}")))?;
        Ok(out.to_vec::<f32>()?)
    }
}

fn reshape_if_needed(lit: xla::Literal, dims: &[i64]) -> Result<xla::Literal> {
    if dims.len() <= 1 {
        return Ok(lit);
    }
    Ok(lit.reshape(dims)?)
}

// ---------------------------------------------------------------------------
// Actor wrapper
// ---------------------------------------------------------------------------

enum EngineMsg {
    Load(String, PathBuf, mpsc::Sender<Result<()>>),
    Execute(String, Vec<ExecInput>, mpsc::Sender<Result<Vec<f32>>>),
    Platform(mpsc::Sender<String>),
}

/// Cloneable, `Send` handle to an engine running on its own thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineMsg>,
}

impl EngineHandle {
    /// Spawn the service thread (creates the PJRT client there).
    pub fn spawn() -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        thread::Builder::new()
            .name("a2q-pjrt".into())
            .spawn(move || {
                let mut engine = match Engine::cpu() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for msg in rx {
                    match msg {
                        EngineMsg::Load(key, path, reply) => {
                            let _ = reply.send(engine.load_hlo_file(&key, &path));
                        }
                        EngineMsg::Execute(key, inputs, reply) => {
                            let _ = reply.send(engine.execute(&key, &inputs));
                        }
                        EngineMsg::Platform(reply) => {
                            let _ = reply.send(engine.platform());
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn engine thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died".into()))??;
        Ok(EngineHandle { tx })
    }

    pub fn load_artifact(&self, artifact: &ModelArtifact) -> Result<()> {
        self.load_hlo_file(&artifact.name, artifact.hlo_path.clone())
    }

    pub fn load_hlo_file(&self, key: &str, path: PathBuf) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Load(key.to_string(), path, tx))
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?
    }

    pub fn execute(&self, key: &str, inputs: Vec<ExecInput>) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Execute(key.to_string(), inputs, tx))
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?
    }

    pub fn platform(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Platform(tx))
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread stopped".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_input_constructors() {
        match ExecInput::f32_2d(vec![0.0; 6], 2, 3) {
            ExecInput::F32(d, dims) => {
                assert_eq!(d.len(), 6);
                assert_eq!(dims, vec![2, 3]);
            }
            _ => panic!(),
        }
        match ExecInput::i32_1d(vec![1, 2]) {
            ExecInput::I32(_, dims) => assert_eq!(dims, vec![2]),
            _ => panic!(),
        }
    }

    // Full execution is covered by the integration tests in
    // rust/tests/pjrt_roundtrip.rs (gated on `make artifacts` having run).
}
