//! The PJRT execution engine: compile-once, execute-many.
//!
//! The real backend binds the `xla` crate (xla_extension 0.5.1), which is
//! **not in the offline dependency set** — `thiserror` is this crate's
//! sole external dependency.  This module therefore ships the engine as a
//! stub with the exact production surface: handles construct, artifact
//! keys register, and `execute` returns `Error::Runtime` directing callers
//! to the native backend (`coordinator::NativeExecutor`, which runs the
//! same parameters through `gnn::infer`).  The artifact-gated integration
//! tests in `rust/tests/` skip themselves when no compiled artifacts are
//! present, so the stub keeps `cargo test` green while preserving every
//! call site for the day the xla closure is vendored.
//!
//! `Engine` also carries a serving-side [`ParallelConfig`]: the
//! coordinator configures the engine's intra-op parallelism budget here
//! (instance-scoped; the process default for the convenience kernel entry
//! points is installed only via the explicit
//! `threadpool::set_global_parallelism`).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::thread;

use crate::error::{Error, Result};
use crate::util::threadpool::{self, ParallelConfig};

use super::artifact::ModelArtifact;

/// One typed, shaped input buffer for an executable.
#[derive(Debug, Clone)]
pub enum ExecInput {
    /// (flat data, dims) — dims empty or len-1 means rank-1
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
}

impl ExecInput {
    pub fn f32_1d(data: Vec<f32>) -> ExecInput {
        let n = data.len() as i64;
        ExecInput::F32(data, vec![n])
    }
    pub fn f32_2d(data: Vec<f32>, rows: usize, cols: usize) -> ExecInput {
        ExecInput::F32(data, vec![rows as i64, cols as i64])
    }
    /// Rank-0 scalar (dims = []).
    pub fn f32_scalar(v: f32) -> ExecInput {
        ExecInput::F32(vec![v], vec![])
    }
    /// Arbitrary-shape f32 tensor.
    pub fn f32_shaped(data: Vec<f32>, dims: Vec<i64>) -> ExecInput {
        ExecInput::F32(data, dims)
    }
    pub fn i32_1d(data: Vec<i32>) -> ExecInput {
        let n = data.len() as i64;
        ExecInput::I32(data, vec![n])
    }

    /// Element count of the buffer.
    pub fn len(&self) -> usize {
        match self {
            ExecInput::F32(v, _) => v.len(),
            ExecInput::I32(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn backend_unavailable(what: &str) -> Error {
    Error::Runtime(format!(
        "PJRT backend unavailable ({what}): the xla crate is not in the \
         offline dependency set — use coordinator::NativeExecutor for \
         execution"
    ))
}

/// Single-threaded compiled-executable cache over a PJRT CPU client
/// (stubbed — see the module docs).
pub struct Engine {
    /// registered artifact keys → HLO path (compilation is deferred to the
    /// real backend; registration still validates the path exists)
    executables: HashMap<String, PathBuf>,
    parallel: ParallelConfig,
}

impl Engine {
    /// Create a CPU engine with the env-derived parallelism budget.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            executables: HashMap::new(),
            parallel: ParallelConfig::from_env(),
        })
    }

    pub fn platform(&self) -> String {
        "cpu-stub".to_string()
    }

    /// The engine's intra-op parallelism budget.
    pub fn parallelism(&self) -> ParallelConfig {
        self.parallel
    }

    /// Set this engine's budget.  Instance-scoped on purpose: the process
    /// default used by the convenience kernel entry points is installed
    /// only via the explicit `threadpool::set_global_parallelism`, so two
    /// engines (or an engine and a `NativeExecutor`) never clobber each
    /// other's budgets as a construction side effect.
    pub fn set_parallelism(&mut self, cfg: ParallelConfig) {
        self.parallel = cfg;
    }

    /// Compile (or fetch from cache) the HLO-text artifact.
    pub fn load_artifact(&mut self, artifact: &ModelArtifact) -> Result<()> {
        self.load_hlo_file(&artifact.name, &artifact.hlo_path)
    }

    /// Register an HLO text file under a cache key.  The stub validates
    /// the path and defers compilation; `execute` reports the missing
    /// backend.
    pub fn load_hlo_file(&mut self, key: &str, path: &Path) -> Result<()> {
        if self.executables.contains_key(key) {
            return Ok(());
        }
        if !path.exists() {
            return Err(Error::artifact(format!(
                "HLO artifact not found: {}",
                path.display()
            )));
        }
        self.executables.insert(key.to_string(), path.to_path_buf());
        Ok(())
    }

    pub fn is_loaded(&self, key: &str) -> bool {
        self.executables.contains_key(key)
    }

    pub fn loaded_count(&self) -> usize {
        self.executables.len()
    }

    /// Execute a loaded computation.  The AOT export wraps the result in a
    /// 1-tuple (`return_tuple=True`), unwrapped here; returns the flat f32
    /// output buffer.  Stub: always `Error::Runtime`.
    pub fn execute(&self, key: &str, inputs: &[ExecInput]) -> Result<Vec<f32>> {
        let _ = inputs;
        if !self.executables.contains_key(key) {
            return Err(Error::Runtime(format!("executable '{key}' not loaded")));
        }
        Err(backend_unavailable("execute"))
    }
}

// ---------------------------------------------------------------------------
// Actor wrapper
// ---------------------------------------------------------------------------

enum EngineMsg {
    Load(String, PathBuf, mpsc::Sender<Result<()>>),
    Execute(String, Vec<ExecInput>, mpsc::Sender<Result<Vec<f32>>>),
    Platform(mpsc::Sender<String>),
    SetParallelism(ParallelConfig, mpsc::Sender<()>),
}

/// Cloneable, `Send` handle to an engine running on its own thread.  The
/// real `xla::PjRtClient` holds `Rc` internals (neither `Send` nor
/// `Sync`), so the engine lives behind a dedicated service thread (actor
/// pattern) and the coordinator's runner threads talk to it over channels.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineMsg>,
}

impl EngineHandle {
    /// Spawn the service thread with the current process-default budget.
    /// Spawning never mutates the process default — pin that explicitly
    /// via `threadpool::set_global_parallelism`.
    pub fn spawn() -> Result<EngineHandle> {
        Self::spawn_with(threadpool::global_parallelism())
    }

    /// Spawn the service thread with an explicit engine-scoped budget.
    pub fn spawn_with(parallel: ParallelConfig) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<EngineMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        thread::Builder::new()
            .name("a2q-pjrt".into())
            .spawn(move || {
                let mut engine = match Engine::cpu() {
                    Ok(mut e) => {
                        e.set_parallelism(parallel);
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for msg in rx {
                    match msg {
                        EngineMsg::Load(key, path, reply) => {
                            let _ = reply.send(engine.load_hlo_file(&key, &path));
                        }
                        EngineMsg::Execute(key, inputs, reply) => {
                            let _ = reply.send(engine.execute(&key, &inputs));
                        }
                        EngineMsg::Platform(reply) => {
                            let _ = reply.send(engine.platform());
                        }
                        EngineMsg::SetParallelism(cfg, reply) => {
                            engine.set_parallelism(cfg);
                            let _ = reply.send(());
                        }
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn engine thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("engine thread died".into()))??;
        Ok(EngineHandle { tx })
    }

    pub fn load_artifact(&self, artifact: &ModelArtifact) -> Result<()> {
        self.load_hlo_file(&artifact.name, artifact.hlo_path.clone())
    }

    pub fn load_hlo_file(&self, key: &str, path: PathBuf) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Load(key.to_string(), path, tx))
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?
    }

    pub fn execute(&self, key: &str, inputs: Vec<ExecInput>) -> Result<Vec<f32>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Execute(key.to_string(), inputs, tx))
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?
    }

    pub fn platform(&self) -> Result<String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::Platform(tx))
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread stopped".into()))
    }

    /// Reconfigure the engine's (and process-default) parallelism budget.
    pub fn set_parallelism(&self, cfg: ParallelConfig) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineMsg::SetParallelism(cfg, tx))
            .map_err(|_| Error::Runtime("engine thread stopped".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("engine thread stopped".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_input_constructors() {
        match ExecInput::f32_2d(vec![0.0; 6], 2, 3) {
            ExecInput::F32(d, dims) => {
                assert_eq!(d.len(), 6);
                assert_eq!(dims, vec![2, 3]);
            }
            _ => panic!(),
        }
        match ExecInput::i32_1d(vec![1, 2]) {
            ExecInput::I32(_, dims) => assert_eq!(dims, vec![2]),
            _ => panic!(),
        }
        assert_eq!(ExecInput::f32_scalar(1.0).len(), 1);
        assert!(!ExecInput::f32_1d(vec![0.0]).is_empty());
    }

    #[test]
    fn stub_engine_registers_but_does_not_execute() {
        let mut e = Engine::cpu().unwrap();
        assert_eq!(e.loaded_count(), 0);
        assert!(e.load_hlo_file("k", Path::new("/nonexistent/x.hlo")).is_err());
        // register an existing file (any file works for the stub)
        let dir = std::env::temp_dir().join("a2q_engine_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.hlo.txt");
        std::fs::write(&path, "ENTRY main {}\n").unwrap();
        e.load_hlo_file("m", &path).unwrap();
        assert!(e.is_loaded("m"));
        let err = e.execute("m", &[]).unwrap_err();
        assert!(format!("{err}").contains("NativeExecutor"));
        let err = e.execute("missing", &[]).unwrap_err();
        assert!(format!("{err}").contains("not loaded"));
    }

    #[test]
    fn handle_roundtrips_parallelism_and_platform() {
        let h = EngineHandle::spawn_with(ParallelConfig::serial()).unwrap();
        assert_eq!(h.platform().unwrap(), "cpu-stub");
        h.set_parallelism(ParallelConfig::with_threads(2)).unwrap();
    }

    // Full execution is covered by the integration tests in
    // rust/tests/pjrt_roundtrip.rs (gated on `make artifacts` having run).
}
