//! Artifact discovery: the `artifacts/models` directory layout.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// Metadata of one exported model variant (subset of the manifest needed
/// for runtime dispatch; full parameters load through `gnn::GnnModel`).
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub name: String,
    pub dir: PathBuf,
    pub hlo_path: PathBuf,
    pub dataset: String,
    pub arch: String,
    pub method: String,
    pub node_level: bool,
    pub num_nodes: usize,
    pub num_edges: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    pub graph_capacity: usize,
    pub avg_bits: f64,
    pub accuracy: f64,
    pub expected_head: Vec<f32>,
    pub manifest: Json,
}

/// Parse the ENTRY computation's surviving parameters from HLO text.
///
/// XLA eliminates unused entry parameters during lowering (e.g. GCN never
/// reads `sum_w`), so the compiled program may expect fewer buffers than
/// the logical export signature.  jax names entry args `Arg_<logical>...`;
/// this returns the logical index for each surviving position, sorted by
/// position.
pub fn parse_param_map(hlo_text: &str) -> Vec<usize> {
    let mut in_entry = false;
    let mut pairs: Vec<(usize, usize)> = Vec::new(); // (position, logical)
    for line in hlo_text.lines() {
        if line.starts_with("ENTRY") {
            in_entry = true;
            continue;
        }
        if in_entry {
            if line.starts_with('}') {
                break;
            }
            let Some(ppos) = line.find(" parameter(") else {
                continue;
            };
            let pos_str: String = line[ppos + " parameter(".len()..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            let Some(apos) = line.find("Arg_") else { continue };
            let log_str: String = line[apos + 4..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            if let (Ok(p), Ok(l)) = (pos_str.parse(), log_str.parse()) {
                pairs.push((p, l));
            }
        }
    }
    pairs.sort_unstable();
    pairs.dedup();
    pairs.into_iter().map(|(_p, l)| l).collect()
}

impl ModelArtifact {
    pub fn load(dir: &Path, name: &str) -> Result<ModelArtifact> {
        let man = json::parse_file(&dir.join(format!("{name}.manifest.json")))?;
        Ok(ModelArtifact {
            name: name.to_string(),
            dir: dir.to_path_buf(),
            hlo_path: dir.join(man.req_str("hlo")?),
            dataset: man.req_str("dataset")?.to_string(),
            arch: man.req_str("arch")?.to_string(),
            method: man.req_str("method")?.to_string(),
            node_level: man.req("node_level")?.as_bool().unwrap_or(true),
            num_nodes: man.req_usize("num_nodes")?,
            num_edges: man.req_usize("num_edges")?,
            in_dim: man.req_usize("in_dim")?,
            out_dim: man.req_usize("out_dim")?,
            graph_capacity: man.req_usize("graph_capacity")?,
            avg_bits: man.req_f64("avg_bits")?,
            accuracy: man.req_f64("accuracy")?,
            expected_head: man
                .req("expected_head")?
                .as_arr()
                .map(|a| {
                    a.iter()
                        .filter_map(|v| v.as_f64())
                        .map(|v| v as f32)
                        .collect()
                })
                .unwrap_or_default(),
            manifest: man,
        })
    }

    pub fn bits_path(&self) -> Option<PathBuf> {
        self.manifest
            .get("bits_bin")
            .and_then(|v| v.as_str())
            .map(|f| self.dir.join(f))
    }

    /// Surviving logical parameter indices of the compiled program, in
    /// positional order.  Preferred source: the manifest's `param_map`
    /// (jax's `kept_var_idx`, recorded at export).  Fallback: parsing the
    /// HLO entry's Arg names (only valid when jax did not renumber them).
    pub fn param_map(&self) -> Result<Vec<usize>> {
        if let Some(arr) = self.manifest.get("param_map").and_then(|v| v.as_arr()) {
            let map: Vec<usize> = arr.iter().filter_map(|v| v.as_usize()).collect();
            if !map.is_empty() {
                return Ok(map);
            }
        }
        let text = std::fs::read_to_string(&self.hlo_path)?;
        let map = parse_param_map(&text);
        if map.is_empty() {
            return Err(Error::artifact(format!(
                "{}: no parameters found in HLO entry",
                self.hlo_path.display()
            )));
        }
        Ok(map)
    }

    /// Number of data inputs before the appended weight parameters.
    pub fn num_data_inputs(&self) -> usize {
        self.manifest
            .get("num_data_inputs")
            .and_then(|v| v.as_usize())
            .unwrap_or(if self.node_level { 5 } else { 7 })
    }

    /// Load the weight tensors (manifest order) as shaped exec inputs —
    /// appended after the data inputs on every execution (HLO text cannot
    /// carry large constants; see aot.py).
    pub fn weight_inputs(&self) -> Result<Vec<super::engine::ExecInput>> {
        use std::io::Read;
        let path = self.dir.join(self.manifest.req_str("weights_bin")?);
        let mut raw = Vec::new();
        std::fs::File::open(&path)?.read_to_end(&mut raw)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            // a2q-lint: allow(panic-path) chunks_exact(4) yields only
            // 4-byte slices, so the conversion is infallible
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let mut out = Vec::new();
        for t in self
            .manifest
            .req("tensors")?
            .as_arr()
            .ok_or_else(|| Error::artifact("tensors not an array"))?
        {
            let shape: Vec<i64> = t
                .req("shape")?
                .as_arr()
                .ok_or_else(|| Error::artifact("bad shape"))?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as i64)
                .collect();
            let offset = t.req_usize("offset")?;
            let len: usize = shape.iter().product::<i64>().max(1) as usize;
            out.push(super::engine::ExecInput::f32_shaped(
                data[offset..offset + len].to_vec(),
                shape,
            ));
        }
        Ok(out)
    }
}

/// The `index.json` written by `aot.py`: all exported variants.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub dir: PathBuf,
    pub models: Vec<String>,
}

impl ArtifactIndex {
    /// Load `<artifacts>/models/index.json`.
    pub fn load(artifacts: &Path) -> Result<ArtifactIndex> {
        let dir = artifacts.join("models");
        let idx = json::parse_file(&dir.join("index.json")).map_err(|e| {
            Error::artifact(format!(
                "cannot read artifact index ({e}); run `make artifacts` first"
            ))
        })?;
        let models = idx
            .req("models")?
            .as_arr()
            .ok_or_else(|| Error::artifact("index.models not an array"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        Ok(ArtifactIndex { dir, models })
    }

    pub fn artifact(&self, name: &str) -> Result<ModelArtifact> {
        if !self.models.iter().any(|m| m == name) {
            return Err(Error::artifact(format!(
                "model '{name}' not in index (have: {:?})",
                self.models
            )));
        }
        ModelArtifact::load(&self.dir, name)
    }

    pub fn all(&self) -> Result<Vec<ModelArtifact>> {
        self.models
            .iter()
            .map(|m| ModelArtifact::load(&self.dir, m))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_index_gives_actionable_error() {
        let err = ArtifactIndex::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn param_map_parses_entry_only() {
        let hlo = r#"
region_0 {
  Arg_9.9 = f32[2]{0} parameter(0)
}

ENTRY main.42 {
  Arg_2.7 = s32[13534]{0} parameter(2)
  Arg_0.19 = f32[2708,1433]{1,0} parameter(0)
  Arg_1.11 = s32[13534]{0} parameter(1)
  Arg_3.1 = f32[13534]{0} parameter(3)
  ROOT t = (f32[2708,7]{1,0}) tuple(Arg_0.19)
}
"#;
        // position order 0..3 → logical 0,1,2,3 (sum_w / logical 4 dropped)
        assert_eq!(parse_param_map(hlo), vec![0, 1, 2, 3]);
    }

    #[test]
    fn param_map_reordered_logicals() {
        let hlo = "ENTRY e {\n  Arg_4.1 = f32[2]{0} parameter(0)\n  Arg_1.2 = f32[2]{0} parameter(1)\n}\n";
        assert_eq!(parse_param_map(hlo), vec![4, 1]);
    }
}
