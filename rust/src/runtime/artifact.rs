//! Artifact discovery: the `artifacts/models` directory layout.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// Metadata of one exported model variant (subset of the manifest needed
/// for runtime dispatch; full parameters load through `gnn::GnnModel`).
#[derive(Debug, Clone)]
pub struct ModelArtifact {
    pub name: String,
    pub dir: PathBuf,
    pub hlo_path: PathBuf,
    pub dataset: String,
    pub arch: String,
    pub method: String,
    pub node_level: bool,
    pub num_nodes: usize,
    pub num_edges: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    pub graph_capacity: usize,
    pub avg_bits: f64,
    pub accuracy: f64,
    pub expected_head: Vec<f32>,
    pub manifest: Json,
}

/// Parse the ENTRY computation's surviving parameters from HLO text.
///
/// XLA eliminates unused entry parameters during lowering (e.g. GCN never
/// reads `sum_w`), so the compiled program may expect fewer buffers than
/// the logical export signature.  jax names entry args `Arg_<logical>...`;
/// this returns the logical index for each surviving position, sorted by
/// position.
pub fn parse_param_map(hlo_text: &str) -> Vec<usize> {
    let mut in_entry = false;
    let mut pairs: Vec<(usize, usize)> = Vec::new(); // (position, logical)
    for line in hlo_text.lines() {
        if line.starts_with("ENTRY") {
            in_entry = true;
            continue;
        }
        if in_entry {
            if line.starts_with('}') {
                break;
            }
            let Some(ppos) = line.find(" parameter(") else {
                continue;
            };
            let pos_str: String = line[ppos + " parameter(".len()..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            let Some(apos) = line.find("Arg_") else { continue };
            let log_str: String = line[apos + 4..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            if let (Ok(p), Ok(l)) = (pos_str.parse(), log_str.parse()) {
                pairs.push((p, l));
            }
        }
    }
    pairs.sort_unstable();
    pairs.dedup();
    pairs.into_iter().map(|(_p, l)| l).collect()
}

/// Parse the manifest's `expected_head` strictly.  The old path mapped a
/// malformed field (non-array, or non-numeric elements) to an **empty**
/// vec via `unwrap_or_default`, which silently muted the downstream
/// head-parity check — a corrupted manifest looked like "no expectation
/// recorded" instead of failing the load.
fn parse_expected_head(man: &Json) -> Result<Vec<f32>> {
    let arr = man
        .req("expected_head")?
        .as_arr()
        .ok_or_else(|| Error::artifact("expected_head is not an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let n = v.as_f64().ok_or_else(|| {
            Error::artifact(format!("expected_head[{i}] is not a number"))
        })?;
        out.push(n as f32);
    }
    Ok(out)
}

impl ModelArtifact {
    pub fn load(dir: &Path, name: &str) -> Result<ModelArtifact> {
        let man = json::parse_file(&dir.join(format!("{name}.manifest.json")))?;
        Ok(ModelArtifact {
            name: name.to_string(),
            dir: dir.to_path_buf(),
            hlo_path: dir.join(man.req_str("hlo")?),
            dataset: man.req_str("dataset")?.to_string(),
            arch: man.req_str("arch")?.to_string(),
            method: man.req_str("method")?.to_string(),
            node_level: man.req("node_level")?.as_bool().unwrap_or(true),
            num_nodes: man.req_usize("num_nodes")?,
            num_edges: man.req_usize("num_edges")?,
            in_dim: man.req_usize("in_dim")?,
            out_dim: man.req_usize("out_dim")?,
            graph_capacity: man.req_usize("graph_capacity")?,
            avg_bits: man.req_f64("avg_bits")?,
            accuracy: man.req_f64("accuracy")?,
            expected_head: parse_expected_head(&man)?,
            manifest: man,
        })
    }

    pub fn bits_path(&self) -> Option<PathBuf> {
        self.manifest
            .get("bits_bin")
            .and_then(|v| v.as_str())
            .map(|f| self.dir.join(f))
    }

    /// Surviving logical parameter indices of the compiled program, in
    /// positional order.  Preferred source: the manifest's `param_map`
    /// (jax's `kept_var_idx`, recorded at export).  Fallback: parsing the
    /// HLO entry's Arg names (only valid when jax did not renumber them).
    pub fn param_map(&self) -> Result<Vec<usize>> {
        if let Some(arr) = self.manifest.get("param_map").and_then(|v| v.as_arr()) {
            let map: Vec<usize> = arr.iter().filter_map(|v| v.as_usize()).collect();
            if !map.is_empty() {
                return Ok(map);
            }
        }
        let text = std::fs::read_to_string(&self.hlo_path)?;
        let map = parse_param_map(&text);
        if map.is_empty() {
            return Err(Error::artifact(format!(
                "{}: no parameters found in HLO entry",
                self.hlo_path.display()
            )));
        }
        Ok(map)
    }

    /// Number of data inputs before the appended weight parameters.
    pub fn num_data_inputs(&self) -> usize {
        self.manifest
            .get("num_data_inputs")
            .and_then(|v| v.as_usize())
            .unwrap_or(if self.node_level { 5 } else { 7 })
    }

    /// Load the weight tensors (manifest order) as shaped exec inputs —
    /// appended after the data inputs on every execution (HLO text cannot
    /// carry large constants; see aot.py).
    pub fn weight_inputs(&self) -> Result<Vec<super::engine::ExecInput>> {
        use std::io::Read;
        let path = self.dir.join(self.manifest.req_str("weights_bin")?);
        let mut raw = Vec::new();
        std::fs::File::open(&path)?.read_to_end(&mut raw)?;
        if raw.len() % 4 != 0 {
            return Err(Error::artifact(format!(
                "{}: not a multiple of 4 bytes ({} bytes; truncated?)",
                path.display(),
                raw.len()
            )));
        }
        let data: Vec<f32> = raw
            .chunks_exact(4)
            // a2q-lint: allow(panic-path) chunks_exact(4) yields only
            // 4-byte slices, so the conversion is infallible
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let mut out = Vec::new();
        for t in self
            .manifest
            .req("tensors")?
            .as_arr()
            .ok_or_else(|| Error::artifact("tensors not an array"))?
        {
            let tname = t.get("name").and_then(|v| v.as_str()).unwrap_or("<unnamed>");
            let shape_arr = t
                .req("shape")?
                .as_arr()
                .ok_or_else(|| Error::artifact(format!("tensor {tname}: bad shape")))?;
            let mut shape: Vec<i64> = Vec::with_capacity(shape_arr.len());
            let mut len: usize = 1;
            for (i, v) in shape_arr.iter().enumerate() {
                let d = v.as_f64().ok_or_else(|| {
                    Error::artifact(format!("tensor {tname}: shape[{i}] is not a number"))
                })?;
                // the old `product::<i64>().max(1)` let negative dims
                // sneak through as a bogus (possibly huge) element count
                if d < 0.0 || d.fract() != 0.0 || d > u32::MAX as f64 {
                    return Err(Error::artifact(format!(
                        "tensor {tname}: bad shape dim {d} at axis {i}"
                    )));
                }
                len = len.checked_mul(d as usize).ok_or_else(|| {
                    Error::artifact(format!("tensor {tname}: shape overflows"))
                })?;
                shape.push(d as i64);
            }
            let len = len.max(1);
            let off = t.req_f64("offset")?;
            if off < 0.0 || off.fract() != 0.0 {
                return Err(Error::artifact(format!(
                    "tensor {tname}: bad offset {off}"
                )));
            }
            let offset = off as usize;
            // the old unchecked `data[offset..offset + len]` panicked the
            // loader on a truncated weights.bin or an out-of-range offset
            let end = offset.checked_add(len).filter(|&e| e <= data.len());
            let Some(end) = end else {
                return Err(Error::artifact(format!(
                    "tensor {tname}: range [{offset}, {}) exceeds {} ({} f32 values) — \
                     truncated weights file or bad manifest offset",
                    offset as u64 + len as u64,
                    path.display(),
                    data.len()
                )));
            };
            out.push(super::engine::ExecInput::f32_shaped(
                data[offset..end].to_vec(),
                shape,
            ));
        }
        Ok(out)
    }
}

/// The `index.json` written by `aot.py`: all exported variants.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub dir: PathBuf,
    pub models: Vec<String>,
}

impl ArtifactIndex {
    /// Load `<artifacts>/models/index.json`.
    pub fn load(artifacts: &Path) -> Result<ArtifactIndex> {
        let dir = artifacts.join("models");
        let idx = json::parse_file(&dir.join("index.json")).map_err(|e| {
            Error::artifact(format!(
                "cannot read artifact index ({e}); run `make artifacts` first"
            ))
        })?;
        let models = idx
            .req("models")?
            .as_arr()
            .ok_or_else(|| Error::artifact("index.models not an array"))?
            .iter()
            .filter_map(|v| v.as_str().map(String::from))
            .collect();
        Ok(ArtifactIndex { dir, models })
    }

    pub fn artifact(&self, name: &str) -> Result<ModelArtifact> {
        if !self.models.iter().any(|m| m == name) {
            return Err(Error::artifact(format!(
                "model '{name}' not in index (have: {:?})",
                self.models
            )));
        }
        ModelArtifact::load(&self.dir, name)
    }

    pub fn all(&self) -> Result<Vec<ModelArtifact>> {
        self.models
            .iter()
            .map(|m| ModelArtifact::load(&self.dir, m))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build an artifact over a synthetic weights.bin (`n_f32` values) and
    /// a single declared tensor `{shape, offset}` in a fresh temp dir.
    fn tensor_fixture(tag: &str, n_f32: usize, shape: &str, offset: i64) -> ModelArtifact {
        let dir = std::env::temp_dir().join(format!("a2q_artifact_test_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        let mut raw = Vec::new();
        for i in 0..n_f32 {
            raw.extend_from_slice(&(i as f32).to_le_bytes());
        }
        std::fs::write(dir.join("weights.bin"), &raw).unwrap();
        let man = json::parse(&format!(
            r#"{{"weights_bin": "weights.bin",
                 "tensors": [{{"name": "w", "shape": {shape}, "offset": {offset}}}]}}"#
        ))
        .unwrap();
        ModelArtifact {
            name: tag.into(),
            dir,
            hlo_path: PathBuf::new(),
            dataset: "unit".into(),
            arch: "gcn".into(),
            method: "a2q".into(),
            node_level: true,
            num_nodes: 0,
            num_edges: 0,
            in_dim: 2,
            out_dim: 2,
            graph_capacity: 0,
            avg_bits: 4.0,
            accuracy: 0.0,
            expected_head: vec![],
            manifest: man,
        }
    }

    #[test]
    fn weight_inputs_in_range_loads() {
        let art = tensor_fixture("ok", 6, "[2, 2]", 2);
        let inputs = art.weight_inputs().unwrap();
        assert_eq!(inputs.len(), 1);
    }

    #[test]
    fn weight_inputs_rejects_truncated_weights_file() {
        // manifest says 2x2 at offset 2, file holds only 4 values
        let art = tensor_fixture("trunc", 4, "[2, 2]", 2);
        let err = art.weight_inputs().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("truncated"), "got: {msg}");
        assert!(msg.contains("tensor w"), "got: {msg}");
    }

    #[test]
    fn weight_inputs_rejects_out_of_range_offset() {
        let art = tensor_fixture("offrange", 6, "[2, 2]", 1_000_000);
        let err = art.weight_inputs().unwrap_err();
        assert!(format!("{err}").contains("exceeds"), "got: {err}");
        // negative offsets are malformed, not a silent cast to 0
        let art = tensor_fixture("offneg", 6, "[2, 2]", -4);
        let err = art.weight_inputs().unwrap_err();
        assert!(format!("{err}").contains("bad offset"), "got: {err}");
    }

    #[test]
    fn weight_inputs_rejects_negative_dim() {
        // old code: product([-2, -2]).max(1) = 4, slice passed silently
        let art = tensor_fixture("negdim", 6, "[-2, -2]", 0);
        let err = art.weight_inputs().unwrap_err();
        assert!(format!("{err}").contains("bad shape dim"), "got: {err}");
    }

    fn manifest_with_expected_head(tag: &str, expected_head: &str) -> (PathBuf, String) {
        let dir = std::env::temp_dir().join(format!("a2q_artifact_load_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        let name = format!("m_{tag}");
        let man = format!(
            r#"{{"hlo": "m.hlo", "dataset": "unit", "arch": "gcn", "method": "a2q",
                 "node_level": true, "num_nodes": 3, "num_edges": 2, "in_dim": 2,
                 "out_dim": 2, "graph_capacity": 0, "avg_bits": 4.0, "accuracy": 0.5,
                 "expected_head": {expected_head}}}"#
        );
        std::fs::write(dir.join(format!("{name}.manifest.json")), man).unwrap();
        (dir, name)
    }

    #[test]
    fn load_accepts_numeric_expected_head() {
        let (dir, name) = manifest_with_expected_head("ok", "[0.5, -1.5]");
        let art = ModelArtifact::load(&dir, &name).unwrap();
        assert_eq!(art.expected_head, vec![0.5, -1.5]);
    }

    #[test]
    fn load_rejects_non_array_expected_head() {
        // regression: unwrap_or_default turned this into an empty vec,
        // silently muting the downstream head-parity check
        let (dir, name) = manifest_with_expected_head("nonarr", r#""nope""#);
        let err = ModelArtifact::load(&dir, &name).unwrap_err();
        assert!(format!("{err}").contains("not an array"), "got: {err}");
    }

    #[test]
    fn load_rejects_non_numeric_expected_head_element() {
        let (dir, name) = manifest_with_expected_head("nonnum", r#"[1.0, "x", 2.0]"#);
        let err = ModelArtifact::load(&dir, &name).unwrap_err();
        assert!(format!("{err}").contains("expected_head[1]"), "got: {err}");
    }

    #[test]
    fn missing_index_gives_actionable_error() {
        let err = ArtifactIndex::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn param_map_parses_entry_only() {
        let hlo = r#"
region_0 {
  Arg_9.9 = f32[2]{0} parameter(0)
}

ENTRY main.42 {
  Arg_2.7 = s32[13534]{0} parameter(2)
  Arg_0.19 = f32[2708,1433]{1,0} parameter(0)
  Arg_1.11 = s32[13534]{0} parameter(1)
  Arg_3.1 = f32[13534]{0} parameter(3)
  ROOT t = (f32[2708,7]{1,0}) tuple(Arg_0.19)
}
"#;
        // position order 0..3 → logical 0,1,2,3 (sum_w / logical 4 dropped)
        assert_eq!(parse_param_map(hlo), vec![0, 1, 2, 3]);
    }

    #[test]
    fn param_map_reordered_logicals() {
        let hlo = "ENTRY e {\n  Arg_4.1 = f32[2]{0} parameter(0)\n  Arg_1.2 = f32[2]{0} parameter(1)\n}\n";
        assert_eq!(parse_param_map(hlo), vec![4, 1]);
    }
}
