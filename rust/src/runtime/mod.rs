//! PJRT runtime: load and execute the AOT artifacts on the request path.
//!
//! `python/compile/aot.py` lowers each trained model to **HLO text** (the
//! interchange format that round-trips through xla_extension 0.5.1 — jax ≥
//! 0.5 serialized protos carry 64-bit instruction ids it rejects).  This
//! module compiles those artifacts once on a `PjRtClient` and executes them
//! with zero python involvement.

pub mod artifact;
pub mod engine;
pub mod persist;

pub use artifact::{ArtifactIndex, ModelArtifact};
pub use engine::{Engine, EngineHandle, ExecInput};
pub use persist::{FsyncPolicy, PersistConfig, Persistence, Recovery, Snapshot};
