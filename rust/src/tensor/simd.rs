//! Runtime-dispatched SIMD kernels (AVX2 on x86_64, NEON on aarch64) for
//! the three hot loops of the bucketed integer path: packed-code
//! unpacking, the {−1,0,1} add/sub accumulator, and the axpy inner loops
//! of the dense matmuls.
//!
//! The scalar path is the always-available oracle: every vector kernel
//! here is **bitwise identical** to it.  For i32 kernels that is automatic
//! (integer arithmetic is exact and per-element order never changes); for
//! f32 the vector paths perform one multiply and one add per element —
//! two separately-rounded IEEE operations, never a fused multiply-add —
//! in the same ascending-j order as the scalar loop, so every lane rounds
//! exactly like its scalar counterpart.
//!
//! Dispatch is decided once per process by [`active`]: the best ISA the
//! CPU supports, overridable with `A2Q_SIMD={auto,avx2,neon,scalar}`.
//! Forcing an ISA the CPU (or build target) cannot run is a hard error,
//! not a silent scalar fallback — the CI ISA matrix relies on a forced
//! leg either exercising that ISA or failing loudly.  The decision rides
//! in [`ParallelConfig::simd`](crate::util::threadpool::ParallelConfig),
//! so tests can cross scalar/SIMD explicitly regardless of the env.

use std::sync::OnceLock;

/// An instruction-set choice for the kernels in this module.  All
/// variants exist on every architecture (so configs, logs and tests can
/// name them portably); [`Isa::available`] says whether the current CPU
/// can actually run one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isa {
    /// Plain Rust loops — the portable oracle every other path must match.
    Scalar,
    /// 256-bit AVX2 (x86_64; requires runtime CPU support).
    Avx2,
    /// 128-bit NEON (baseline on aarch64).
    Neon,
}

impl Isa {
    /// The `A2Q_SIMD` spelling of this ISA.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Whether this ISA's kernels can run on the current CPU/target.
    pub fn available(self) -> bool {
        match self {
            Isa::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            Isa::Avx2 => false,
            // NEON is part of the aarch64 baseline.
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => true,
            #[cfg(not(target_arch = "aarch64"))]
            Isa::Neon => false,
        }
    }
}

/// Best ISA the current CPU supports — what `A2Q_SIMD=auto` resolves to.
pub fn detect() -> Isa {
    if Isa::Avx2.available() {
        Isa::Avx2
    } else if Isa::Neon.available() {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Resolve an `A2Q_SIMD` setting to an ISA.  `None`, `""` and `auto` pick
/// [`detect`]; a named ISA must actually be available — forcing an
/// unavailable one is an error rather than a silent scalar fallback, so a
/// forced CI leg can never become vacuous.
pub fn resolve(request: Option<&str>) -> Result<Isa, String> {
    let req = request.map(|s| s.trim().to_ascii_lowercase());
    match req.as_deref() {
        None | Some("") | Some("auto") => Ok(detect()),
        Some("scalar") => Ok(Isa::Scalar),
        Some(name) => {
            let isa = match name {
                "avx2" => Isa::Avx2,
                "neon" => Isa::Neon,
                other => {
                    return Err(format!(
                        "A2Q_SIMD={other}: unknown ISA (expected auto|scalar|avx2|neon)"
                    ))
                }
            };
            if isa.available() {
                Ok(isa)
            } else {
                Err(format!(
                    "A2Q_SIMD={name}: {name} is not available on this CPU/target \
                     (refusing to silently fall back to scalar)"
                ))
            }
        }
    }
}

static ACTIVE: OnceLock<Isa> = OnceLock::new();

/// The process-wide dispatch decision: detected once on first use,
/// overridable via `A2Q_SIMD`.  Panics (descriptively) on an invalid or
/// unavailable forced value.
pub fn active() -> Isa {
    *ACTIVE.get_or_init(|| {
        resolve(std::env::var("A2Q_SIMD").ok().as_deref()).unwrap_or_else(|e| panic!("{e}"))
    })
}

/// The ISAs a parity test should cross on this machine: the scalar oracle
/// plus the active vector ISA when one is enabled.
pub fn parity_isas() -> Vec<Isa> {
    match active() {
        Isa::Scalar => vec![Isa::Scalar],
        isa => vec![Isa::Scalar, isa],
    }
}

// ---------------------------------------------------------------------------
// axpy / add / sub
// ---------------------------------------------------------------------------

/// `acc[j] += a * b[j]` — one multiply then one add per element, ascending
/// j.  Bitwise identical across ISAs: the vector paths round each element
/// through the same two IEEE operations as the scalar loop (no FMA).
#[inline]
pub fn axpy_f32(isa: Isa, acc: &mut [f32], a: f32, b: &[f32]) {
    debug_assert_eq!(acc.len(), b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: this arm only runs for Isa::Avx2, which resolve()/active()
        // hand out only after is_x86_feature_detected!("avx2") succeeded.
        Isa::Avx2 => unsafe { axpy_f32_avx2(acc, a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is part of the aarch64 baseline; Isa::Neon is only
        // constructible on targets where Isa::available() returned true.
        Isa::Neon => unsafe { axpy_f32_neon(acc, a, b) },
        _ => axpy_f32_scalar(acc, a, b),
    }
}

/// `acc[j] += c * b[j]`, exact i32.
#[inline]
pub fn axpy_i32(isa: Isa, acc: &mut [i32], c: i32, b: &[i32]) {
    debug_assert_eq!(acc.len(), b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only dispatched after the runtime CPUID
        // probe in Isa::available() proved AVX2 support.
        Isa::Avx2 => unsafe { axpy_i32_avx2(acc, c, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (Isa::available() is true).
        Isa::Neon => unsafe { axpy_i32_neon(acc, c, b) },
        _ => axpy_i32_scalar(acc, c, b),
    }
}

/// `acc[j] += b[j]`, exact i32 (the `+1` arm of the pm-one accumulator).
#[inline]
pub fn add_assign_i32(isa: Isa, acc: &mut [i32], b: &[i32]) {
    debug_assert_eq!(acc.len(), b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only dispatched after the runtime CPUID
        // probe in Isa::available() proved AVX2 support.
        Isa::Avx2 => unsafe { add_assign_i32_avx2(acc, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (Isa::available() is true).
        Isa::Neon => unsafe { add_assign_i32_neon(acc, b) },
        _ => add_assign_i32_scalar(acc, b),
    }
}

/// `acc[j] -= b[j]`, exact i32 (the `−1` arm of the pm-one accumulator).
#[inline]
pub fn sub_assign_i32(isa: Isa, acc: &mut [i32], b: &[i32]) {
    debug_assert_eq!(acc.len(), b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only dispatched after the runtime CPUID
        // probe in Isa::available() proved AVX2 support.
        Isa::Avx2 => unsafe { sub_assign_i32_avx2(acc, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64 (Isa::available() is true).
        Isa::Neon => unsafe { sub_assign_i32_neon(acc, b) },
        _ => sub_assign_i32_scalar(acc, b),
    }
}

#[inline]
fn axpy_f32_scalar(acc: &mut [f32], a: f32, b: &[f32]) {
    for (o, &bv) in acc.iter_mut().zip(b) {
        *o += a * bv;
    }
}

#[inline]
fn axpy_i32_scalar(acc: &mut [i32], c: i32, b: &[i32]) {
    for (o, &bv) in acc.iter_mut().zip(b) {
        *o += c * bv;
    }
}

#[inline]
fn add_assign_i32_scalar(acc: &mut [i32], b: &[i32]) {
    for (o, &bv) in acc.iter_mut().zip(b) {
        *o += bv;
    }
}

#[inline]
fn sub_assign_i32_scalar(acc: &mut [i32], b: &[i32]) {
    for (o, &bv) in acc.iter_mut().zip(b) {
        *o -= bv;
    }
}

// ---------------------------------------------------------------------------
// Packed-code unpacking
// ---------------------------------------------------------------------------

/// Decode `out.len()` codes of width `bits` (1..=8) starting at `base_bit`
/// of the u64 slab `words`, subtracting `bias` (the signed-range rebias).
///
/// Contract (same one the scalar const-generic unpackers in
/// `quant::pack` rely on, guaranteed by the bucket's trailing pad word):
/// one whole u64 must be readable past the word holding the last code's
/// first bit.  The AVX2/NEON paths turn that into 4-byte unaligned window
/// loads — a code spans at most 15 bits of its 32-bit window, and the pad
/// word keeps every window load inside the slab.
#[inline]
pub fn unpack_codes(
    isa: Isa,
    bits: usize,
    words: &[u64],
    base_bit: usize,
    bias: i32,
    out: &mut [i32],
) {
    if out.is_empty() {
        return;
    }
    debug_assert!((1..=8).contains(&bits));
    debug_assert!(
        ((base_bit + (out.len() - 1) * bits) >> 6) + 2 <= words.len(),
        "unpack_codes: slab too short for span + pad word"
    );
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only dispatched after the runtime CPUID
        // probe proved AVX2; the debug_assert above restates the slab
        // contract (pad word past the last code's first-bit word) that
        // every caller upholds, keeping all window loads inside `words`.
        Isa::Avx2 => unsafe { unpack_codes_avx2(bits, words, base_bit, bias, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64; same slab contract as the
        // AVX2 arm keeps the 4-byte window loads inside `words`.
        Isa::Neon => unsafe { unpack_codes_neon(bits, words, base_bit, bias, out) },
        _ => unpack_codes_scalar(bits, words, base_bit, bias, out),
    }
}

/// Runtime-width scalar decode — same window expression as the
/// const-generic `unpack_span_b` in `quant::pack` (exact integers, so the
/// two are trivially identical); also the tail path of the vector kernels.
#[inline]
fn unpack_codes_scalar(bits: usize, words: &[u64], base_bit: usize, bias: i32, out: &mut [i32]) {
    let mask = (1u64 << bits) - 1;
    let mut bit = base_bit;
    for slot in out.iter_mut() {
        let w = bit >> 6;
        let s = bit & 63;
        let lo = words[w] >> s;
        // (x << 1) << (63 - s) == x << (64 - s) without the UB shift at s = 0
        let hi = (words[w + 1] << 1) << (63 - s);
        *slot = ((lo | hi) & mask) as i32 - bias;
        bit += bits;
    }
}

// ---------------------------------------------------------------------------
// AVX2 (x86_64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32_avx2(acc: &mut [f32], a: f32, b: &[f32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees AVX2; `j + 8 <= n`
        // keeps every 8-lane unaligned load/store inside `acc` and `b`
        // (equal lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            let va = _mm256_set1_ps(a);
            while j + 8 <= n {
                let vb = _mm256_loadu_ps(bp.add(j));
                let vc = _mm256_loadu_ps(ap.add(j));
                // mul then add as two separately-rounded ops (never fmadd):
                // the scalar oracle rounds twice per element
                _mm256_storeu_ps(ap.add(j), _mm256_add_ps(vc, _mm256_mul_ps(va, vb)));
                j += 8;
            }
        }
        super::axpy_f32_scalar(&mut acc[j..], a, &b[j..]);
    }

    /// SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_i32_avx2(acc: &mut [i32], c: i32, b: &[i32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees AVX2; `j + 8 <= n`
        // keeps every 8-lane unaligned load/store inside `acc` and `b`
        // (equal lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            let vc = _mm256_set1_epi32(c);
            while j + 8 <= n {
                let vb = _mm256_loadu_si256(bp.add(j) as *const __m256i);
                let va = _mm256_loadu_si256(ap.add(j) as *const __m256i);
                let r = _mm256_add_epi32(va, _mm256_mullo_epi32(vc, vb));
                _mm256_storeu_si256(ap.add(j) as *mut __m256i, r);
                j += 8;
            }
        }
        super::axpy_i32_scalar(&mut acc[j..], c, &b[j..]);
    }

    /// SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_i32_avx2(acc: &mut [i32], b: &[i32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees AVX2; `j + 8 <= n`
        // keeps every 8-lane unaligned load/store inside `acc` and `b`
        // (equal lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            while j + 8 <= n {
                let vb = _mm256_loadu_si256(bp.add(j) as *const __m256i);
                let va = _mm256_loadu_si256(ap.add(j) as *const __m256i);
                _mm256_storeu_si256(ap.add(j) as *mut __m256i, _mm256_add_epi32(va, vb));
                j += 8;
            }
        }
        super::add_assign_i32_scalar(&mut acc[j..], &b[j..]);
    }

    /// SAFETY: caller must ensure AVX2 is available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sub_assign_i32_avx2(acc: &mut [i32], b: &[i32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees AVX2; `j + 8 <= n`
        // keeps every 8-lane unaligned load/store inside `acc` and `b`
        // (equal lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            while j + 8 <= n {
                let vb = _mm256_loadu_si256(bp.add(j) as *const __m256i);
                let va = _mm256_loadu_si256(ap.add(j) as *const __m256i);
                _mm256_storeu_si256(ap.add(j) as *mut __m256i, _mm256_sub_epi32(va, vb));
                j += 8;
            }
        }
        super::sub_assign_i32_scalar(&mut acc[j..], &b[j..]);
    }

    /// Eight codes per step via unaligned 32-bit window loads + a variable
    /// logical right shift.  Per-lane shift amounts are loop-invariant
    /// (8·bits is a whole number of bytes, so each lane's bit phase repeats)
    /// and per-lane byte offsets advance uniformly by `bits` bytes.
    ///
    /// SAFETY: caller must ensure AVX2 is available and uphold the
    /// [`super::unpack_codes`] slab contract (pad word ⇒ every 4-byte
    /// window load lands inside `words`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack_codes_avx2(
        bits: usize,
        words: &[u64],
        base_bit: usize,
        bias: i32,
        out: &mut [i32],
    ) {
        let n = out.len();
        let bytes = words.as_ptr() as *const u8;
        let mut offs = [0usize; 8];
        let mut sh = [0i32; 8];
        for (l, (o, s)) in offs.iter_mut().zip(sh.iter_mut()).enumerate() {
            let p = base_bit + l * bits;
            *o = p >> 3;
            *s = (p & 7) as i32;
        }
        let op = out.as_mut_ptr();
        let mut i = 0usize;
        let mut cursor = 0usize;
        // SAFETY: the target_feature contract guarantees AVX2.  Each lane's
        // 4-byte window starts at byte `offs[l] + cursor`, which the slab
        // contract (trailing pad word, debug_asserted by the dispatch
        // wrapper) keeps inside `words` at every step; the 8-lane stores
        // stay inside `out` because `i + 8 <= n`.
        unsafe {
            let vmask = _mm256_set1_epi32((1i32 << bits) - 1);
            let vbias = _mm256_set1_epi32(bias);
            let vshift = _mm256_set_epi32(sh[7], sh[6], sh[5], sh[4], sh[3], sh[2], sh[1], sh[0]);
            while i + 8 <= n {
                let ld = |l: usize| (bytes.add(offs[l] + cursor) as *const i32).read_unaligned();
                let win = _mm256_set_epi32(ld(7), ld(6), ld(5), ld(4), ld(3), ld(2), ld(1), ld(0));
                let v = _mm256_and_si256(_mm256_srlv_epi32(win, vshift), vmask);
                _mm256_storeu_si256(op.add(i) as *mut __m256i, _mm256_sub_epi32(v, vbias));
                i += 8;
                cursor += bits;
            }
        }
        super::unpack_codes_scalar(bits, words, base_bit + i * bits, bias, &mut out[i..]);
    }
}

#[cfg(target_arch = "x86_64")]
use avx2::{
    add_assign_i32_avx2, axpy_f32_avx2, axpy_i32_avx2, sub_assign_i32_avx2, unpack_codes_avx2,
};

// ---------------------------------------------------------------------------
// NEON (aarch64)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// SAFETY: caller must ensure NEON is available (aarch64 baseline).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_f32_neon(acc: &mut [f32], a: f32, b: &[f32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees NEON; `j + 4 <= n`
        // keeps every 4-lane load/store inside `acc` and `b` (equal
        // lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            let va = vdupq_n_f32(a);
            while j + 4 <= n {
                let vb = vld1q_f32(bp.add(j));
                let vc = vld1q_f32(ap.add(j));
                // separate mul + add (not vfmaq): two roundings, like scalar
                vst1q_f32(ap.add(j), vaddq_f32(vc, vmulq_f32(va, vb)));
                j += 4;
            }
        }
        super::axpy_f32_scalar(&mut acc[j..], a, &b[j..]);
    }

    /// SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy_i32_neon(acc: &mut [i32], c: i32, b: &[i32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees NEON; `j + 4 <= n`
        // keeps every 4-lane load/store inside `acc` and `b` (equal
        // lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            let vc = vdupq_n_s32(c);
            while j + 4 <= n {
                let vb = vld1q_s32(bp.add(j));
                let va = vld1q_s32(ap.add(j));
                vst1q_s32(ap.add(j), vaddq_s32(va, vmulq_s32(vc, vb)));
                j += 4;
            }
        }
        super::axpy_i32_scalar(&mut acc[j..], c, &b[j..]);
    }

    /// SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn add_assign_i32_neon(acc: &mut [i32], b: &[i32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees NEON; `j + 4 <= n`
        // keeps every 4-lane load/store inside `acc` and `b` (equal
        // lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            while j + 4 <= n {
                vst1q_s32(ap.add(j), vaddq_s32(vld1q_s32(ap.add(j)), vld1q_s32(bp.add(j))));
                j += 4;
            }
        }
        super::add_assign_i32_scalar(&mut acc[j..], &b[j..]);
    }

    /// SAFETY: caller must ensure NEON is available.
    #[target_feature(enable = "neon")]
    pub unsafe fn sub_assign_i32_neon(acc: &mut [i32], b: &[i32]) {
        let n = acc.len();
        let ap = acc.as_mut_ptr();
        let bp = b.as_ptr();
        let mut j = 0;
        // SAFETY: the target_feature contract guarantees NEON; `j + 4 <= n`
        // keeps every 4-lane load/store inside `acc` and `b` (equal
        // lengths, debug_asserted by the dispatch wrapper).
        unsafe {
            while j + 4 <= n {
                vst1q_s32(ap.add(j), vsubq_s32(vld1q_s32(ap.add(j)), vld1q_s32(bp.add(j))));
                j += 4;
            }
        }
        super::sub_assign_i32_scalar(&mut acc[j..], &b[j..]);
    }

    /// Eight codes per step (two 4-lane halves so the stride stays a whole
    /// number of bytes even at odd widths).  NEON has no variable right
    /// shift, so `vshlq_u32` by negated amounts performs the logical
    /// right shift.
    ///
    /// SAFETY: caller must ensure NEON is available and uphold the
    /// [`super::unpack_codes`] slab contract.
    #[target_feature(enable = "neon")]
    pub unsafe fn unpack_codes_neon(
        bits: usize,
        words: &[u64],
        base_bit: usize,
        bias: i32,
        out: &mut [i32],
    ) {
        let n = out.len();
        let bytes = words.as_ptr() as *const u8;
        let mut offs = [0usize; 8];
        let mut sh = [0i32; 8];
        for (l, (o, s)) in offs.iter_mut().zip(sh.iter_mut()).enumerate() {
            let p = base_bit + l * bits;
            *o = p >> 3;
            // vshlq by a negative amount shifts right (logical on u32)
            *s = -((p & 7) as i32);
        }
        let op = out.as_mut_ptr();
        let mut i = 0usize;
        let mut cursor = 0usize;
        // SAFETY: the target_feature contract guarantees NEON.  Each lane's
        // 4-byte window starts at byte `offs[l] + cursor`, which the slab
        // contract (trailing pad word, debug_asserted by the dispatch
        // wrapper) keeps inside `words` at every step; the two 4-lane
        // stores stay inside `out` because `i + 8 <= n`.
        unsafe {
            let vmask = vdupq_n_u32((1u32 << bits) - 1);
            let vbias = vdupq_n_s32(bias);
            let shift_lo = vld1q_s32(sh.as_ptr());
            let shift_hi = vld1q_s32(sh.as_ptr().add(4));
            while i + 8 <= n {
                let mut win = [0u32; 8];
                for (l, w) in win.iter_mut().enumerate() {
                    *w = (bytes.add(offs[l] + cursor) as *const u32).read_unaligned();
                }
                let lo = vshlq_u32(vld1q_u32(win.as_ptr()), shift_lo);
                let hi = vshlq_u32(vld1q_u32(win.as_ptr().add(4)), shift_hi);
                let lo = vsubq_s32(vreinterpretq_s32_u32(vandq_u32(lo, vmask)), vbias);
                let hi = vsubq_s32(vreinterpretq_s32_u32(vandq_u32(hi, vmask)), vbias);
                vst1q_s32(op.add(i), lo);
                vst1q_s32(op.add(i + 4), hi);
                i += 8;
                cursor += bits;
            }
        }
        super::unpack_codes_scalar(bits, words, base_bit + i * bits, bias, &mut out[i..]);
    }
}

#[cfg(target_arch = "aarch64")]
use neon::{
    add_assign_i32_neon, axpy_f32_neon, axpy_i32_neon, sub_assign_i32_neon, unpack_codes_neon,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};

    /// The ISA/dispatch CI matrix is only meaningful if a forced
    /// `A2Q_SIMD` leg really runs on the forced path.  This test reads the
    /// same env var the dispatcher does and pins the outcome — a silent
    /// scalar fallback on a forced leg fails here.
    #[test]
    fn forced_dispatch_is_honored_no_silent_fallback() {
        let req = std::env::var("A2Q_SIMD").ok();
        let got = active();
        match req.as_deref().map(str::trim) {
            Some("scalar") => assert_eq!(got, Isa::Scalar, "A2Q_SIMD=scalar not honored"),
            Some("avx2") => assert_eq!(got, Isa::Avx2, "A2Q_SIMD=avx2 not honored"),
            Some("neon") => assert_eq!(got, Isa::Neon, "A2Q_SIMD=neon not honored"),
            _ => assert_eq!(got, detect(), "auto must select the best available ISA"),
        }
        assert!(got.available());
    }

    #[test]
    fn resolve_accepts_auto_spellings() {
        assert_eq!(resolve(None).unwrap(), detect());
        assert_eq!(resolve(Some("")).unwrap(), detect());
        assert_eq!(resolve(Some("auto")).unwrap(), detect());
        assert_eq!(resolve(Some(" AUTO ")).unwrap(), detect());
        assert_eq!(resolve(Some("scalar")).unwrap(), Isa::Scalar);
    }

    #[test]
    fn resolve_rejects_unknown_and_unavailable() {
        assert!(resolve(Some("sse9")).is_err());
        for isa in [Isa::Avx2, Isa::Neon] {
            let r = resolve(Some(isa.name()));
            if isa.available() {
                assert_eq!(r.unwrap(), isa);
            } else {
                let msg = r.unwrap_err();
                assert!(msg.contains(isa.name()), "unhelpful error: {msg}");
            }
        }
    }

    #[test]
    fn parity_isas_starts_with_scalar_oracle() {
        let isas = parity_isas();
        assert_eq!(isas[0], Isa::Scalar);
        assert!(isas.iter().all(|i| i.available()));
        assert_eq!(isas.len(), if active() == Isa::Scalar { 1 } else { 2 });
    }

    /// Degenerate and boundary lengths every vector kernel must get right:
    /// empty, shorter than one lane, exactly one lane, lane+1, and a few
    /// non-multiples of both 4 (NEON) and 8 (AVX2) lanes.
    const LENGTHS: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 9, 12, 15, 16, 17, 31, 33, 63, 100];

    #[test]
    fn axpy_add_sub_i32_bitwise_match_scalar() {
        property("simd i32 kernels == scalar", 20, |g: &mut Gen| {
            for &n in LENGTHS {
                let acc0: Vec<i32> = (0..n).map(|_| g.usize_range(0, 4000) as i32 - 2000).collect();
                let b: Vec<i32> = (0..n).map(|_| g.usize_range(0, 255) as i32 - 127).collect();
                let c = g.usize_range(0, 255) as i32 - 127;
                for isa in parity_isas() {
                    let mut want = acc0.clone();
                    axpy_i32_scalar(&mut want, c, &b);
                    let mut got = acc0.clone();
                    axpy_i32(isa, &mut got, c, &b);
                    assert_eq!(want, got, "axpy_i32 {isa:?} n={n}");

                    let mut want = acc0.clone();
                    add_assign_i32_scalar(&mut want, &b);
                    let mut got = acc0.clone();
                    add_assign_i32(isa, &mut got, &b);
                    assert_eq!(want, got, "add_assign_i32 {isa:?} n={n}");

                    let mut want = acc0.clone();
                    sub_assign_i32_scalar(&mut want, &b);
                    let mut got = acc0.clone();
                    sub_assign_i32(isa, &mut got, &b);
                    assert_eq!(want, got, "sub_assign_i32 {isa:?} n={n}");
                }
            }
        });
    }

    #[test]
    fn axpy_f32_bitwise_matches_scalar() {
        property("simd axpy_f32 == scalar (bit patterns)", 20, |g: &mut Gen| {
            for &n in LENGTHS {
                let acc0 = g.vec_normal(n, 3.0);
                let b = g.vec_normal(n, 3.0);
                let a = g.vec_normal(1, 2.0)[0];
                for isa in parity_isas() {
                    let mut want = acc0.clone();
                    axpy_f32_scalar(&mut want, a, &b);
                    let mut got = acc0.clone();
                    axpy_f32(isa, &mut got, a, &b);
                    let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                    let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(wb, gb, "axpy_f32 {isa:?} n={n} not bitwise");
                }
            }
        });
    }

    #[test]
    fn unpack_codes_bitwise_matches_scalar_all_widths() {
        property("simd unpack == scalar, widths 1..=8", 20, |g: &mut Gen| {
            for bits in 1usize..=8 {
                // enough payload for the longest span at any base_bit, plus
                // the pad word the slab contract guarantees
                let n = *g.choose(&[0usize, 1, 3, 7, 8, 9, 17, 40, 101]);
                let base_bit = g.usize_range(0, 64);
                let words_needed = (base_bit + n * bits).div_ceil(64) + 1;
                let words: Vec<u64> = (0..words_needed)
                    .map(|_| {
                        (g.usize_range(0, 1 << 16) as u64)
                            | ((g.usize_range(0, 1 << 16) as u64) << 16)
                            | ((g.usize_range(0, 1 << 16) as u64) << 32)
                            | ((g.usize_range(0, 1 << 16) as u64) << 48)
                    })
                    .collect();
                let bias = if g.usize_range(0, 2) == 1 {
                    1i32 << (bits - 1)
                } else {
                    0
                };
                let mut want = vec![0i32; n];
                unpack_codes_scalar(bits, &words, base_bit, bias, &mut want);
                for isa in parity_isas() {
                    let mut got = vec![0i32; n];
                    unpack_codes(isa, bits, &words, base_bit, bias, &mut got);
                    assert_eq!(want, got, "unpack {isa:?} bits={bits} n={n} base={base_bit}");
                }
            }
        });
    }

    /// The trailing pad word is the load-bearing part of the slab contract:
    /// a span ending flush against the last payload word must decode
    /// without touching anything past the pad.
    #[test]
    fn unpack_codes_span_flush_to_pad_word() {
        for bits in 1usize..=8 {
            let n = 128 / bits; // exactly fills two payload words
            let words: Vec<u64> = vec![u64::MAX, 0xAAAA_5555_AAAA_5555, 0]; // + pad
            let mut want = vec![0i32; n];
            unpack_codes_scalar(bits, &words, 0, 0, &mut want);
            for isa in parity_isas() {
                let mut got = vec![0i32; n];
                unpack_codes(isa, bits, &words, 0, 0, &mut got);
                assert_eq!(want, got, "flush span {isa:?} bits={bits}");
            }
        }
    }
}
