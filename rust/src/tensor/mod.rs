//! Dense tensor substrate for the native (non-PJRT) inference path.
//!
//! A deliberately small surface: row-major `Matrix` over `f32` or `i32`,
//! with the kernels the GNN layers and the accelerator model need —
//! blocked matmul, elementwise ops, row/col scaling, softmax.  The hot
//! inner loops run through [`simd`]: explicit AVX2/NEON paths selected
//! once at runtime (overridable via `A2Q_SIMD`), each bitwise identical
//! to the scalar oracle (see benches/quant_kernels.rs and §Perf).

pub mod dense;
pub mod ops;
pub mod simd;

pub use dense::Matrix;
pub use ops::{
    matmul, matmul_codes_with, matmul_i32, matmul_i32_with, matmul_with, relu_inplace, row_scale,
    softmax_rows, WeightPanel,
};
pub use simd::Isa;
