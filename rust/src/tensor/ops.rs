//! Dense kernels: blocked matmul (f32 and i32-accumulate), elementwise ops.
//!
//! The matmuls are row-blocked and parallel: output rows are split into
//! disjoint contiguous chunks handed to scoped workers through
//! [`threadpool::parallel_for_chunks`], with a serial fallback below the
//! [`ParallelConfig::min_rows_per_task`] threshold (scoped-thread spawn
//! costs dominate tiny kernels).  `matmul`/`matmul_i32` use the process
//! default budget; the `*_with` variants take an explicit one.  Inner
//! loops dispatch through [`simd`] on [`ParallelConfig::simd`]; every
//! vector path is bitwise identical to the scalar oracle (exact i32;
//! f32 keeps the per-element mul-then-add rounding and ascending-k
//! order), so parity suites pin results across ISAs and thread counts.

use crate::util::threadpool::{self, ParallelConfig};

use super::dense::Matrix;
use super::simd::{self, Isa};

/// Cache block edge for the matmul kernels (tuned in §Perf; 64 keeps the
/// working set of a block-panel within L1/L2 on this machine).
const BLOCK: usize = 64;

/// Serial kernel over the output rows in `out` (which holds rows starting
/// at logical row `row0` of C), blocked over (i, k) with a j-innermost
/// axpy that runs vectorized under `isa` (C and B rows are contiguous).
fn matmul_rows_f32(a: &Matrix<f32>, b: &Matrix<f32>, isa: Isa, row0: usize, out: &mut [f32]) {
    let (k, n) = (a.cols, b.cols);
    let rows = out.len() / n;
    for i0 in (0..rows).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(rows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &a.data[(row0 + i) * k..(row0 + i + 1) * k];
                let crow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue; // features are sparse post-quantization
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    simd::axpy_f32(isa, crow, aik, brow);
                }
            }
        }
    }
}

fn matmul_rows_i32(a: &Matrix<i32>, b: &Matrix<i32>, isa: Isa, row0: usize, out: &mut [i32]) {
    let (k, n) = (a.cols, b.cols);
    let rows = out.len() / n;
    for i0 in (0..rows).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(rows);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &a.data[(row0 + i) * k..(row0 + i + 1) * k];
                let crow = &mut out[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let aik = arow[kk];
                    if aik == 0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    simd::axpy_i32(isa, crow, aik, brow);
                }
            }
        }
    }
}

/// C = A @ B with the process-default parallelism budget.
pub fn matmul(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
    matmul_with(a, b, &threadpool::global_parallelism())
}

/// C = A @ B, row-parallel under the given budget.  Each worker owns a
/// disjoint run of output rows, so results are bitwise identical to the
/// serial path regardless of thread count.
pub fn matmul_with(a: &Matrix<f32>, b: &Matrix<f32>, cfg: &ParallelConfig) -> Matrix<f32> {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let (m, n) = (a.rows, b.cols);
    let mut c = Matrix::zeros(m, n);
    threadpool::parallel_rows(cfg, m, n, &mut c.data, |row0, chunk| {
        matmul_rows_f32(a, b, cfg.simd, row0, chunk);
    });
    c
}

/// Integer-path matmul with the process-default parallelism budget.
pub fn matmul_i32(a: &Matrix<i32>, b: &Matrix<i32>) -> Matrix<i32> {
    matmul_i32_with(a, b, &threadpool::global_parallelism())
}

/// Integer-path matmul: i8-coded activations/weights (stored widened) with
/// i32 accumulation — the arithmetic the paper's accelerator performs.
/// Returns the raw i32 accumulators; rescale with [`rescale_outer`].
/// Row-parallel under the given budget.
pub fn matmul_i32_with(a: &Matrix<i32>, b: &Matrix<i32>, cfg: &ParallelConfig) -> Matrix<i32> {
    assert_eq!(a.cols, b.rows, "matmul_i32 shape mismatch");
    let (m, n) = (a.rows, b.cols);
    let mut c = Matrix::zeros(m, n);
    threadpool::parallel_rows(cfg, m, n, &mut c.data, |row0, chunk| {
        matmul_rows_i32(a, b, cfg.simd, row0, chunk);
    });
    c
}

/// Request-invariant integer weight codes in the layout the bucketed
/// kernels stream: quantization is per *output column* (each column has
/// its own step), but the codes are stored k-major — one contiguous panel
/// per input feature — and widened from their 4-bit range to `i32`, which
/// is exactly what the row-streaming accumulators ([`accumulate_code_row`],
/// `PackedFeatures::matmul_panel`) touch per nonzero activation code.
/// The type names and freezes that layout contract (the raw `Matrix<i32>`
/// codes already had it); it is built once at session preparation
/// (`gnn::prepared::PreparedModel`) and shared by every kernel call.
#[derive(Debug, Clone)]
pub struct WeightPanel {
    rows: usize,
    cols: usize,
    data: Vec<i32>,
}

impl WeightPanel {
    /// Take ownership of a `[k, n]` code matrix as the cached panel.
    pub fn from_codes(codes: Matrix<i32>) -> WeightPanel {
        WeightPanel {
            rows: codes.rows,
            cols: codes.cols,
            data: codes.data,
        }
    }

    /// Input dimension k (one panel row per activation feature).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Output dimension n.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The widened codes, k-major: `data()[kk*cols..(kk+1)*cols]` is the
    /// panel accumulated when activation code `kk` is nonzero.
    pub fn data(&self) -> &[i32] {
        &self.data
    }

    /// Resident bytes of the cached panel.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Whether every representable code at this bitwidth lies in {−1, 0, 1}
/// (signed b ≤ 2 has levels ≤ 1; unsigned b = 1 is {0, 1}) — the condition
/// for the add/sub-only accumulation fast path.
#[inline]
pub fn codes_fit_pm_one(bits: u8, signed: bool) -> bool {
    if signed {
        bits <= 2
    } else {
        bits <= 1
    }
}

/// Column-tile edge for [`accumulate_code_row`]: a 1024-column i32
/// accumulator tile (4 KB) stays L1-resident while the k-major panel rows
/// stream past it, so wide output layers do not evict the accumulator
/// between k steps.  Tiling only splits the j axis — each `acc[j]` still
/// accumulates over k in ascending order, so results are bitwise
/// identical to the untiled loop at any tile size.
const PANEL_TILE_COLS: usize = 1024;

/// One output row of the integer matmul: `acc[j] += Σ_k codes[k]·w[k][j]`,
/// ascending k with the zero-code skip.  `wdata` is a k-major panel of
/// `codes.len() × n` widened weight codes ([`WeightPanel::data`]); wide
/// panels are walked in [`PANEL_TILE_COLS`] column tiles so the streamed
/// panel stays cache-friendly.  When `pm_one` (see [`codes_fit_pm_one`])
/// the inner loop is add/sub-only — no multiplies.  The inner loops
/// dispatch on `isa`; i32 accumulation is exact, so the fast, general and
/// vector paths (and any row order around them) are bitwise identical.
/// This one helper is shared by the bucketed bucket-matmul, the
/// dense-code fallback, and the incremental row patcher so the arithmetic
/// cannot diverge.
pub fn accumulate_code_row(
    isa: Isa,
    codes: &[i32],
    wdata: &[i32],
    n: usize,
    pm_one: bool,
    acc: &mut [i32],
) {
    debug_assert_eq!(acc.len(), n);
    debug_assert_eq!(codes.len() * n, wdata.len());
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + PANEL_TILE_COLS).min(n);
        accumulate_code_tile(isa, codes, wdata, n, pm_one, j0, &mut acc[j0..j1]);
        j0 = j1;
    }
}

/// One column tile of [`accumulate_code_row`]: `acc_tile` covers output
/// columns `j0 .. j0 + acc_tile.len()`.
fn accumulate_code_tile(
    isa: Isa,
    codes: &[i32],
    wdata: &[i32],
    n: usize,
    pm_one: bool,
    j0: usize,
    acc_tile: &mut [i32],
) {
    let j1 = j0 + acc_tile.len();
    if pm_one {
        for (kk, &c) in codes.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let brow = &wdata[kk * n + j0..kk * n + j1];
            if c > 0 {
                simd::add_assign_i32(isa, acc_tile, brow);
            } else {
                simd::sub_assign_i32(isa, acc_tile, brow);
            }
        }
    } else {
        for (kk, &c) in codes.iter().enumerate() {
            if c == 0 {
                continue;
            }
            simd::axpy_i32(isa, acc_tile, c, &wdata[kk * n + j0..kk * n + j1]);
        }
    }
}

/// Dense-code matmul against a cached [`WeightPanel`]: `acc = a @ panel`,
/// i32-accumulated, row-parallel under `cfg`.  The unquantized-input branch
/// of the integer forward (unit-step raw codes) takes this route; quantized
/// maps stream off the bucketed packed payload instead
/// (`quant::pack::PackedFeatures::matmul_panel`).  Bitwise identical to
/// [`matmul_i32_with`] on the same operands (exact i32 sums).
pub fn matmul_codes_with(
    a: &Matrix<i32>,
    panel: &WeightPanel,
    cfg: &ParallelConfig,
) -> Matrix<i32> {
    assert_eq!(a.cols, panel.rows(), "code matmul shape mismatch");
    let (m, n) = (a.rows, panel.cols());
    let mut c = Matrix::zeros(m, n);
    threadpool::parallel_rows(cfg, m, n, &mut c.data, |row0, chunk| {
        for (ri, crow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a.data[(row0 + ri) * a.cols..(row0 + ri + 1) * a.cols];
            accumulate_code_row(cfg.simd, arow, panel.data(), n, false, crow);
        }
    });
    c
}

/// Eq. 2 rescale: out[i][j] = acc[i][j] * sx[i] * sw[j].
pub fn rescale_outer(acc: &Matrix<i32>, sx: &[f32], sw: &[f32]) -> Matrix<f32> {
    assert_eq!(acc.rows, sx.len());
    assert_eq!(acc.cols, sw.len());
    let mut out = Matrix::zeros(acc.rows, acc.cols);
    for i in 0..acc.rows {
        let si = sx[i];
        let arow = acc.row(i);
        let orow = out.row_mut(i);
        for j in 0..acc.cols {
            orow[j] = arow[j] as f32 * si * sw[j];
        }
    }
    out
}

/// In-place ReLU.
pub fn relu_inplace(m: &mut Matrix<f32>) {
    for v in &mut m.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place ELU (α = 1), used between GAT layers.
pub fn elu_inplace(m: &mut Matrix<f32>) {
    for v in &mut m.data {
        if *v < 0.0 {
            *v = v.exp() - 1.0;
        }
    }
}

/// In-place LeakyReLU with the given negative slope.
pub fn leaky_relu_inplace(m: &mut Matrix<f32>, slope: f32) {
    for v in &mut m.data {
        if *v < 0.0 {
            *v *= slope;
        }
    }
}

/// Scale each row i by s[i].
pub fn row_scale(m: &mut Matrix<f32>, s: &[f32]) {
    assert_eq!(m.rows, s.len());
    for i in 0..m.rows {
        let si = s[i];
        for v in m.row_mut(i) {
            *v *= si;
        }
    }
}

/// Add a bias row-vector to every row.
pub fn add_bias(m: &mut Matrix<f32>, bias: &[f32]) {
    assert_eq!(m.cols, bias.len());
    for i in 0..m.rows {
        let row = m.row_mut(i);
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Row-wise softmax (numerically stable).
pub fn softmax_rows(m: &mut Matrix<f32>) {
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};

    fn naive_matmul(a: &Matrix<f32>, b: &Matrix<f32>) -> Matrix<f32> {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *c.at_mut(i, j) = acc;
            }
        }
        c
    }

    #[test]
    fn matmul_small_exact() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = matmul(&a, &b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_matches_naive_property() {
        property("blocked matmul == naive", 25, |g: &mut Gen| {
            let m = g.usize_range(1, 90);
            let k = g.usize_range(1, 90);
            let n = g.usize_range(1, 90);
            let a = Matrix::from_vec(m, k, g.vec_normal(m * k, 1.0)).unwrap();
            let b = Matrix::from_vec(k, n, g.vec_normal(k * n, 1.0)).unwrap();
            let fast = matmul(&a, &b);
            let slow = naive_matmul(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-3);
        });
    }

    #[test]
    fn matmul_i32_and_rescale_match_f32() {
        property("int path == f32 path on integer codes", 25, |g: &mut Gen| {
            let m = g.usize_range(1, 40);
            let k = g.usize_range(1, 40);
            let n = g.usize_range(1, 40);
            let ai: Vec<i32> = (0..m * k).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let bi: Vec<i32> = (0..k * n).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let sx = g.vec_uniform(m, 0.01, 0.2);
            let sw = g.vec_uniform(n, 0.01, 0.2);
            let a_int = Matrix::from_vec(m, k, ai.clone()).unwrap();
            let b_int = Matrix::from_vec(k, n, bi.clone()).unwrap();
            let int_out = rescale_outer(&matmul_i32(&a_int, &b_int), &sx, &sw);

            let af: Vec<f32> = ai
                .iter()
                .enumerate()
                .map(|(idx, v)| *v as f32 * sx[idx / k])
                .collect();
            let bf: Vec<f32> = bi
                .iter()
                .enumerate()
                .map(|(idx, v)| *v as f32 * sw[idx % n])
                .collect();
            let a_f = Matrix::from_vec(m, k, af).unwrap();
            let b_f = Matrix::from_vec(k, n, bf).unwrap();
            let f_out = matmul(&a_f, &b_f);
            assert!(int_out.max_abs_diff(&f_out) < 1e-3);
        });
    }

    #[test]
    fn parallel_matmul_bitwise_matches_serial() {
        use crate::util::threadpool::ParallelConfig;
        property("parallel matmul == serial (f32/i32)", 15, |g: &mut Gen| {
            let m = g.usize_range(1, 200);
            let k = g.usize_range(1, 60);
            let n = g.usize_range(1, 60);
            // parallel runs the active (possibly SIMD) dispatch, the serial
            // reference is pinned scalar — one compare crosses both axes
            let par = ParallelConfig {
                threads: g.usize_range(2, 6),
                min_rows_per_task: g.usize_range(1, 16),
                ..ParallelConfig::serial()
            };
            let ser = ParallelConfig {
                simd: Isa::Scalar,
                ..ParallelConfig::serial()
            };

            let a = Matrix::from_vec(m, k, g.vec_normal(m * k, 1.0)).unwrap();
            let b = Matrix::from_vec(k, n, g.vec_normal(k * n, 1.0)).unwrap();
            assert_eq!(matmul_with(&a, &b, &par).data, matmul_with(&a, &b, &ser).data);

            let ai: Vec<i32> = (0..m * k).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let bi: Vec<i32> = (0..k * n).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let a_i = Matrix::from_vec(m, k, ai).unwrap();
            let b_i = Matrix::from_vec(k, n, bi).unwrap();
            assert_eq!(
                matmul_i32_with(&a_i, &b_i, &par).data,
                matmul_i32_with(&a_i, &b_i, &ser).data
            );
        });
    }

    #[test]
    fn activations() {
        let mut m = Matrix::from_vec(1, 3, vec![-1.0, 0.0, 2.0]).unwrap();
        relu_inplace(&mut m);
        assert_eq!(m.data, vec![0.0, 0.0, 2.0]);
        let mut m = Matrix::from_vec(1, 2, vec![-2.0, 3.0]).unwrap();
        leaky_relu_inplace(&mut m, 0.2);
        assert_eq!(m.data, vec![-0.4, 3.0]);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]).unwrap();
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f32 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn accumulate_code_row_fast_path_matches_general() {
        use crate::util::threadpool::ParallelConfig;
        property("±1 fast path == multiply path == dense matmul", 25, |g: &mut Gen| {
            let k = g.usize_range(1, 40);
            let n = g.usize_range(1, 24);
            // codes restricted to {-1, 0, 1} so both paths are legal
            let codes: Vec<i32> = (0..k).map(|_| g.usize_range(0, 3) as i32 - 1).collect();
            let wdata: Vec<i32> = (0..k * n).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let mut fast = vec![0i32; n];
            for isa in simd::parity_isas() {
                let mut f = vec![0i32; n];
                let mut slow = vec![0i32; n];
                accumulate_code_row(isa, &codes, &wdata, n, true, &mut f);
                accumulate_code_row(isa, &codes, &wdata, n, false, &mut slow);
                assert_eq!(f, slow, "{isa:?}: pm-one != multiply path");
                if isa == Isa::Scalar {
                    fast = f;
                } else {
                    assert_eq!(f, fast, "{isa:?}: simd != scalar oracle");
                }
            }
            let a = Matrix::from_vec(1, k, codes).unwrap();
            let b = Matrix::from_vec(k, n, wdata.clone()).unwrap();
            let dense = matmul_i32_with(&a, &b, &ParallelConfig::serial());
            assert_eq!(fast, dense.data);
            let panel = WeightPanel::from_codes(b);
            let via_panel = matmul_codes_with(&a, &panel, &ParallelConfig::serial());
            assert_eq!(fast, via_panel.data);
        });
    }

    /// The j-tiled accumulator must agree with an untiled reference even
    /// when n straddles tile boundaries (and with every ISA).
    #[test]
    fn accumulate_code_row_tiling_is_invisible() {
        property("j-tiled accumulate == untiled reference", 10, |g: &mut Gen| {
            let k = g.usize_range(1, 12);
            let n = *g.choose(&[1usize, 7, 1023, 1024, 1025, 2500]);
            let codes: Vec<i32> = (0..k).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let wdata: Vec<i32> = (0..k * n).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let mut want = vec![0i32; n];
            for (kk, &c) in codes.iter().enumerate() {
                if c == 0 {
                    continue;
                }
                for (o, &bv) in want.iter_mut().zip(&wdata[kk * n..(kk + 1) * n]) {
                    *o += c * bv;
                }
            }
            for isa in simd::parity_isas() {
                let mut got = vec![0i32; n];
                accumulate_code_row(isa, &codes, &wdata, n, false, &mut got);
                assert_eq!(want, got, "{isa:?} n={n}");
            }
        });
    }

    #[test]
    fn codes_fit_pm_one_table() {
        assert!(codes_fit_pm_one(1, true));
        assert!(codes_fit_pm_one(2, true));
        assert!(!codes_fit_pm_one(3, true));
        assert!(codes_fit_pm_one(1, false));
        assert!(!codes_fit_pm_one(2, false));
    }

    #[test]
    fn matmul_codes_matches_matmul_i32_property() {
        use crate::util::threadpool::ParallelConfig;
        property("panel matmul == dense i32 matmul", 20, |g: &mut Gen| {
            let m = g.usize_range(1, 60);
            let k = g.usize_range(1, 40);
            let n = g.usize_range(1, 20);
            let ai: Vec<i32> = (0..m * k).map(|_| g.usize_range(0, 255) as i32 - 127).collect();
            let bi: Vec<i32> = (0..k * n).map(|_| g.usize_range(0, 15) as i32 - 7).collect();
            let a = Matrix::from_vec(m, k, ai).unwrap();
            let b = Matrix::from_vec(k, n, bi).unwrap();
            let cfg = ParallelConfig {
                threads: g.usize_range(1, 5),
                min_rows_per_task: g.usize_range(1, 8),
                ..ParallelConfig::serial()
            };
            let want = matmul_i32_with(&a, &b, &cfg);
            let panel = WeightPanel::from_codes(b);
            assert_eq!(panel.rows(), k);
            assert_eq!(panel.cols(), n);
            assert_eq!(panel.bytes(), k * n * 4);
            let got = matmul_codes_with(&a, &panel, &cfg);
            assert_eq!(want.data, got.data);
        });
    }

    #[test]
    fn bias_and_row_scale() {
        let mut m = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        add_bias(&mut m, &[1.0, 2.0]);
        assert_eq!(m.data, vec![2.0, 3.0, 2.0, 3.0]);
        row_scale(&mut m, &[2.0, 0.5]);
        assert_eq!(m.data, vec![4.0, 6.0, 1.0, 1.5]);
    }
}
