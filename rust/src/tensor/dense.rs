//! Row-major dense matrix.

use crate::error::{Error, Result};

/// Row-major dense matrix over a copyable scalar.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix<T = f32> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Copy + Default> Matrix<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![T::default(); rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<T>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "Matrix::from_vec: {}x{} needs {} elements, got {}",
                rows,
                cols,
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> T {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut T {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[T] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Transpose (copy).
    pub fn transpose(&self) -> Matrix<T> {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Take a contiguous row slice [lo, hi) as a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix<T> {
        assert!(lo <= hi && hi <= self.rows);
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }
}

impl Matrix<f32> {
    /// Maximum absolute element of a row.
    pub fn row_abs_max(&self, r: usize) -> f32 {
        self.row(r).iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    /// Mean absolute value over the full matrix.
    pub fn abs_mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|v| v.abs()).sum::<f32>() / self.data.len() as f32
    }

    /// Max |a - b| over two equal-shaped matrices.
    pub fn max_abs_diff(&self, other: &Matrix<f32>) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Argmax per row (classification readout).
    pub fn argmax_rows(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                let mut best = 0;
                for (i, v) in row.iter().enumerate() {
                    if *v > row[best] {
                        best = i;
                    }
                }
                best
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Matrix::<f32>::from_vec(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let t = m.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.at(2, 1), 6.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn argmax_rows() {
        let m = Matrix::from_vec(2, 3, vec![0.1, 0.9, 0.0, 3.0, -1.0, 2.0]).unwrap();
        assert_eq!(m.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn row_abs_max_and_diff() {
        let m = Matrix::from_vec(1, 3, vec![-5.0, 2.0, 3.0]).unwrap();
        assert_eq!(m.row_abs_max(0), 5.0);
        let n = Matrix::from_vec(1, 3, vec![-5.0, 2.5, 3.0]).unwrap();
        assert_eq!(m.max_abs_diff(&n), 0.5);
    }

    #[test]
    fn slice_rows() {
        let m = Matrix::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let s = m.slice_rows(1, 3);
        assert_eq!(s.shape(), (2, 2));
        assert_eq!(s.at(0, 0), 3.0);
    }
}
