//! Nearest Neighbor Strategy runtime (Algorithm 1).
//!
//! The paper sorts the m learned `q_max = s·(2^{b-1}−1)` values offline and
//! binary-searches them per node at inference ("can be implemented by
//! binary searching"; the ASIC overlaps it with a comparator array).  This
//! is that lookup: O(log m) per node, allocation-free per query.

use crate::error::{Error, Result};

use super::uniform::levels;

/// Sorted NNS lookup table over m (step, bits) groups.
#[derive(Debug, Clone)]
pub struct NnsTable {
    /// sorted ascending
    qmax: Vec<f32>,
    /// (step, bits) in qmax-sorted order
    params: Vec<(f32, u8)>,
    /// original group index in qmax-sorted order (for gradient bookkeeping /
    /// diagnostics parity with python)
    orig_index: Vec<u32>,
}

impl NnsTable {
    pub fn new(steps: &[f32], bits: &[u8], signed: bool) -> NnsTable {
        assert_eq!(steps.len(), bits.len());
        let mut rows: Vec<(f32, (f32, u8), u32)> = steps
            .iter()
            .zip(bits)
            .enumerate()
            .map(|(i, (&s, &b))| (s * levels(b, signed) as f32, (s, b), i as u32))
            .collect();
        // stable sort keeps the python argmin tie-break (lower original
        // index wins among equal qmax); total_cmp keeps construction
        // panic-free even on NaN/Inf steps (a corrupt artifact must not be
        // able to DoS a runner thread — rejection happens at model-load
        // time via [`Self::try_new`] / `NodeQuantParams::new`)
        rows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.2.cmp(&b.2)));
        NnsTable {
            qmax: rows.iter().map(|r| r.0).collect(),
            params: rows.iter().map(|r| r.1).collect(),
            orig_index: rows.iter().map(|r| r.2).collect(),
        }
    }

    /// Validating constructor for the model-load / session-prepare
    /// boundary: rejects zero-length tables, length mismatches, and
    /// non-finite steps with a descriptive artifact error instead of
    /// leaving a table that panics (empty `select`) or mis-sorts at
    /// request time.
    pub fn try_new(steps: &[f32], bits: &[u8], signed: bool) -> Result<NnsTable> {
        if steps.is_empty() {
            return Err(Error::artifact("NNS table has no (step, bits) groups"));
        }
        if steps.len() != bits.len() {
            return Err(Error::artifact(format!(
                "NNS steps/bits length mismatch: {} vs {}",
                steps.len(),
                bits.len()
            )));
        }
        if let Some(i) = steps.iter().position(|s| !s.is_finite()) {
            return Err(Error::artifact(format!(
                "non-finite NNS step {} in group {i} (corrupt artifact?)",
                steps[i]
            )));
        }
        Ok(NnsTable::new(steps, bits, signed))
    }

    pub fn len(&self) -> usize {
        self.qmax.len()
    }

    pub fn is_empty(&self) -> bool {
        self.qmax.is_empty()
    }

    /// Binary-search the group whose q_max is nearest to `f`.
    /// Ties (equidistant neighbours) resolve to the lower original index,
    /// matching `jnp.argmin` in the python reference.
    pub fn select(&self, f: f32) -> (usize, f32, u8) {
        debug_assert!(!self.qmax.is_empty());
        let pos = self.qmax.partition_point(|&q| q < f);
        let candidates = [pos.checked_sub(1), Some(pos)];
        let mut best: Option<(f32, u32, usize)> = None;
        for cand in candidates.into_iter().flatten() {
            if cand >= self.qmax.len() {
                continue;
            }
            // rewind to the head of the equal-qmax run: within a run the
            // stable sort put the lowest original index first, which is the
            // argmin tie-break python uses.
            let mut cand = cand;
            while cand > 0 && self.qmax[cand - 1] == self.qmax[cand] {
                cand -= 1;
            }
            let dist = (self.qmax[cand] - f).abs();
            let key = (dist, self.orig_index[cand], cand);
            best = match best {
                None => Some(key),
                Some(cur) if (key.0, key.1) < (cur.0, cur.1) => Some(key),
                Some(cur) => Some(cur),
            };
        }
        let (_, _, idx) = best.expect("non-empty table");
        let (s, b) = self.params[idx];
        (self.orig_index[idx] as usize, s, b)
    }

    /// Checked [`Self::select`] for *online* assignment (unseen nodes at
    /// serving time, Algorithm 1 over a live aggregation value): a
    /// non-finite query means the caller's feature/activation row is
    /// corrupt, and silently assigning it a bitwidth would bake garbage
    /// into the resident quantization state — reject it instead.
    pub fn try_select(&self, f: f32) -> Result<(usize, f32, u8)> {
        if self.qmax.is_empty() {
            return Err(Error::artifact("NNS selection over an empty table"));
        }
        if !f.is_finite() {
            return Err(Error::dataset(format!(
                "non-finite aggregation value {f} rejected by NNS assignment"
            )));
        }
        Ok(self.select(f))
    }

    /// Select per row of a [N, F] matrix using the row max-|x| (Algorithm 1
    /// line 4-5). Returns (orig_index, step, bits) per row.
    pub fn select_rows(&self, x: &[f32], feat_dim: usize) -> Vec<(usize, f32, u8)> {
        x.chunks_exact(feat_dim)
            .map(|row| {
                let f = row.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                self.select(f)
            })
            .collect()
    }

    /// Linear-scan reference (used by tests and the crossover bench).
    pub fn select_linear(&self, f: f32) -> (usize, f32, u8) {
        let mut best = 0usize;
        let mut best_key = (f32::INFINITY, u32::MAX);
        for (i, &q) in self.qmax.iter().enumerate() {
            let key = ((q - f).abs(), self.orig_index[i]);
            if key < best_key {
                best_key = key;
                best = i;
            }
        }
        let (s, b) = self.params[best];
        (self.orig_index[best] as usize, s, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{property, Gen};

    #[test]
    fn picks_nearest() {
        // qmax: 0.1*7=0.7, 1.0*7=7.0
        let t = NnsTable::new(&[0.1, 1.0], &[4, 4], true);
        assert_eq!(t.select(0.6).0, 0);
        assert_eq!(t.select(6.0).0, 1);
        assert_eq!(t.select(100.0).0, 1);
        assert_eq!(t.select(0.0).0, 0);
    }

    #[test]
    fn binary_matches_linear_property() {
        property("nns binary == linear scan", 100, |g: &mut Gen| {
            let m = g.usize_range(1, 200);
            let steps = g.vec_uniform(m, 0.005, 0.5);
            let bits: Vec<u8> = (0..m).map(|_| g.usize_range(1, 9) as u8).collect();
            let t = NnsTable::new(&steps, &bits, true);
            for _ in 0..20 {
                let f = g.f32_range(0.0, 5.0);
                let (bi, bs, bb) = t.select(f);
                let (li, ls, lb) = t.select_linear(f);
                assert_eq!((bi, bs, bb), (li, ls, lb), "f={f}");
            }
        });
    }

    #[test]
    fn selection_minimises_distance_property() {
        property("nns argmin optimality", 50, |g: &mut Gen| {
            let m = g.usize_range(2, 64);
            let steps = g.vec_uniform(m, 0.01, 0.4);
            let bits: Vec<u8> = (0..m).map(|_| g.usize_range(2, 9) as u8).collect();
            let t = NnsTable::new(&steps, &bits, true);
            let f = g.f32_range(0.0, 4.0);
            let (idx, s, b) = t.select(f);
            let chosen_q = s * levels(b, true) as f32;
            for (st, bt) in steps.iter().zip(&bits) {
                let q = st * levels(*bt, true) as f32;
                assert!(
                    (chosen_q - f).abs() <= (q - f).abs() + 1e-6,
                    "group {idx} not optimal for f={f}"
                );
            }
        });
    }

    #[test]
    fn select_rows_uses_row_max() {
        let t = NnsTable::new(&[0.1, 1.0], &[4, 4], true);
        // row 0 max |x| = 0.5 -> group 0; row 1 max = 6 -> group 1
        let x = vec![0.5, -0.2, -6.0, 0.1];
        let picks = t.select_rows(&x, 2);
        assert_eq!(picks[0].0, 0);
        assert_eq!(picks[1].0, 1);
    }

    #[test]
    fn tie_breaks_to_lower_original_index() {
        // duplicate qmax values: groups 0 and 1 identical
        let t = NnsTable::new(&[0.1, 0.1, 0.2], &[4, 4, 4], true);
        assert_eq!(t.select(0.7).0, 0);
    }

    #[test]
    fn nan_steps_do_not_panic_construction() {
        // a corrupt artifact must not be able to DoS the runner: new()
        // sorts with total_cmp (NaN sorts last) instead of unwrapping
        let t = NnsTable::new(&[0.1, f32::NAN, 0.2], &[4, 4, 4], true);
        assert_eq!(t.len(), 3);
        // finite queries still resolve to a finite group
        let (_, s, _) = t.select(0.7);
        assert!(s.is_finite());
    }

    #[test]
    fn try_new_rejects_corrupt_tables() {
        let empty = NnsTable::try_new(&[], &[], true).unwrap_err();
        assert!(format!("{empty}").contains("no (step, bits) groups"));
        let mismatch = NnsTable::try_new(&[0.1, 0.2], &[4], true).unwrap_err();
        assert!(format!("{mismatch}").contains("length mismatch"));
        for bad in [f32::NAN, f32::INFINITY] {
            let err = NnsTable::try_new(&[0.1, bad], &[4, 4], true).unwrap_err();
            assert!(format!("{err}").contains("non-finite"));
        }
        assert!(NnsTable::try_new(&[0.1, 0.2], &[4, 4], true).is_ok());
    }

    #[test]
    fn unseen_node_assignment_matches_brute_force_scan() {
        // Online assignment for a node the model never saw: the chosen
        // (step, bits) must be exactly the argmin of |s·levels(b) − f|
        // over the learned table, with ties resolved to the lowest
        // original group index — an independent brute-force scan here, not
        // select_linear, so the two implementations can't share a bug.
        property("unseen-node NNS == brute force", 80, |g: &mut Gen| {
            let m = g.usize_range(1, 120);
            let mut steps = g.vec_uniform(m, 0.005, 0.5);
            if m >= 3 {
                // force exact duplicates so ties actually occur
                steps[m / 2] = steps[0];
            }
            let bits: Vec<u8> = (0..m)
                .map(|i| if i == m / 2 || i == 0 { 4 } else { g.usize_range(1, 9) as u8 })
                .collect();
            let t = NnsTable::new(&steps, &bits, true);
            for _ in 0..10 {
                let f = g.f32_range(0.0, 5.0);
                let (idx, s, b) = t.try_select(f).unwrap();
                let mut best = 0usize;
                let mut best_d = f32::INFINITY;
                for (i, (st, bt)) in steps.iter().zip(&bits).enumerate() {
                    let d = (st * levels(*bt, true) as f32 - f).abs();
                    if d < best_d {
                        best_d = d;
                        best = i;
                    }
                }
                assert_eq!(idx, best, "f={f}");
                assert_eq!((s, b), (steps[best], bits[best]), "f={f}");
            }
        });
    }

    #[test]
    fn try_select_rejects_non_finite_aggregation_values() {
        let t = NnsTable::new(&[0.1, 1.0], &[4, 4], true);
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let err = t.try_select(bad).unwrap_err();
            assert!(
                format!("{err}").contains("non-finite"),
                "expected non-finite rejection, got: {err}"
            );
        }
        // finite values (including 0 and the far tail) still assign
        assert_eq!(t.try_select(0.0).unwrap().0, 0);
        assert_eq!(t.try_select(1e30).unwrap().0, 1);
    }

    #[test]
    fn nan_property_select_never_picks_nan_for_finite_query() {
        property("nns with NaN groups still serves finite queries", 50, |g: &mut Gen| {
            let m = g.usize_range(2, 40);
            let mut steps = g.vec_uniform(m, 0.01, 0.4);
            let poison = g.usize_range(0, m);
            steps[poison] = f32::NAN;
            let bits: Vec<u8> = (0..m).map(|_| g.usize_range(2, 9) as u8).collect();
            let t = NnsTable::new(&steps, &bits, true);
            let f = g.f32_range(0.0, 3.0);
            let (_, s, _) = t.select(f);
            assert!(s.is_finite(), "selected NaN group for finite f={f}");
        });
    }
}
