//! Bit-packed feature storage.
//!
//! The paper's compression ratios are *memory* ratios: an m-bit node stores
//! its F features in m·F bits.  This module actually packs/unpacks codes at
//! arbitrary bitwidths 1..=8 (sign-magnitude is avoided by biasing signed
//! codes), proving the claimed memory layout is realizable and giving the
//! serving path a compact at-rest representation.

/// Packed feature map: each row packed at its own bitwidth.
#[derive(Debug, Clone)]
pub struct PackedFeatures {
    pub data: Vec<u8>,
    /// per row: (bit offset into data, bits, step)
    pub rows: Vec<(usize, u8, f32)>,
    pub feat_dim: usize,
    pub signed: bool,
}

/// Pack integer codes row-wise; row v uses bits[v] bits per element.
/// Signed codes c ∈ [−(2^{b−1}−1), 2^{b−1}−1] are stored biased by
/// +(2^{b−1}−1); unsigned codes stored raw.
pub fn pack_rows(
    codes: &[i32],
    steps: &[f32],
    bits: &[u8],
    feat_dim: usize,
    signed: bool,
) -> PackedFeatures {
    assert_eq!(codes.len(), steps.len() * feat_dim);
    assert_eq!(steps.len(), bits.len());
    let total_bits: usize = bits.iter().map(|&b| b as usize * feat_dim).sum();
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut rows = Vec::with_capacity(bits.len());
    let mut bitpos = 0usize;
    for (v, (&b, &s)) in bits.iter().zip(steps).enumerate() {
        rows.push((bitpos, b, s));
        let bias = if signed { (1i32 << (b.max(1) - 1)) - 1 } else { 0 };
        for &c in &codes[v * feat_dim..(v + 1) * feat_dim] {
            let raw = (c + bias) as u32;
            write_bits(&mut data, bitpos, b, raw);
            bitpos += b as usize;
        }
    }
    PackedFeatures {
        data,
        rows,
        feat_dim,
        signed,
    }
}

impl PackedFeatures {
    /// Unpack one row back to integer codes.
    pub fn unpack_row(&self, v: usize) -> Vec<i32> {
        let (start, b, _s) = self.rows[v];
        let bias = if self.signed {
            (1i32 << (b.max(1) - 1)) - 1
        } else {
            0
        };
        let mut out = Vec::with_capacity(self.feat_dim);
        let mut pos = start;
        for _ in 0..self.feat_dim {
            let raw = read_bits(&self.data, pos, b);
            out.push(raw as i32 - bias);
            pos += b as usize;
        }
        out
    }

    /// Dequantize one row.
    pub fn dequantize_row(&self, v: usize) -> Vec<f32> {
        let (_, _, s) = self.rows[v];
        self.unpack_row(v).into_iter().map(|c| c as f32 * s).collect()
    }

    /// Total storage in bytes (payload only).
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }
}

fn write_bits(data: &mut [u8], bitpos: usize, nbits: u8, value: u32) {
    debug_assert!(nbits <= 8 && (nbits == 32 || value < (1u32 << nbits)));
    let mut pos = bitpos;
    for i in 0..nbits {
        if (value >> i) & 1 == 1 {
            data[pos / 8] |= 1 << (pos % 8);
        }
        pos += 1;
    }
}

fn read_bits(data: &[u8], bitpos: usize, nbits: u8) -> u32 {
    let mut out = 0u32;
    let mut pos = bitpos;
    for i in 0..nbits {
        if (data[pos / 8] >> (pos % 8)) & 1 == 1 {
            out |= 1 << i;
        }
        pos += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::{levels, quantize_value};
    use crate::util::prop::{property, Gen};

    #[test]
    fn pack_unpack_roundtrip() {
        let steps = vec![0.1f32, 0.2];
        let bits = vec![3u8, 5];
        let codes = vec![1, -3, 0, 2, /* row1 */ 7, -15, 4, -1];
        let p = pack_rows(&codes, &steps, &bits, 4, true);
        assert_eq!(p.unpack_row(0), &codes[..4]);
        assert_eq!(p.unpack_row(1), &codes[4..]);
    }

    #[test]
    fn payload_matches_bit_accounting() {
        let steps = vec![0.1f32; 10];
        let bits = vec![2u8; 10];
        let codes = vec![0i32; 10 * 16];
        let p = pack_rows(&codes, &steps, &bits, 16, true);
        assert_eq!(p.payload_bytes(), (10 * 16 * 2 + 7) / 8);
    }

    #[test]
    fn roundtrip_property_with_real_quantizer() {
        property("pack roundtrip", 50, |g: &mut Gen| {
            let n = g.usize_range(1, 20);
            let f = g.usize_range(1, 24);
            let signed = g.bool(0.5);
            let steps = g.vec_uniform(n, 0.01, 0.3);
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
            let x = g.vec_normal(n * f, 1.0);
            let mut codes = vec![0i32; n * f];
            for v in 0..n {
                for j in 0..f {
                    codes[v * f + j] =
                        quantize_value(x[v * f + j], steps[v], bits[v], signed);
                }
            }
            let p = pack_rows(&codes, &steps, &bits, f, signed);
            for v in 0..n {
                assert_eq!(p.unpack_row(v), &codes[v * f..(v + 1) * f], "row {v}");
                let lv = levels(bits[v], signed);
                assert!(p.unpack_row(v).iter().all(|c| c.abs() <= lv));
            }
        });
    }

    #[test]
    fn dequantize_row_scales() {
        let p = pack_rows(&[3, -2], &[0.5], &[4], 2, true);
        assert_eq!(p.dequantize_row(0), vec![1.5, -1.0]);
    }
}
