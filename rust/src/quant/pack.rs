//! Bit-packed feature storage.
//!
//! The paper's compression ratios are *memory* ratios: an m-bit node stores
//! its F features in m·F bits.  This module actually packs/unpacks codes at
//! arbitrary bitwidths 1..=8 (sign-magnitude is avoided by biasing signed
//! codes), proving the claimed memory layout is realizable and giving the
//! serving path a compact at-rest representation.

use crate::util::threadpool::{self, ParallelConfig};

/// Packed feature map: each row packed at its own bitwidth.
#[derive(Debug, Clone)]
pub struct PackedFeatures {
    pub data: Vec<u8>,
    /// per row: (bit offset into data, bits, step)
    pub rows: Vec<(usize, u8, f32)>,
    pub feat_dim: usize,
    pub signed: bool,
}

/// Pack integer codes row-wise; row v uses bits[v] bits per element.
/// Signed codes c ∈ [−(2^{b−1}−1), 2^{b−1}−1] are stored biased by
/// +(2^{b−1}−1); unsigned codes stored raw.
///
/// `steps` are recorded verbatim as each row's dequantization scale (the
/// `sx` of the Eq. 2 rescale), so callers must pass the *same* clamped
/// steps the codes were quantized with — `NodeQuantParams` guarantees this
/// by flooring steps to [`crate::quant::uniform::MIN_STEP`] at
/// construction (a raw 0.0 step here would silently zero the row in
/// `rescale_outer`).
pub fn pack_rows(
    codes: &[i32],
    steps: &[f32],
    bits: &[u8],
    feat_dim: usize,
    signed: bool,
) -> PackedFeatures {
    assert_eq!(codes.len(), steps.len() * feat_dim);
    assert_eq!(steps.len(), bits.len());
    debug_assert!(
        steps.iter().all(|s| s.is_finite() && *s > 0.0),
        "pack_rows expects clamped finite steps (see NodeQuantParams::new)"
    );
    let total_bits: usize = bits.iter().map(|&b| b as usize * feat_dim).sum();
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut rows = Vec::with_capacity(bits.len());
    let mut bitpos = 0usize;
    for (v, (&b, &s)) in bits.iter().zip(steps).enumerate() {
        rows.push((bitpos, b, s));
        let bias = if signed { (1i32 << (b.max(1) - 1)) - 1 } else { 0 };
        for &c in &codes[v * feat_dim..(v + 1) * feat_dim] {
            let raw = (c + bias) as u32;
            write_bits(&mut data, bitpos, b, raw);
            bitpos += b as usize;
        }
    }
    PackedFeatures {
        data,
        rows,
        feat_dim,
        signed,
    }
}

/// Pack a **gathered subset** of rows — a shard's owned slab.  `codes`
/// holds the subset's rows contiguously (`ids.len() × feat_dim`), while
/// `steps`/`bits` are the *full* resident per-node vectors indexed by the
/// global ids in `ids`.  This is the sharded serving layout: each shard
/// keeps its owned rows bit-packed at their learned per-node widths, so a
/// mirror/halo payload is `Σ bits[v]·F` bits, not f32 rows.  Row `i` of
/// the result corresponds to global id `ids[i]`.
pub fn pack_rows_subset(
    codes: &[i32],
    steps: &[f32],
    bits: &[u8],
    ids: &[u32],
    feat_dim: usize,
    signed: bool,
) -> PackedFeatures {
    assert_eq!(codes.len(), ids.len() * feat_dim);
    assert_eq!(steps.len(), bits.len());
    let sub_steps: Vec<f32> = ids.iter().map(|&v| steps[v as usize]).collect();
    let sub_bits: Vec<u8> = ids.iter().map(|&v| bits[v as usize]).collect();
    pack_rows(codes, &sub_steps, &sub_bits, feat_dim, signed)
}

impl PackedFeatures {
    /// Number of packed rows.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    /// Per-row quantization steps, in row order (the `sx` of the Eq. 2
    /// rescale).
    pub fn steps(&self) -> Vec<f32> {
        self.rows.iter().map(|&(_, _, s)| s).collect()
    }

    /// Unpack one row into a caller-provided buffer (no allocation — the
    /// integer inference path reuses one scratch row per worker).
    pub fn unpack_row_into(&self, v: usize, out: &mut [i32]) {
        assert_eq!(out.len(), self.feat_dim);
        let (start, b, _s) = self.rows[v];
        let bias = if self.signed {
            (1i32 << (b.max(1) - 1)) - 1
        } else {
            0
        };
        let mut pos = start;
        for slot in out.iter_mut() {
            *slot = read_bits(&self.data, pos, b) as i32 - bias;
            pos += b as usize;
        }
    }

    /// Unpack one row back to integer codes.
    pub fn unpack_row(&self, v: usize) -> Vec<i32> {
        let mut out = vec![0i32; self.feat_dim];
        self.unpack_row_into(v, &mut out);
        out
    }

    /// Integer matmul straight off the packed payload: `acc = codes(self) @
    /// w`, i32-accumulated, row-parallel under `cfg`.  This is the serving
    /// hot path — the at-rest bit-packed representation feeds the update
    /// phase without ever materializing a dense `[N, F]` code matrix; each
    /// worker streams rows through one scratch buffer.  Rescale the result
    /// with [`crate::tensor::ops::rescale_outer`] using [`Self::steps`].
    pub fn matmul_i32(
        &self,
        w: &crate::tensor::Matrix<i32>,
        cfg: &ParallelConfig,
    ) -> crate::tensor::Matrix<i32> {
        assert_eq!(self.feat_dim, w.rows, "packed matmul shape mismatch");
        let (m, n) = (self.rows.len(), w.cols);
        let mut c = crate::tensor::Matrix::zeros(m, n);
        threadpool::parallel_rows(cfg, m, n, &mut c.data, |row0, chunk| {
            let mut scratch = vec![0i32; self.feat_dim];
            for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                self.unpack_row_into(row0 + ri, &mut scratch);
                for (kk, &code) in scratch.iter().enumerate() {
                    if code == 0 {
                        continue;
                    }
                    let brow = &w.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += code * brow[j];
                    }
                }
            }
        });
        c
    }

    /// Dequantize one row.
    pub fn dequantize_row(&self, v: usize) -> Vec<f32> {
        let (_, _, s) = self.rows[v];
        self.unpack_row(v).into_iter().map(|c| c as f32 * s).collect()
    }

    /// Total storage in bytes (payload only).
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }
}

fn write_bits(data: &mut [u8], bitpos: usize, nbits: u8, value: u32) {
    debug_assert!(nbits <= 8 && (nbits == 32 || value < (1u32 << nbits)));
    let mut pos = bitpos;
    for i in 0..nbits {
        if (value >> i) & 1 == 1 {
            data[pos / 8] |= 1 << (pos % 8);
        }
        pos += 1;
    }
}

fn read_bits(data: &[u8], bitpos: usize, nbits: u8) -> u32 {
    let mut out = 0u32;
    let mut pos = bitpos;
    for i in 0..nbits {
        if (data[pos / 8] >> (pos % 8)) & 1 == 1 {
            out |= 1 << i;
        }
        pos += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::{levels, quantize_value};
    use crate::util::prop::{property, Gen};

    #[test]
    fn pack_unpack_roundtrip() {
        let steps = vec![0.1f32, 0.2];
        let bits = vec![3u8, 5];
        let codes = vec![1, -3, 0, 2, /* row1 */ 7, -15, 4, -1];
        let p = pack_rows(&codes, &steps, &bits, 4, true);
        assert_eq!(p.unpack_row(0), &codes[..4]);
        assert_eq!(p.unpack_row(1), &codes[4..]);
    }

    #[test]
    fn payload_matches_bit_accounting() {
        let steps = vec![0.1f32; 10];
        let bits = vec![2u8; 10];
        let codes = vec![0i32; 10 * 16];
        let p = pack_rows(&codes, &steps, &bits, 16, true);
        assert_eq!(p.payload_bytes(), (10 * 16 * 2 + 7) / 8);
    }

    #[test]
    fn roundtrip_property_with_real_quantizer() {
        property("pack roundtrip", 50, |g: &mut Gen| {
            let n = g.usize_range(1, 20);
            let f = g.usize_range(1, 24);
            let signed = g.bool(0.5);
            let steps = g.vec_uniform(n, 0.01, 0.3);
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
            let x = g.vec_normal(n * f, 1.0);
            let mut codes = vec![0i32; n * f];
            for v in 0..n {
                for j in 0..f {
                    codes[v * f + j] =
                        quantize_value(x[v * f + j], steps[v], bits[v], signed);
                }
            }
            let p = pack_rows(&codes, &steps, &bits, f, signed);
            for v in 0..n {
                assert_eq!(p.unpack_row(v), &codes[v * f..(v + 1) * f], "row {v}");
                let lv = levels(bits[v], signed);
                assert!(p.unpack_row(v).iter().all(|c| c.abs() <= lv));
            }
        });
    }

    #[test]
    fn dequantize_row_scales() {
        let p = pack_rows(&[3, -2], &[0.5], &[4], 2, true);
        assert_eq!(p.dequantize_row(0), vec![1.5, -1.0]);
    }

    #[test]
    fn unpack_row_into_matches_unpack_row() {
        let codes = vec![1, -3, 0, 2, 7, -15, 4, -1];
        let p = pack_rows(&codes, &[0.1, 0.2], &[3, 5], 4, true);
        let mut buf = vec![0i32; 4];
        for v in 0..2 {
            p.unpack_row_into(v, &mut buf);
            assert_eq!(buf, p.unpack_row(v));
        }
        assert_eq!(p.num_rows(), 2);
        assert_eq!(p.steps(), vec![0.1, 0.2]);
    }

    #[test]
    fn pack_rows_subset_matches_full_pack() {
        property("shard slab == sliced full pack", 25, |g: &mut Gen| {
            let n = g.usize_range(2, 30);
            let f = g.usize_range(1, 16);
            let signed = g.bool(0.5);
            let steps = g.vec_uniform(n, 0.01, 0.3);
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
            let x = g.vec_normal(n * f, 1.0);
            let mut codes = vec![0i32; n * f];
            for v in 0..n {
                for j in 0..f {
                    codes[v * f + j] = quantize_value(x[v * f + j], steps[v], bits[v], signed);
                }
            }
            // a random ascending subset of rows (a shard's owned block)
            let ids: Vec<u32> =
                (0..n as u32).filter(|_| g.bool(0.6)).collect();
            let sub_codes: Vec<i32> = ids
                .iter()
                .flat_map(|&v| codes[v as usize * f..(v as usize + 1) * f].to_vec())
                .collect();
            let slab = pack_rows_subset(&sub_codes, &steps, &bits, &ids, f, signed);
            let full = pack_rows(&codes, &steps, &bits, f, signed);
            assert_eq!(slab.num_rows(), ids.len());
            for (li, &v) in ids.iter().enumerate() {
                assert_eq!(slab.unpack_row(li), full.unpack_row(v as usize), "row {v}");
                assert_eq!(slab.steps()[li], steps[v as usize]);
            }
        });
    }

    #[test]
    fn packed_matmul_matches_dense_codes_property() {
        use crate::tensor::{ops, Matrix};
        property("packed matmul == dense i32 matmul", 25, |g: &mut Gen| {
            let n = g.usize_range(1, 80);
            let f = g.usize_range(1, 32);
            let cols = g.usize_range(1, 16);
            let signed = g.bool(0.5);
            let steps = g.vec_uniform(n, 0.01, 0.3);
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
            let x = g.vec_normal(n * f, 1.0);
            let mut codes = vec![0i32; n * f];
            for v in 0..n {
                for j in 0..f {
                    codes[v * f + j] = quantize_value(x[v * f + j], steps[v], bits[v], signed);
                }
            }
            let packed = pack_rows(&codes, &steps, &bits, f, signed);
            let w = Matrix::from_vec(
                f,
                cols,
                (0..f * cols).map(|i| (i % 15) as i32 - 7).collect(),
            )
            .unwrap();
            let cfg = crate::util::threadpool::ParallelConfig {
                threads: g.usize_range(1, 5),
                min_rows_per_task: g.usize_range(1, 8),
            };
            let dense = Matrix::from_vec(n, f, codes).unwrap();
            let want = ops::matmul_i32_with(&dense, &w, &cfg);
            let got = packed.matmul_i32(&w, &cfg);
            assert_eq!(got.data, want.data);
        });
    }
}
