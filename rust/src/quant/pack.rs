//! Bit-packed feature storage, **bucketed by bitwidth** so compute cost
//! scales with each node's assigned bits.
//!
//! The paper's compression ratios are *memory* ratios: an m-bit node stores
//! its F features in m·F bits.  Its headline hardware result (§5.4, up to
//! 2× on a dedicated accelerator) additionally *exploits* the learned
//! widths at compute time.  This module realizes both on CPU:
//!
//! * **Layout** — rows are grouped into per-bitwidth buckets (b ∈ 1..=8).
//!   Each bucket owns a word-aligned `u64` slab: every row starts at a
//!   fresh 64-bit word (`words_per_row = ⌈b·F/64⌉`, one trailing pad word
//!   per slab so decoders may over-read one word), with codes packed
//!   contiguously inside the row.  `Bucket::rows` is the permutation from
//!   bucket-local row order back to global row ids.
//! * **Decode** — per-bitwidth specialized unpackers (const-generic
//!   `b = 1..=8`, match-dispatched once per bucket) extract each code from
//!   a 64-bit window with shifts and a mask: no per-bit loop, no
//!   data-dependent branches.  Under a vector ISA
//!   ([`ParallelConfig::simd`], see [`crate::tensor::simd`]) whole spans
//!   decode eight codes at a time instead — bitwise identical (exact
//!   integers).  The old element-by-element [`read_bits`] decoder survives
//!   as the *reference kernel* ([`PackedFeatures::matmul_i32_scratch`],
//!   pinned fully scalar) — the parity oracle the bucketed kernels are
//!   property-tested against and the baseline the `quant/bucketed_speedup`
//!   bench metric is measured from.
//! * **Accumulate** — buckets whose codes lie in {−1, 0, 1} (signed b ≤ 2,
//!   unsigned b = 1) take an add/sub-only inner loop
//!   ([`crate::tensor::ops::accumulate_code_row`], shared with the
//!   incremental row patcher so the arithmetic cannot diverge).
//!
//! **Reordering is bitwise safe:** the integer matmul accumulates in
//! `i32`, which is exact — every row's output is a sum of integer products
//! independent of which bucket computed it or in what order, and each
//! global row lives in exactly one bucket, so scattering bucket-local
//! results back through the permutation reproduces the unbucketed kernel
//! bit for bit (property-tested here and in `rust/tests/forward_parity.rs`
//! / `shard_parity.rs` / `delta_parity.rs`).
//!
//! Sign-magnitude is avoided by biasing signed codes before packing.

use crate::tensor::dense::Matrix;
use crate::tensor::ops::{self, WeightPanel};
use crate::tensor::simd::{self, Isa};
use crate::util::threadpool::{self, ParallelConfig};

/// Bias added to signed codes before packing so the stored value is
/// non-negative: `c ∈ [−levels, levels]` maps to `[0, 2·levels]`.
#[inline]
fn bias_for(bits: u8, signed: bool) -> i32 {
    if signed {
        (1i32 << (bits.max(1) - 1)) - 1
    } else {
        0
    }
}

/// One bitwidth's rows: a word-aligned slab plus the permutation back to
/// global row order.
#[derive(Debug, Clone)]
pub struct Bucket {
    /// Effective bitwidth of every row in this bucket (1..=8).
    pub bits: u8,
    /// `⌈bits · feat_dim / 64⌉` — each bucket-local row starts at word
    /// `local · words_per_row`.
    pub words_per_row: usize,
    /// The slab: `rows.len() · words_per_row` payload words plus one
    /// trailing pad word (decoders read one word past a code's start).
    pub words: Vec<u64>,
    /// Permutation: bucket-local row `li` holds global row `rows[li]`.
    pub rows: Vec<u32>,
}

impl Bucket {
    /// Number of rows in this bucket.
    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    #[inline]
    fn base_bit(&self, local: usize) -> usize {
        local * self.words_per_row * 64
    }

    /// Decode bucket-local row `local` into `out` (length = feat_dim),
    /// through the per-bitwidth specialized unpacker (or the `isa` vector
    /// decoder — bitwise identical).
    #[inline]
    fn unpack_local_into(&self, local: usize, signed: bool, isa: Isa, out: &mut [i32]) {
        let bias = bias_for(self.bits, signed);
        unpack_span(isa, self.bits, &self.words, self.base_bit(local), bias, out);
    }
}

/// Packed feature map: rows grouped into per-bitwidth buckets.
#[derive(Debug, Clone)]
pub struct PackedFeatures {
    /// Non-empty buckets in ascending bitwidth order.
    pub buckets: Vec<Bucket>,
    /// Per global row: (bucket index, bucket-local row).
    row_loc: Vec<(u32, u32)>,
    /// Per-row quantization steps in **global row order** — the dedicated
    /// slice-returnable field behind [`Self::steps`] (the integer forward
    /// reads it per layer; no per-call Vec is built).
    steps: Vec<f32>,
    /// Per-row recorded bitwidths, global row order.
    bits: Vec<u8>,
    pub feat_dim: usize,
    pub signed: bool,
}

/// Pack integer codes row-wise; row v uses bits[v] bits per element.
/// Signed codes c ∈ [−(2^{b−1}−1), 2^{b−1}−1] are stored biased by
/// +(2^{b−1}−1); unsigned codes stored raw.
///
/// `steps` are recorded verbatim as each row's dequantization scale (the
/// `sx` of the Eq. 2 rescale), so callers must pass the *same* clamped
/// steps the codes were quantized with — `NodeQuantParams` guarantees this
/// by flooring steps to [`crate::quant::uniform::MIN_STEP`] at
/// construction (a raw 0.0 step here would silently zero the row in
/// `rescale_outer`).
///
/// Widths above 8 are a hard error here (the bucketed kernels dispatch on
/// 1..=8); `NodeQuantParams::new` rejects such artifacts at load time so
/// the serving path never reaches this assert.
pub fn pack_rows(
    codes: &[i32],
    steps: &[f32],
    bits: &[u8],
    feat_dim: usize,
    signed: bool,
) -> PackedFeatures {
    assert_eq!(codes.len(), steps.len() * feat_dim);
    assert_eq!(steps.len(), bits.len());
    debug_assert!(
        steps.iter().all(|s| s.is_finite() && *s > 0.0),
        "pack_rows expects clamped finite steps (see NodeQuantParams::new)"
    );
    let n = bits.len();
    // first pass: rows per effective width (b = 0 is tolerated as an
    // all-zero-codes row and folded into the 1-bit bucket — same bias,
    // same decode)
    let mut count = [0usize; 9];
    for &b in bits {
        let be = b.max(1) as usize;
        assert!(be <= 8, "bitwidths are 1..=8, got {b}");
        count[be] += 1;
    }
    let mut bucket_of_width = [usize::MAX; 9];
    let mut buckets = Vec::new();
    for (be, &cnt) in count.iter().enumerate().skip(1) {
        if cnt > 0 {
            bucket_of_width[be] = buckets.len();
            let wpr = (be * feat_dim).div_ceil(64);
            buckets.push(Bucket {
                bits: be as u8,
                words_per_row: wpr,
                words: vec![0u64; cnt * wpr + 1],
                rows: Vec::with_capacity(cnt),
            });
        }
    }
    // second pass: scatter each row into its bucket's slab
    let mut row_loc = vec![(0u32, 0u32); n];
    for (v, &b) in bits.iter().enumerate() {
        let be = b.max(1) as usize;
        let bi = bucket_of_width[be];
        let bk = &mut buckets[bi];
        let local = bk.rows.len();
        bk.rows.push(v as u32);
        row_loc[v] = (bi as u32, local as u32);
        let bias = bias_for(b, signed);
        let lv = crate::quant::uniform::levels(b.max(1), signed);
        let mut bit = local * bk.words_per_row * 64;
        for &c in &codes[v * feat_dim..(v + 1) * feat_dim] {
            // codes must be quantizer output (|c| <= levels, unsigned >= 0):
            // the pm-one fast path relies on low-bit codes really being in
            // {-1, 0, 1}, so an out-of-range code would silently diverge
            // from the scratch reference in release builds
            debug_assert!(
                c.abs() <= lv && (signed || c >= 0),
                "code {c} out of range for {b}-bit signed={signed} row {v}"
            );
            let raw = (c + bias) as u32 as u64;
            write_bits(&mut bk.words, bit, be as u8, raw);
            bit += be;
        }
    }
    PackedFeatures {
        buckets,
        row_loc,
        steps: steps.to_vec(),
        bits: bits.to_vec(),
        feat_dim,
        signed,
    }
}

/// Pack a **gathered subset** of rows — a shard's owned slab.  `codes`
/// holds the subset's rows contiguously (`ids.len() × feat_dim`), while
/// `steps`/`bits` are the *full* resident per-node vectors indexed by the
/// global ids in `ids`.  This is the sharded serving layout: each shard
/// keeps its owned rows bit-packed at their learned per-node widths, so a
/// mirror/halo payload is `Σ bits[v]·F` bits, not f32 rows.  Row `i` of
/// the result corresponds to global id `ids[i]`.
pub fn pack_rows_subset(
    codes: &[i32],
    steps: &[f32],
    bits: &[u8],
    ids: &[u32],
    feat_dim: usize,
    signed: bool,
) -> PackedFeatures {
    assert_eq!(codes.len(), ids.len() * feat_dim);
    assert_eq!(steps.len(), bits.len());
    let sub_steps: Vec<f32> = ids.iter().map(|&v| steps[v as usize]).collect();
    let sub_bits: Vec<u8> = ids.iter().map(|&v| bits[v as usize]).collect();
    // the same clamped-steps invariant pack_rows enforces — intentionally
    // re-asserted here on the *gathered* steps (shadowing the downstream
    // check) so a violation names the shard-slab gather, not the generic
    // pack: a slab must not smuggle a raw 0.0 step past the Eq. 2 rescale
    debug_assert!(
        sub_steps.iter().all(|s| s.is_finite() && *s > 0.0),
        "pack_rows_subset expects clamped finite steps for every gathered id"
    );
    pack_rows(codes, &sub_steps, &sub_bits, feat_dim, signed)
}

impl PackedFeatures {
    /// Number of packed rows.
    pub fn num_rows(&self) -> usize {
        self.row_loc.len()
    }

    /// Per-row quantization steps, in global row order (the `sx` of the
    /// Eq. 2 rescale).  A borrowed slice of the dedicated field — callers
    /// feed it straight to `rescale_outer` without allocating.
    pub fn steps(&self) -> &[f32] {
        &self.steps
    }

    /// Per-row recorded bitwidths, global row order.
    pub fn bits(&self) -> &[u8] {
        &self.bits
    }

    /// Unpack one row into a caller-provided buffer (no allocation — the
    /// integer inference path reuses one scratch row per worker).  Routes
    /// through the bucketed per-bitwidth unpacker under the process-wide
    /// SIMD dispatch (kernels wanting an explicit ISA go through the
    /// `ParallelConfig`-taking entry points).
    pub fn unpack_row_into(&self, v: usize, out: &mut [i32]) {
        assert_eq!(out.len(), self.feat_dim);
        let (bi, li) = self.row_loc[v];
        self.buckets[bi as usize].unpack_local_into(li as usize, self.signed, simd::active(), out);
    }

    /// Unpack one row back to integer codes.
    pub fn unpack_row(&self, v: usize) -> Vec<i32> {
        let mut out = vec![0i32; self.feat_dim];
        self.unpack_row_into(v, &mut out);
        out
    }

    /// Reference decode of one row through the per-element bit loop
    /// ([`read_bits`]) — the pre-bucketing kernel, kept as the parity
    /// oracle and bench baseline.
    fn unpack_row_into_ref(&self, v: usize, out: &mut [i32]) {
        assert_eq!(out.len(), self.feat_dim);
        let (bi, li) = self.row_loc[v];
        let bk = &self.buckets[bi as usize];
        let bias = bias_for(bk.bits, self.signed);
        let mut pos = bk.base_bit(li as usize);
        for slot in out.iter_mut() {
            *slot = read_bits(&bk.words, pos, bk.bits) as i32 - bias;
            pos += bk.bits as usize;
        }
    }

    /// Bucketed integer matmul: `acc = codes(self) @ w`, i32-accumulated.
    /// This is the serving hot path — each bucket streams its word-aligned
    /// slab through the per-bitwidth unpacker (add/sub-only accumulation
    /// when codes fit {−1, 0, 1}), computes a bucket-local output block
    /// row-parallel under `cfg`, and the blocks are scattered back through
    /// the bucket permutation into global row order.  Bitwise identical to
    /// [`Self::matmul_i32_scratch`] and to the dense-code
    /// [`ops::matmul_i32_with`] at any thread count (i32 sums are exact;
    /// every global row has exactly one bucket).  Rescale the result with
    /// [`crate::tensor::ops::rescale_outer`] using [`Self::steps`].
    pub fn matmul_i32(&self, w: &Matrix<i32>, cfg: &ParallelConfig) -> Matrix<i32> {
        assert_eq!(self.feat_dim, w.rows, "packed matmul shape mismatch");
        self.matmul_impl(w.cols, &w.data, cfg)
    }

    /// [`Self::matmul_i32`] against a session-cached [`WeightPanel`] (the
    /// weight-code layout `PreparedModel` derives once).
    pub fn matmul_panel(&self, panel: &WeightPanel, cfg: &ParallelConfig) -> Matrix<i32> {
        assert_eq!(self.feat_dim, panel.rows(), "packed matmul shape mismatch");
        self.matmul_impl(panel.cols(), panel.data(), cfg)
    }

    fn matmul_impl(&self, n: usize, wdata: &[i32], cfg: &ParallelConfig) -> Matrix<i32> {
        let m = self.num_rows();
        let mut c = Matrix::zeros(m, n);
        if n == 0 {
            return c;
        }
        let single = self.buckets.len() == 1;
        for bk in &self.buckets {
            let bm = bk.num_rows();
            let pm_one = ops::codes_fit_pm_one(bk.bits, self.signed);
            // bucket-local rows are contiguous, so the standard row-parallel
            // dispatch applies; each worker owns disjoint output rows
            let run = |data: &mut [i32]| {
                threadpool::parallel_rows(cfg, bm, n, data, |row0, chunk| {
                    let mut scratch = vec![0i32; self.feat_dim];
                    for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                        bk.unpack_local_into(row0 + ri, self.signed, cfg.simd, &mut scratch);
                        ops::accumulate_code_row(cfg.simd, &scratch, wdata, n, pm_one, crow);
                    }
                });
            };
            if single {
                // uniform-bitwidth map: one bucket whose rows were pushed
                // in global order, so the permutation is the identity —
                // compute straight into the output, no block + scatter
                debug_assert!(bk.rows.iter().enumerate().all(|(i, &g)| g as usize == i));
                run(&mut c.data);
            } else {
                let mut local = vec![0i32; bm * n];
                run(&mut local);
                // scatter: every global row lives in exactly one bucket
                for (li, &gid) in bk.rows.iter().enumerate() {
                    let g = gid as usize;
                    c.data[g * n..(g + 1) * n].copy_from_slice(&local[li * n..(li + 1) * n]);
                }
            }
        }
        c
    }

    /// Reference integer matmul: per-global-row decode through the
    /// element-by-element [`read_bits`] loop into an i32 scratch row, then
    /// the uniform multiply inner loop — the exact shape of the
    /// pre-bucketing kernel.  Kept as the bitwise parity oracle for
    /// [`Self::matmul_i32`] (property-tested here and in the parity test
    /// suites) and as the baseline for the `quant/bucketed_speedup` bench
    /// metric; its accumulation is pinned to [`Isa::Scalar`] so the oracle
    /// never depends on the dispatch under test.
    pub fn matmul_i32_scratch(&self, w: &Matrix<i32>, cfg: &ParallelConfig) -> Matrix<i32> {
        assert_eq!(self.feat_dim, w.rows, "packed matmul shape mismatch");
        let (m, n) = (self.num_rows(), w.cols);
        let mut c = Matrix::zeros(m, n);
        threadpool::parallel_rows(cfg, m, n, &mut c.data, |row0, chunk| {
            let mut scratch = vec![0i32; self.feat_dim];
            for (ri, crow) in chunk.chunks_mut(n).enumerate() {
                self.unpack_row_into_ref(row0 + ri, &mut scratch);
                ops::accumulate_code_row(Isa::Scalar, &scratch, &w.data, n, false, crow);
            }
        });
        c
    }

    /// Dequantize one row.
    pub fn dequantize_row(&self, v: usize) -> Vec<f32> {
        let s = self.steps[v];
        self.unpack_row(v).into_iter().map(|c| c as f32 * s).collect()
    }

    /// Total storage in bytes (bucket slabs, including per-row word
    /// alignment and the one pad word per bucket).
    pub fn payload_bytes(&self) -> usize {
        self.buckets.iter().map(|b| b.words.len() * 8).sum()
    }
}

/// Write `nbits` (≤ 8) of `value` at bit offset `bitpos` into a pre-zeroed
/// `u64` slab.  A value spans at most two words; the spill into the second
/// word is taken only when the span actually crosses a word boundary.
/// `value` is masked to `nbits` so an out-of-range caller value is
/// truncated (as the old per-bit loop did) rather than ORing stray high
/// bits over neighboring codes.
fn write_bits(words: &mut [u64], bitpos: usize, nbits: u8, value: u64) {
    debug_assert!(nbits <= 8 && value < (1u64 << nbits.max(1)));
    let value = value & ((1u64 << nbits) - 1);
    let w = bitpos >> 6;
    let s = bitpos & 63;
    words[w] |= value << s;
    if s + nbits as usize > 64 {
        words[w + 1] |= value >> (64 - s);
    }
}

/// Read `nbits` (≤ 8) at bit offset `bitpos` — the element-by-element
/// reference decoder (one shift/test/branch per *bit*).  The specialized
/// unpackers below replace it on the hot path; it remains the oracle the
/// boundary and roundtrip tests pin down.
fn read_bits(words: &[u64], bitpos: usize, nbits: u8) -> u32 {
    let mut out = 0u32;
    for i in 0..nbits as usize {
        let pos = bitpos + i;
        if (words[pos >> 6] >> (pos & 63)) & 1 == 1 {
            out |= 1 << i;
        }
    }
    out
}

/// Branch-free decode of `out.len()` codes of width `B` starting at
/// `base_bit`: each code is extracted from a two-word 64-bit window with
/// two shifts, an or and a mask — no per-bit loop, no data-dependent
/// branches.  Requires one readable word past the last code's word (the
/// bucket slab's trailing pad word).  `(hi << 1) << (63 − s)` is
/// `hi << (64 − s)` computed without an undefined 64-bit shift at `s = 0`
/// (where the high word must contribute nothing).
#[inline(always)]
fn unpack_span_b<const B: usize>(words: &[u64], base_bit: usize, bias: i32, out: &mut [i32]) {
    let mask = (1u64 << B) - 1;
    let mut bit = base_bit;
    for slot in out.iter_mut() {
        let w = bit >> 6;
        let s = bit & 63;
        let lo = words[w] >> s;
        let hi = (words[w + 1] << 1) << (63 - s);
        *slot = ((lo | hi) & mask) as i32 - bias;
        bit += B;
    }
}

/// ISA dispatch for span decode: the scalar path match-dispatches to the
/// monomorphized per-bitwidth unpacker (once per bucket, not per element);
/// vector ISAs route through [`simd::unpack_codes`], which decodes eight
/// codes per step under the same slab contract (the trailing pad word) and
/// is bitwise identical — exact integer extraction either way.
fn unpack_span(isa: Isa, bits: u8, words: &[u64], base_bit: usize, bias: i32, out: &mut [i32]) {
    if isa != Isa::Scalar {
        simd::unpack_codes(isa, bits as usize, words, base_bit, bias, out);
        return;
    }
    match bits {
        1 => unpack_span_b::<1>(words, base_bit, bias, out),
        2 => unpack_span_b::<2>(words, base_bit, bias, out),
        3 => unpack_span_b::<3>(words, base_bit, bias, out),
        4 => unpack_span_b::<4>(words, base_bit, bias, out),
        5 => unpack_span_b::<5>(words, base_bit, bias, out),
        6 => unpack_span_b::<6>(words, base_bit, bias, out),
        7 => unpack_span_b::<7>(words, base_bit, bias, out),
        8 => unpack_span_b::<8>(words, base_bit, bias, out),
        other => unreachable!("bucket bitwidths are 1..=8, got {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::{levels, quantize_value};
    use crate::util::prop::{property, Gen};

    /// Quantize a random [n, f] map with per-row (step, bits) — the input
    /// shape every packing test starts from.
    fn random_codes(
        g: &mut Gen,
        n: usize,
        f: usize,
        signed: bool,
    ) -> (Vec<i32>, Vec<f32>, Vec<u8>) {
        let steps = g.vec_uniform(n, 0.01, 0.3);
        let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 9) as u8).collect();
        let x = g.vec_normal(n * f, 1.0);
        let mut codes = vec![0i32; n * f];
        for v in 0..n {
            for j in 0..f {
                codes[v * f + j] = quantize_value(x[v * f + j], steps[v], bits[v], signed);
            }
        }
        (codes, steps, bits)
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let steps = vec![0.1f32, 0.2];
        let bits = vec![3u8, 5];
        let codes = vec![1, -3, 0, 2, /* row1 */ 7, -15, 4, -1];
        let p = pack_rows(&codes, &steps, &bits, 4, true);
        assert_eq!(p.unpack_row(0), &codes[..4]);
        assert_eq!(p.unpack_row(1), &codes[4..]);
        // two distinct widths -> two buckets, ascending
        assert_eq!(p.buckets.len(), 2);
        assert_eq!(p.buckets[0].bits, 3);
        assert_eq!(p.buckets[1].bits, 5);
    }

    #[test]
    fn payload_matches_word_accounting() {
        // 10 rows × 16 feats × 2 bits = 32 bits/row -> 1 word per row,
        // plus the bucket's trailing pad word
        let steps = vec![0.1f32; 10];
        let bits = vec![2u8; 10];
        let codes = vec![0i32; 10 * 16];
        let p = pack_rows(&codes, &steps, &bits, 16, true);
        assert_eq!(p.buckets.len(), 1);
        assert_eq!(p.buckets[0].words_per_row, 1);
        assert_eq!(p.payload_bytes(), (10 + 1) * 8);
        // a 5-bit row of 16 feats needs 80 bits -> 2 words
        let p = pack_rows(&[0i32; 16], &[0.1], &[5], 16, true);
        assert_eq!(p.buckets[0].words_per_row, 2);
        assert_eq!(p.payload_bytes(), (2 + 1) * 8);
    }

    #[test]
    fn roundtrip_property_with_real_quantizer() {
        // pack -> bucketed unpack == original codes, over all bitwidths
        // 1..=8 with mixed-width rows (replayable via A2Q_PROP_SEED)
        property("pack roundtrip", 50, |g: &mut Gen| {
            let n = g.usize_range(1, 20);
            let f = g.usize_range(1, 24);
            let signed = g.bool(0.5);
            let (codes, steps, bits) = random_codes(g, n, f, signed);
            let p = pack_rows(&codes, &steps, &bits, f, signed);
            for v in 0..n {
                assert_eq!(p.unpack_row(v), &codes[v * f..(v + 1) * f], "row {v}");
                let lv = levels(bits[v], signed);
                assert!(p.unpack_row(v).iter().all(|c| c.abs() <= lv));
                // the reference decoder agrees with the specialized one
                let mut refrow = vec![0i32; f];
                p.unpack_row_into_ref(v, &mut refrow);
                assert_eq!(refrow, p.unpack_row(v), "ref decode row {v}");
            }
            // the buckets partition the global rows exactly once, ascending
            let mut seen = vec![false; n];
            let mut last_bits = 0u8;
            for bk in &p.buckets {
                assert!(bk.bits > last_bits, "buckets must ascend");
                last_bits = bk.bits;
                for &gid in &bk.rows {
                    assert!(!seen[gid as usize], "row {gid} in two buckets");
                    seen[gid as usize] = true;
                    assert_eq!(bits[gid as usize].max(1), bk.bits);
                }
            }
            assert!(seen.iter().all(|&s| s), "every row has a bucket");
        });
    }

    #[test]
    fn dequantize_row_scales() {
        let p = pack_rows(&[3, -2], &[0.5], &[4], 2, true);
        assert_eq!(p.dequantize_row(0), vec![1.5, -1.0]);
    }

    #[test]
    fn unpack_row_into_matches_unpack_row() {
        let codes = vec![1, -3, 0, 2, 7, -15, 4, -1];
        let p = pack_rows(&codes, &[0.1, 0.2], &[3, 5], 4, true);
        let mut buf = vec![0i32; 4];
        for v in 0..2 {
            p.unpack_row_into(v, &mut buf);
            assert_eq!(buf, p.unpack_row(v));
        }
        assert_eq!(p.num_rows(), 2);
        assert_eq!(p.steps(), &[0.1, 0.2]);
        assert_eq!(p.bits(), &[3, 5]);
    }

    #[test]
    fn write_read_bits_at_byte_and_word_boundaries() {
        // every width at offsets straddling byte (8k) and word (64k)
        // boundaries, including the exact boundary and one bit either side
        for nbits in 1u8..=8 {
            let max = (1u64 << nbits) - 1;
            for &pos in &[
                0usize, 7, 8, 9, 15, 16, 56, 62, 63, 64, 65, 71, 120, 126, 127, 128, 190,
            ] {
                for value in [0u64, 1, max / 2, max] {
                    let mut words = vec![0u64; 4];
                    write_bits(&mut words, pos, nbits, value);
                    assert_eq!(
                        read_bits(&words, pos, nbits) as u64,
                        value,
                        "nbits={nbits} pos={pos} value={value}"
                    );
                    // the specialized unpacker sees the same value on
                    // every available ISA path
                    for isa in simd::parity_isas() {
                        let mut out = [0i32; 1];
                        unpack_span(isa, nbits, &words, pos, 0, &mut out);
                        assert_eq!(
                            out[0] as u64, value,
                            "unpack_span {isa:?} nbits={nbits} pos={pos}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn write_bits_word_straddle_preserves_neighbors() {
        // a 7-bit value written across the word boundary must not clobber
        // adjacent codes on either side
        let mut words = vec![0u64; 3];
        write_bits(&mut words, 55, 8, 0xA5); // bits 55..63
        write_bits(&mut words, 63, 7, 0x55); // straddles words 0/1
        write_bits(&mut words, 70, 8, 0xC3); // bits 70..78 in word 1
        assert_eq!(read_bits(&words, 55, 8), 0xA5);
        assert_eq!(read_bits(&words, 63, 7), 0x55);
        assert_eq!(read_bits(&words, 70, 8), 0xC3);
    }

    #[test]
    fn pack_rows_subset_matches_full_pack() {
        property("shard slab == sliced full pack", 25, |g: &mut Gen| {
            let n = g.usize_range(2, 30);
            let f = g.usize_range(1, 16);
            let signed = g.bool(0.5);
            let (codes, steps, bits) = random_codes(g, n, f, signed);
            // a random ascending subset of rows (a shard's owned block)
            let ids: Vec<u32> = (0..n as u32).filter(|_| g.bool(0.6)).collect();
            let sub_codes: Vec<i32> = ids
                .iter()
                .flat_map(|&v| codes[v as usize * f..(v as usize + 1) * f].to_vec())
                .collect();
            let slab = pack_rows_subset(&sub_codes, &steps, &bits, &ids, f, signed);
            let full = pack_rows(&codes, &steps, &bits, f, signed);
            assert_eq!(slab.num_rows(), ids.len());
            for (li, &v) in ids.iter().enumerate() {
                assert_eq!(slab.unpack_row(li), full.unpack_row(v as usize), "row {v}");
                assert_eq!(slab.steps()[li], steps[v as usize]);
            }
        });
    }

    #[test]
    fn bucketed_matmul_matches_scratch_and_dense_property() {
        property("bucketed == scratch == dense i32 matmul", 25, |g: &mut Gen| {
            let n = g.usize_range(1, 80);
            let f = g.usize_range(1, 40);
            let cols = g.usize_range(1, 16);
            let signed = g.bool(0.5);
            let (codes, steps, bits) = random_codes(g, n, f, signed);
            let packed = pack_rows(&codes, &steps, &bits, f, signed);
            let w = Matrix::from_vec(
                f,
                cols,
                (0..f * cols).map(|i| (i % 15) as i32 - 7).collect(),
            )
            .unwrap();
            let dense = Matrix::from_vec(n, f, codes).unwrap();
            let panel = WeightPanel::from_codes(w.clone());
            for isa in simd::parity_isas() {
                let cfg = ParallelConfig {
                    threads: g.usize_range(1, 5),
                    min_rows_per_task: g.usize_range(1, 8),
                    simd: isa,
                };
                let want = ops::matmul_i32_with(&dense, &w, &cfg);
                let got = packed.matmul_i32(&w, &cfg);
                assert_eq!(got.data, want.data, "{isa:?}: bucketed != dense");
                let scratch = packed.matmul_i32_scratch(&w, &cfg);
                assert_eq!(scratch.data, want.data, "{isa:?}: scratch != dense");
                let via_panel = packed.matmul_panel(&panel, &cfg);
                assert_eq!(via_panel.data, want.data, "{isa:?}: panel != dense");
            }
        });
    }

    #[test]
    fn low_bit_buckets_take_the_pm_one_fast_path_bitwise() {
        // all rows at b <= 2 signed: the add/sub-only inner loop governs
        // the whole matmul and must still be exact
        property("b<=2 fast path bitwise", 20, |g: &mut Gen| {
            let n = g.usize_range(1, 60);
            let f = g.usize_range(1, 32);
            let cols = g.usize_range(1, 12);
            let steps = g.vec_uniform(n, 0.01, 0.3);
            let bits: Vec<u8> = (0..n).map(|_| g.usize_range(1, 3) as u8).collect();
            let x = g.vec_normal(n * f, 1.0);
            let mut codes = vec![0i32; n * f];
            for v in 0..n {
                for j in 0..f {
                    codes[v * f + j] = quantize_value(x[v * f + j], steps[v], bits[v], true);
                }
            }
            assert!(codes.iter().all(|c| c.abs() <= 1));
            let packed = pack_rows(&codes, &steps, &bits, f, true);
            let w = Matrix::from_vec(
                f,
                cols,
                (0..f * cols).map(|i| (i % 13) as i32 - 6).collect(),
            )
            .unwrap();
            let cfg = ParallelConfig::serial();
            let dense = Matrix::from_vec(n, f, codes).unwrap();
            assert_eq!(
                packed.matmul_i32(&w, &cfg).data,
                ops::matmul_i32_with(&dense, &w, &cfg).data
            );
        });
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        for isa in simd::parity_isas() {
            let cfg = ParallelConfig::serial().with_simd(isa);
            // no rows
            let p = pack_rows(&[], &[], &[], 4, true);
            assert_eq!(p.num_rows(), 0);
            let w = Matrix::from_vec(4, 3, vec![1i32; 12]).unwrap();
            let out = p.matmul_i32(&w, &cfg);
            assert_eq!(out.shape(), (0, 3));
            // zero feature dim
            let p = pack_rows(&[], &[0.1, 0.1], &[3, 4], 0, true);
            assert_eq!(p.num_rows(), 2);
            let w = Matrix::from_vec(0, 2, vec![]).unwrap();
            let out = p.matmul_i32(&w, &cfg);
            assert_eq!(out.data, vec![0i32; 4]);
        }
    }

    /// Degenerate shapes the vector unpackers must not mishandle: rows
    /// shorter than one SIMD lane group, feature counts just off the lane
    /// width, and spans ending flush against the trailing pad word — every
    /// width 1..=8, bitwise against the scalar scratch oracle.
    #[test]
    fn simd_degenerate_shapes_bitwise_equal_scalar() {
        property("simd bucketed matmul on degenerate shapes", 10, |g: &mut Gen| {
            let scalar = ParallelConfig::serial().with_simd(Isa::Scalar);
            for &f in &[1usize, 2, 3, 7, 8, 9, 15, 16, 17, 64] {
                let n = g.usize_range(1, 6);
                let cols = g.usize_range(1, 5);
                let signed = g.bool(0.5);
                // one row per width 1..=8 cycled over n rows: small buckets,
                // several of them (some widths stay empty)
                let bits: Vec<u8> = (0..n).map(|v| (v % 8 + 1) as u8).collect();
                let steps = g.vec_uniform(n, 0.01, 0.3);
                let x = g.vec_normal(n * f, 1.0);
                let mut codes = vec![0i32; n * f];
                for v in 0..n {
                    for j in 0..f {
                        codes[v * f + j] =
                            quantize_value(x[v * f + j], steps[v], bits[v], signed);
                    }
                }
                let packed = pack_rows(&codes, &steps, &bits, f, signed);
                let w = Matrix::from_vec(
                    f,
                    cols,
                    (0..f * cols).map(|i| (i % 15) as i32 - 7).collect(),
                )
                .unwrap();
                let want = packed.matmul_i32_scratch(&w, &scalar);
                for isa in simd::parity_isas() {
                    let got = packed.matmul_i32(&w, &scalar.with_simd(isa));
                    assert_eq!(got.data, want.data, "{isa:?} f={f} n={n}");
                    // row decode parity on the same shapes
                    let mut a = vec![0i32; f];
                    let mut b = vec![0i32; f];
                    for v in 0..n {
                        packed.unpack_row_into_ref(v, &mut a);
                        let (bi, li) = packed.row_loc[v];
                        packed.buckets[bi as usize].unpack_local_into(
                            li as usize,
                            signed,
                            isa,
                            &mut b,
                        );
                        assert_eq!(a, b, "{isa:?} f={f} row {v} decode diverged");
                    }
                }
            }
        });
    }
}
